"""Jitted public wrapper for the Mandelbrot kernel (auto-padding, backend
dispatch: Pallas on TPU, interpret-mode Pallas or the jnp oracle on CPU)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.channels import padded_size
from repro.kernels.mandelbrot.kernel import BLOCK_H, BLOCK_W, mandelbrot_pallas
from repro.kernels.mandelbrot.ref import mandelbrot_reference


@partial(jax.jit, static_argnames=("max_iters", "use_pallas", "interpret"))
def mandelbrot(
    x0: jax.Array,
    y0: jax.Array,
    *,
    max_iters: int = 1000,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Escape-time iterations + colour for a coordinate grid [H, W]."""
    if not use_pallas:
        return mandelbrot_reference(x0, y0, max_iters)
    H, W = x0.shape
    bh = min(BLOCK_H, padded_size(H, 8))
    bw = min(BLOCK_W, padded_size(W, 128))
    Hp, Wp = padded_size(H, bh), padded_size(W, bw)
    if (Hp, Wp) != (H, W):
        # Padding coordinates with 4.0 (outside the set) -> 1 trip, masked off.
        x0 = jnp.pad(x0, ((0, Hp - H), (0, Wp - W)), constant_values=4.0)
        y0 = jnp.pad(y0, ((0, Hp - H), (0, Wp - W)), constant_values=4.0)
    iters, colour = mandelbrot_pallas(
        x0, y0, max_iters, block_h=bh, block_w=bw, interpret=interpret
    )
    return iters[:H, :W], colour[:H, :W]
