"""The pluggable deployment layer (repro.cluster.deploy) + placement policy.

Launcher-logic and policy tests run node-loaders as *threads*
(InProcessLauncher) — the full wire protocol over real localhost sockets,
none of the per-scenario interpreter-fork cost.  The SSHLauncher tests use
a stub ``ssh`` executable that runs the remote command locally, so the
whole fan-out path (command assembly, env export, code sync, handle
lifecycle, logs) is exercised hermetically; CI's ssh-smoke job runs the
same launcher against a real loopback sshd.
"""

import os
import socket
import stat
import sys
import threading
import time

import pytest

from repro.cluster.deploy import (
    InProcessLauncher,
    LocalLauncher,
    PlacementPolicy,
    SSHLauncher,
)
from repro.cluster.deploy.base import NodeHandle
from repro.cluster.membership import (
    DONE,
    LAUNCHING,
    REGISTERED,
    REPLACED,
    Membership,
)
from repro.cluster.node_loader import connect_with_retry
from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails
from repro.runtime.failures import HeartbeatMonitor

# Fast liveness settings for tests (death detected within ~0.4s).
FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _sum_collect():
    return ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)


def _spec(nclusters, workers, n_items, work):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_sum_collect(),
    )


class DeadHandle(NodeHandle):
    """A launch some machine swallowed: accepted, never came up."""

    def __init__(self, node_id):
        self.node_id = node_id
        self.where = "void"

    def poll(self):
        return 1

    def wait(self, timeout=None):
        return 1

    def kill(self):
        pass

    def logs(self):
        return []


class FlakyLauncher(InProcessLauncher):
    """Silently drops the first launch of the named nodes (they never dial
    the host) — the idle-workstation pool's classic failure mode."""

    def __init__(self, drop_first=(), **kw):
        super().__init__(**kw)
        self._drop = set(drop_first)
        self.dropped = []

    def launch(self, node_id, *, avoid=()):
        if node_id in self._drop:
            self._drop.discard(node_id)
            self.dropped.append(node_id)
            self.launched.append(node_id)
            return DeadHandle(node_id)
        return super().launch(node_id, avoid=avoid)


# ---------------------------------------------------------------------------
# membership states
# ---------------------------------------------------------------------------


def test_membership_launch_register_replace_lifecycle():
    m = Membership(HeartbeatMonitor())
    rec = m.expect("node0", now=0.0)
    assert rec.state == LAUNCHING and not rec.alive
    # An announced launch neither counts as arrived nor blocks termination.
    assert m.arrived_count() == 0
    assert m.finished()
    with pytest.raises(ValueError):
        m.expect("node0")

    # Respawn: retire the silent launch, announce its replacement.
    m.replace("node0")
    assert m.nodes["node0"].state == REPLACED
    m.expect("node0r2", now=1.0).attempts = 2
    m.register("node0r2", "127.0.0.1:5", now=1.5)
    assert m.nodes["node0r2"].state == REGISTERED
    assert m.arrived_count() == 1

    # The replaced original showing up late is still a usable worker.
    m.register("node0", "127.0.0.1:6", now=2.0)
    assert m.nodes["node0"].state == REGISTERED
    assert m.arrived_count() == 2
    # ...but a duplicate of a live member is rejected.
    with pytest.raises(ValueError):
        m.register("node0r2", "127.0.0.1:7")
    with pytest.raises(ValueError):
        m.replace("node0")

    m.mark_done("node0")
    m.mark_done("node0r2")
    assert m.finished()


def test_placement_policy_validation():
    PlacementPolicy().validate(3)
    PlacementPolicy(min_nodes=1, max_respawns=2).validate(3)
    with pytest.raises(ValueError, match="min_nodes"):
        PlacementPolicy(min_nodes=0).validate(3)
    with pytest.raises(ValueError, match="min_nodes"):
        PlacementPolicy(min_nodes=4).validate(3)
    with pytest.raises(ValueError, match="max_respawns"):
        PlacementPolicy(max_respawns=-1).validate(3)
    PlacementPolicy(max_heals=2).validate(3)
    with pytest.raises(ValueError, match="max_heals"):
        PlacementPolicy(max_heals=-1).validate(3)


# ---------------------------------------------------------------------------
# node-loader connect retry
# ---------------------------------------------------------------------------


def test_connect_retry_waits_for_late_listener():
    """A node-loader may start before the host is listening (uncontrolled
    remote start order): the dial must retry, not die on ECONNREFUSED."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()  # free the port: nobody is listening now

    got = {}

    def dial():
        try:
            sock = connect_with_retry("127.0.0.1", port, timeout=10.0)
            got["peer"] = sock.getpeername()
            sock.close()
        except OSError as exc:  # pragma: no cover - failure diagnostics
            got["error"] = exc

    t = threading.Thread(target=dial, daemon=True)
    t.start()
    time.sleep(0.6)  # let several refused attempts happen
    listener = socket.socket()
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind(("127.0.0.1", port))
    listener.listen(1)
    t.join(timeout=10)
    listener.close()
    assert not t.is_alive()
    assert got.get("peer") == ("127.0.0.1", port), got


def test_connect_retry_gives_up_after_timeout():
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    t0 = time.monotonic()
    with pytest.raises(ConnectionError, match="could not reach"):
        connect_with_retry("127.0.0.1", port, timeout=0.5)
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------------
# placement policy, end to end over the InProcessLauncher
# ---------------------------------------------------------------------------


def test_degraded_start_admits_job_with_min_nodes():
    """One launch is swallowed; min_nodes=1 admits the job with the
    survivor instead of raising at the registration barrier."""
    launcher = FlakyLauncher(drop_first=["node1"], connect_timeout=5.0)
    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 2, 30, lambda x: x * x), backend="cluster",
        launcher=launcher, min_nodes=1, register_timeout=0.6,
        job_timeout=60.0, **FAST,
    )
    assert app.run() == sum(i * i for i in range(30))
    hl = app.host_loader
    assert hl.stats.degraded_start
    assert hl.stats.items_total == 30
    assert hl.membership.nodes["node0"].state == DONE
    # The straggler stays LAUNCHING — still eligible to late-join a longer
    # job — and never blocked termination.
    assert hl.membership.nodes["node1"].state == LAUNCHING
    assert app.orphaned() == []


def test_silent_node_is_respawned_and_job_runs_at_full_strength():
    """A node that never registers is relaunched (up to max_respawns): the
    job starts at full strength with the replacement doing real work."""
    launcher = FlakyLauncher(drop_first=["node1"], connect_timeout=5.0)
    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 1, 40, lambda x: 3 * x), backend="cluster",
        launcher=launcher, max_respawns=1, respawn_after=0.3,
        register_timeout=10.0, job_timeout=60.0, **FAST,
    )
    assert app.run() == sum(3 * i for i in range(40))
    hl = app.host_loader
    assert hl.stats.respawns == 1
    assert not hl.stats.degraded_start
    assert hl.membership.nodes["node1"].state == REPLACED
    assert hl.membership.nodes["node1r2"].state == DONE
    assert hl.membership.nodes["node1r2"].attempts == 2
    # The replacement was a genuine worker, not a bystander.
    assert hl.membership.nodes["node1r2"].items_done > 0
    assert launcher.launched == ["node0", "node1", "node1r2"]
    assert app.orphaned() == []


def test_late_join_mid_run_gets_load_and_credits_exactly_once():
    """A node registering after the run started is admitted, shipped LOAD,
    and answered credits immediately; results stay exactly-once."""
    n_items = 40
    launcher = InProcessLauncher(connect_timeout=10.0,
                                 delays={"node1": 0.9})
    builder = ClusterBuilder()

    def work(x):
        time.sleep(0.05)
        return x + 1

    app = builder.build_application(
        _spec(2, 1, n_items, work), backend="cluster",
        launcher=launcher, min_nodes=1, register_timeout=0.3,
        job_timeout=60.0, **FAST,
    )
    assert app.run() == sum(i + 1 for i in range(n_items))
    hl = app.host_loader
    assert hl.stats.degraded_start  # node1 missed the barrier...
    assert hl.stats.late_joins == 1  # ...then joined mid-run
    assert hl.stats.items_total == n_items
    assert hl.stats.duplicates_dropped == 0
    assert hl.membership.nodes["node1"].state == DONE
    assert hl.membership.nodes["node1"].items_done > 0
    assert app.orphaned() == []


def test_slow_launcher_prepare_does_not_trigger_spurious_respawns():
    """The silence clock must start when the barrier does, not when the
    launches were announced: a launcher whose prepare() (code sync) takes
    longer than respawn_after must not get its healthy, just-launched
    nodes respawned out from under it."""

    class SlowPrepare(InProcessLauncher):
        def prepare(self, connect_host, port):
            time.sleep(0.6)  # a code sync slower than respawn_after
            super().prepare(connect_host, port)

    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 1, 20, lambda x: x), backend="cluster",
        launcher=SlowPrepare(connect_timeout=10.0),
        max_respawns=2, respawn_after=0.25, register_timeout=10.0,
        job_timeout=60.0, **FAST,
    )
    assert app.run() == sum(range(20))
    assert app.host_loader.stats.respawns == 0
    assert app.orphaned() == []


def test_strict_barrier_still_raises_without_policy_relaxation():
    """The seed contract survives: no min_nodes / respawns -> a missing
    node fails the barrier with a TimeoutError."""
    launcher = FlakyLauncher(drop_first=["node1"], connect_timeout=5.0)
    app = ClusterBuilder().build_application(
        _spec(2, 1, 10, lambda x: x), backend="cluster",
        launcher=launcher, register_timeout=0.5, job_timeout=30.0, **FAST,
    )
    with pytest.raises(TimeoutError, match="registered"):
        app.run()
    assert app.orphaned() == []


# ---------------------------------------------------------------------------
# orphan hygiene
# ---------------------------------------------------------------------------


def test_start_failure_midway_reaps_already_launched_nodes():
    """If bootstrap raises after some launches (the orphaned-children leak),
    teardown still runs and reaps them."""

    class ExplodingLauncher(InProcessLauncher):
        def launch(self, node_id, *, avoid=()):
            if node_id == "node1":
                raise RuntimeError("fan-out exploded on node1")
            return super().launch(node_id, avoid=avoid)

    app = ClusterBuilder().build_application(
        _spec(2, 1, 10, lambda x: x), backend="cluster",
        launcher=ExplodingLauncher(connect_timeout=1.0),
        job_timeout=30.0, shutdown_grace=5.0, **FAST,
    )
    with pytest.raises(RuntimeError, match="fan-out exploded"):
        app.run()
    assert app.error is None  # raised synchronously, not via run_async
    assert "node0" in app.handles
    assert app.orphaned() == []


def test_launcher_and_hosts_are_mutually_exclusive():
    app = ClusterBuilder().build_application(
        _spec(1, 1, 1, lambda x: x), backend="cluster",
        launcher=InProcessLauncher(), hosts=["localhost"],
    )
    with pytest.raises(TypeError, match="not both"):
        app.start()


# ---------------------------------------------------------------------------
# SSHLauncher (hermetic: stub ssh executes the remote command locally)
# ---------------------------------------------------------------------------


@pytest.fixture
def stub_ssh(tmp_path):
    """An ``ssh`` stand-in: drops the hostname, runs the command locally."""
    path = tmp_path / "stub-ssh"
    path.write_text("#!/bin/sh\n# stub ssh: argv = <host> <command>\n"
                    "shift\nexec sh -c \"$1\"\n")
    path.chmod(path.stat().st_mode | stat.S_IXUSR)
    return str(path)


def test_ssh_launcher_runs_cluster_through_stub_ssh(stub_ssh):
    """The full fan-out path — command assembly, env export, per-node ssh
    process, logs — against a stub ssh (CI's ssh-smoke job runs the same
    launcher against a real loopback sshd)."""
    launcher = SSHLauncher(
        ["ws-a", "ws-b"], ssh_cmd=(stub_ssh,), ssh_opts=(),
        python=sys.executable, connect_timeout=30.0,
    )
    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 2, 30, lambda x: x * x), backend="cluster",
        launcher=launcher, job_timeout=120.0, **FAST,
    )
    assert app.run() == sum(i * i for i in range(30))
    # Round-robin placement over the host pool, one ssh client per node.
    assert {h.where for h in app.handles.values()} == {"ssh:ws-a", "ssh:ws-b"}
    assert all(h.returncode == 0 for h in app.handles.values())
    assert any("node-loader done" in line
               for h in app.handles.values() for line in h.logs())
    assert app.orphaned() == []


def test_ssh_code_sync_tar_fallback_ships_src_tree(tmp_path, stub_ssh):
    """Without rsync the sync falls back to tar-over-ssh; the remote dir
    ends up with the src tree the node-loader needs."""
    remote_dir = tmp_path / "deployed"
    launcher = SSHLauncher(
        ["ws-a"], ssh_cmd=(stub_ssh,), ssh_opts=(),
        remote_dir=str(remote_dir), sync="tar",
    )
    launcher.prepare("127.0.0.1", 2000)
    assert launcher.synced_hosts == ["ws-a"]
    synced = remote_dir / "src" / "repro" / "cluster" / "node_loader.py"
    assert synced.is_file()
    assert not list(remote_dir.glob("**/__pycache__"))
    # The launch command runs from the synced tree, not this checkout.
    cmd = launcher.remote_command("node0")
    assert f"cd {remote_dir}" in cmd
    assert f"PYTHONPATH={remote_dir}/src" in cmd


def test_ssh_launcher_end_to_end_from_synced_tree(tmp_path, stub_ssh):
    """Code sync + launch together: the node-loader actually executes out
    of the tar-synced copy (the plain-pickle / compile_cache_dir story)."""
    remote_dir = tmp_path / "deployed"
    launcher = SSHLauncher(
        ["ws-a"], ssh_cmd=(stub_ssh,), ssh_opts=(),
        remote_dir=str(remote_dir), sync="tar",
        python=sys.executable, connect_timeout=30.0,
    )
    app = ClusterBuilder().build_application(
        _spec(1, 2, 20, lambda x: 2 * x), backend="cluster",
        launcher=launcher, job_timeout=120.0, **FAST,
    )
    assert app.run() == sum(2 * i for i in range(20))
    assert app.orphaned() == []


def test_ssh_respawn_avoids_the_machine_that_swallowed_the_launch(stub_ssh,
                                                                  tmp_path):
    """Respawn placement: the replacement launch steers clear of the host
    whose first launch went silent."""
    # A second "ssh" that eats the command: the remote machine accepts the
    # session but the node-loader never comes up.
    eater = tmp_path / "eating-ssh"
    eater.write_text("#!/bin/sh\nexit 0\n")
    eater.chmod(eater.stat().st_mode | stat.S_IXUSR)

    class FirstLaunchEaten(SSHLauncher):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.eaten = False

        def launch(self, node_id, *, avoid=()):
            real_cmd = self.ssh_cmd
            if not self.eaten:
                self.eaten = True
                self.ssh_cmd = (str(eater),)
            try:
                return super().launch(node_id, avoid=avoid)
            finally:
                self.ssh_cmd = real_cmd

    launcher = FirstLaunchEaten(
        ["ws-bad", "ws-good"], ssh_cmd=(stub_ssh,), ssh_opts=(),
        python=sys.executable, connect_timeout=30.0,
    )
    app = ClusterBuilder().build_application(
        _spec(1, 1, 20, lambda x: x + 7), backend="cluster",
        launcher=launcher, max_respawns=1, respawn_after=0.4,
        register_timeout=15.0, job_timeout=120.0, **FAST,
    )
    assert app.run() == sum(i + 7 for i in range(20))
    hl = app.host_loader
    assert hl.stats.respawns == 1
    # node0 went to ws-bad and vanished; node0r2 avoided ws-bad.
    assert app.handles["node0"].where == "ssh:ws-bad"
    assert app.handles["node0r2"].where == "ssh:ws-good"
    assert app.orphaned() == []


def test_ssh_home_relative_remote_dir_stays_shell_expandable():
    """remote_dir='~/x' must reach the remote shell as "$HOME"/x — quoting
    the tilde would make cd/PYTHONPATH point at a literal './~' dir."""
    launcher = SSHLauncher(["ws"], remote_dir="~/cluster-app", sync="none")
    launcher.prepare("0.0.0.0", 2000)
    cmd = launcher.remote_command("node0")
    assert 'cd "$HOME"/cluster-app' in cmd
    assert 'PYTHONPATH="$HOME"/cluster-app/src' in cmd
    assert "'~" not in cmd


def test_ssh_explicit_connect_host_survives_prepare():
    """The quickstart shape: host binds 0.0.0.0, launcher carries the
    LAN-reachable address remote nodes must dial — prepare() must not
    clobber it with the (unroutable or loopback) bind address."""
    launcher = SSHLauncher(["ws"], connect_host="10.0.0.5")
    launcher.prepare("0.0.0.0", 2000)
    assert launcher.connect_host == "10.0.0.5"
    assert "--host 10.0.0.5" in launcher.remote_command("node0")
    # Unconfigured -> fall back to the bind address, loopback-resolved
    # (the ssh-to-localhost case).
    fallback = SSHLauncher(["ws"])
    fallback.prepare("0.0.0.0", 2000)
    assert fallback.connect_host == "127.0.0.1"


def test_local_launcher_is_the_default_and_unchanged():
    """No launcher option -> LocalLauncher subprocesses (seed behaviour)."""
    app = ClusterBuilder().build_application(
        _spec(1, 1, 10, lambda x: x), backend="cluster",
        job_timeout=60.0, **FAST,
    )
    assert app.run() == sum(range(10))
    assert isinstance(app.launcher, LocalLauncher)
    assert all(h.where == "local" for h in app.handles.values())
    assert app.orphaned() == []
