"""The peer data plane (repro.cluster.peer) and its control-plane half.

Units cover the routing table (round-robin and keyed preference orders,
process-stable hashing), the broadcast-block registry/store pair (chunk
assembly, digest rejection, LRU bound), and the DSL/route validation
surface.  The CSP section re-runs Listing 3's assertions over peer-routed
pipeline wirings — a peer hop is a channel rename, so the state space must
not change — and checks that an ill-formed (cyclic) route is rejected
before exploration.  The e2e section boots real ClusterService pools over
an InProcessLauncher and holds the acceptance invariants: zero payload
bytes relayed through the host on a peer hop, exact results under keyed
shuffle and under a mid-run node kill, and broadcast blocks arriving with
at least one chunk traded between peers.
"""

import socket
import threading
import time

import pytest

from repro.cluster import peer
from repro.cluster.deploy.inprocess import InProcessLauncher
from repro.cluster.host_loader import HostLoader
from repro.cluster.netchannels import ChannelClosed
from repro.cluster.service import ClusterService
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    Frame,
    FrameConnection,
    FrameType,
    dumps_code,
)
from repro.core.dsl import Pipeline
from repro.core.processes import EmitDetails, ResultDetails
from repro.core.protocol import normalize_routes
from repro.core.verify import verify_pipeline

FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _list_collect():
    return ResultDetails(name="list", init=lambda: [],
                         collect=lambda a, x: a + [x], finalise=sorted)


def _service(**kw):
    kw.setdefault("nodes", 3)
    kw.setdefault("workers", 2)
    kw.setdefault("launcher", InProcessLauncher())
    for k, v in FAST.items():
        kw.setdefault(k, v)
    return ClusterService(**kw)


def _two_stage(n, *, route="peer", key_fn=None, stage1=None):
    """range -> double (2x2) -> +1 (1x1, the routed hop) -> sorted list."""

    def double(x):
        return x * 2

    return (Pipeline(host="127.0.0.1")
            .emit(_range_emit(n))
            .stage(double, nodes=2, workers=2, name="double")
            .stage(stage1 or _plus_one, nodes=1, workers=1, name="plus",
                   route=route, key_fn=key_fn)
            .collect(_list_collect())
            .build())


# Module-level so resubmits would digest-match; also keeps the closures
# the specs pickle small.
def _plus_one(x):
    return x + 1


def _slow_plus_one(x):
    time.sleep(0.004)
    return x + 1


def _double(x):
    return x * 2


def _times_three(x):
    return x * 3


def _slow_times_three(x):
    time.sleep(0.004)
    return x * 3


def _three_stage(n, *, stage2=None):
    """range -> double -> +1 (peer hop) -> *3 (a SECOND consecutive peer
    hop) -> sorted list: the chained-forwarding shape where intermediate
    values never transit the host at all."""
    return (Pipeline(host="127.0.0.1")
            .emit(_range_emit(n))
            .stage(_double, nodes=2, workers=2, name="double")
            .stage(_plus_one, nodes=1, workers=1, name="plus", route="peer")
            .stage(stage2 or _times_three, nodes=1, workers=1, name="tri",
                   route="peer")
            .collect(_list_collect())
            .build())


# ---------------------------------------------------------------------------
# routing units
# ---------------------------------------------------------------------------


def test_stable_hash_deterministic_and_typed():
    for key in (0, -7, "band", b"raw", 3.5, None, True, (1, "a"), [2, 3]):
        assert peer.stable_hash(key) == peer.stable_hash(key)
    # bool must not collide with int 1 (both hash() to 1 in builtin terms)
    assert peer.stable_hash(True) != peer.stable_hash(1)
    assert peer.stable_hash("1") != peer.stable_hash(1)
    assert 0 <= peer.stable_hash("x") < 2 ** 64


def test_route_table_round_robin_rotates_preference():
    rt = peer.RouteTable({"1": {"targets": ["a", "b", "c"], "mode": "rr",
                               "key_fn": None}})
    assert rt.has(1) and not rt.has(0)
    orders = [rt.targets_for(1, object()) for _ in range(4)]
    # every call returns ALL targets (fallback walk), head rotating
    assert all(sorted(o) == ["a", "b", "c"] for o in orders)
    assert [o[0] for o in orders] == ["a", "b", "c", "a"]


def test_route_table_keyed_pins_by_stable_hash():
    blob = dumps_code(lambda v: v % 4)
    rt = peer.RouteTable({"2": {"targets": ["a", "b"], "mode": "keyed",
                               "key_fn": blob}})
    # same key -> same preference order, every time
    first = rt.targets_for(2, 5)
    assert all(rt.targets_for(2, 5) == first for _ in range(5))
    # the order is the full list, so a dead primary degrades to the next
    assert sorted(first) == ["a", "b"]
    assert first[0] == rt.targets_for(2, 9)[0]  # 5 % 4 == 9 % 4


def test_route_table_empty_and_unknown_stage():
    rt = peer.RouteTable({})
    assert rt.targets_for(0, 1) == []
    assert not rt.has(0)


def test_partition_seam_round_trip():
    try:
        assert not peer.is_partitioned("nodeX")
        peer.partition_node("nodeX", duration_s=30.0)
        assert peer.is_partitioned("nodeX")
        assert peer.is_partitioned("nodeY", "nodeX")
    finally:
        peer.heal_partitions()
    assert not peer.is_partitioned("nodeX")


def test_partition_is_sender_side_only_for_items():
    """Exactly-once under partition races: the sender refuses new
    transfers on a cut edge, but a PEER_ITEMS frame that already reached
    the receiver is processed — the sender has acked it to the host, so
    eating it would strand the item in a ledger no requeue revisits."""
    store = peer.BlockStore()
    server = peer.PeerServer("partRecv", store, bind_host="127.0.0.1")
    server.start()
    got: list = []
    server.set_on_items(lambda jid, items: got.extend(items))
    client = peer.PeerClient(
        "partSend", {"partRecv": ("127.0.0.1", server.port)})
    try:
        # A raw dialed link stands in for a frame in flight when the
        # partition activates: it bypasses the client's send-side gate.
        raw = FrameConnection(
            socket.create_connection(("127.0.0.1", server.port)))
        raw.send(Frame(FrameType.PEER_HELLO, {"node_id": "partSend"}))
        peer.partition_node("partRecv", duration_s=30.0)
        with pytest.raises(ChannelClosed, match="partitioned"):
            client.send_items(7, "partRecv", [{"id": 0, "s": 1, "obj": 0}])
        raw.send(Frame(FrameType.PEER_ITEMS,
                       {"from": "partSend",
                        "items": [{"id": 1, "s": 1, "obj": 5}]},
                       APP_WIRE_CHANNEL, 7))
        deadline = time.monotonic() + 5.0
        while not got and time.monotonic() < deadline:
            time.sleep(0.01)
        assert [i["id"] for i in got] == [1]
        raw.close()
    finally:
        peer.heal_partitions()
        client.close()
        server.close()


def test_peer_server_intake_gate_applies_backpressure():
    """The intake gate runs on the reader thread before each PEER_ITEMS
    hand-off: while it blocks, nothing reaches the handler (the socket
    stops draining), and releasing it delivers everything in order."""
    store = peer.BlockStore()
    server = peer.PeerServer("gateRecv", store, bind_host="127.0.0.1")
    server.start()
    got: list = []
    gate_open = threading.Event()
    server.set_on_items(lambda jid, items: got.extend(items))
    server.set_intake_gate(lambda n: gate_open.wait(10.0))
    client = peer.PeerClient(
        "gateSend", {"gateRecv": ("127.0.0.1", server.port)})
    try:
        client.send_items(1, "gateRecv", [{"id": 0, "s": 1, "obj": 0}])
        client.send_items(1, "gateRecv", [{"id": 1, "s": 1, "obj": 1}])
        time.sleep(0.1)
        assert got == []  # reader parked in the gate, nothing delivered
        gate_open.set()
        deadline = time.monotonic() + 5.0
        while len(got) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert [i["id"] for i in got] == [0, 1]
    finally:
        client.close()
        server.close()


# ---------------------------------------------------------------------------
# broadcast block units
# ---------------------------------------------------------------------------


def test_block_registry_publish_idempotent_immutable():
    reg = peer.BlockRegistry()
    data = b"w" * 100
    digest = reg.publish("weights", data)
    assert reg.publish("weights", data) == digest  # same bytes: fine
    with pytest.raises(ValueError, match="different content"):
        reg.publish("weights", b"x" * 100)
    (entry,) = reg.manifest()
    assert entry == {"name": "weights", "digest": digest,
                     "size": 100, "nchunks": 1}
    assert reg.get_chunk("weights", 0) == data
    assert reg.get_chunk("weights", 1) is None
    assert reg.get_chunk("nope", 0) is None


def test_block_store_assembles_chunks_and_verifies_digest():
    reg = peer.BlockRegistry()
    # >1 chunk so assembly order and indexing actually matter
    data = bytes(range(256)) * ((peer.BLOCK_CHUNK_BYTES * 2) // 256 + 1)
    reg.publish("big", data)
    (entry,) = reg.manifest()
    assert entry["nchunks"] == 3

    store = peer.BlockStore()
    assert store.expect(entry)
    assert store.missing("big") == [0, 1, 2]
    # out-of-order, with a duplicate — both idempotent
    for idx in (2, 0, 0, 1):
        store.add_chunk("big", idx, reg.get_chunk("big", idx), from_peer=idx == 1)
    assert store.wait("big", timeout=5.0) == data
    assert store.missing("big") == []
    assert not store.expect(entry)  # already resident: nothing to fetch
    c = store.counters()
    assert c["blocks_fetched_from_peers"] == 1
    assert c["blocks_fetched_from_host"] == 2
    # resident blocks serve chunks to peers
    assert store.get_chunk("big", 2) == reg.get_chunk("big", 2)


def test_block_store_drops_corrupt_assembly_for_retry():
    reg = peer.BlockRegistry()
    reg.publish("blk", b"a" * 50)
    (entry,) = reg.manifest()
    store = peer.BlockStore()
    store.expect(entry)
    store.add_chunk("blk", 0, b"b" * 50)  # right size, wrong bytes
    assert not store.has("blk")
    assert store.digest_failures == 1
    assert store.missing("blk") == [0]  # retryable
    store.add_chunk("blk", 0, b"a" * 50)
    assert store.wait("blk", timeout=5.0) == b"a" * 50


def test_block_store_lru_bound():
    store = peer.BlockStore(slots=2)
    reg = peer.BlockRegistry()
    for i in range(3):
        reg.publish(f"b{i}", bytes([i]) * 10)
    for entry in reg.manifest():
        store.expect(entry)
        store.add_chunk(entry["name"], 0, reg.get_chunk(entry["name"], 0))
    assert not store.has("b0")  # evicted
    assert store.has("b1") and store.has("b2")


def test_block_eviction_and_release_unpin_global_mirror():
    """The process-global read mirror must shrink with the store LRUs: an
    eviction (or a node shutdown's release) drops the global copy once the
    last holding store lets go, so a warm pool node stays bounded."""
    reg = peer.BlockRegistry()
    for i in range(3):
        reg.publish(f"gmb{i}", bytes([i]) * 16)
    entries = {e["name"]: e for e in reg.manifest()}
    store = peer.BlockStore(slots=2)
    for name in ("gmb0", "gmb1", "gmb2"):
        store.expect(entries[name])
        store.add_chunk(name, 0, reg.get_chunk(name, 0))
    # LRU evicted gmb0 from the store AND the global mirror
    assert not store.has("gmb0")
    assert "gmb0" not in peer._global_blocks
    # a second holder keeps the mirror entry alive past the first release
    store2 = peer.BlockStore()
    store2.expect(entries["gmb1"])
    store2.add_chunk("gmb1", 0, reg.get_chunk("gmb1", 0))
    store.release()
    assert peer.get_block("gmb1", timeout=1.0) == bytes([1]) * 16
    assert "gmb2" not in peer._global_blocks  # sole holder released
    store2.release()
    assert "gmb1" not in peer._global_blocks


# ---------------------------------------------------------------------------
# DSL + route validation
# ---------------------------------------------------------------------------


def test_dsl_rejects_bad_route_values():
    p = Pipeline(host="127.0.0.1").emit(_range_emit(4))
    with pytest.raises(ValueError, match="route must be"):
        p.stage(_plus_one, route="udp")
    with pytest.raises(ValueError, match="key_fn only applies"):
        p.stage(_plus_one, key_fn=lambda v: v)
    with pytest.raises(ValueError, match="first stage cannot"):
        p.stage(_plus_one, route="peer")


def test_peer_routed_hops_maps_receiving_stage_to_source_hop():
    spec = _two_stage(4, key_fn=None)
    assert set(spec.peer_routed_hops()) == {0}
    spec = _two_stage(4, route=None)
    assert spec.peer_routed_hops() == {}


def test_normalize_routes_accepts_adjacent_and_rejects_cyclic():
    assert normalize_routes([0, 1], nstages=3) == frozenset({0, 1})
    assert normalize_routes({0: 1}, nstages=2) == frozenset({0})
    assert normalize_routes(None, nstages=2) == frozenset()
    with pytest.raises(ValueError, match="cyclic peer route"):
        normalize_routes({1: 0}, nstages=3)
    with pytest.raises(ValueError, match="cyclic peer route"):
        normalize_routes({1: 1}, nstages=3)
    with pytest.raises(ValueError, match="skips"):
        normalize_routes({0: 2}, nstages=3)
    with pytest.raises(ValueError):
        normalize_routes([5], nstages=2)  # out of range


# ---------------------------------------------------------------------------
# CSP verification of peer-routed wirings
# ---------------------------------------------------------------------------


def test_verify_peer_routed_pipeline_all_assertions():
    """A peer hop reroutes the rendezvous but not the protocol: the full
    Listing-3 battery must hold over the decentralised wiring."""
    report = verify_pipeline([(2, 1), (1, 1)], 3, routes=[0])
    assert report.deadlock_free, report.summary()
    assert report.divergence_free, report.summary()
    assert report.terminates, report.summary()
    assert report.objects_delivered_exactly_once, report.summary()
    assert report.ok


def test_verify_peer_hop_is_a_channel_rename():
    """Same topology host-routed vs peer-routed: the hop rename must
    preserve the state space exactly (it relabels, never reorders)."""
    host = verify_pipeline([(2, 1), (1, 1)], 3)
    peered = verify_pipeline([(2, 1), (1, 1)], 3, routes=[0])
    assert peered.num_states == host.num_states
    assert peered.num_transitions == host.num_transitions


def test_verify_keyed_shuffle_composition():
    """Three stages, both hops peer-routed (the keyed-shuffle shape: the
    key only picks *which* target, which the finitised model abstracts
    as the hop channel) — still deadlock/livelock free and exactly-once."""
    report = verify_pipeline([(2, 1), (2, 1), (1, 1)], 2, routes=[0, 1])
    assert report.ok, report.summary()


def test_verify_rejects_cyclic_peer_route_before_exploring():
    with pytest.raises(ValueError, match="cyclic peer route"):
        verify_pipeline([(2, 1), (1, 1), (1, 1)], 2, routes={1: 0})


# ---------------------------------------------------------------------------
# host control-plane units
# ---------------------------------------------------------------------------


def test_peer_dir_preserves_ipv6_addresses():
    """The peer directory derives a dialable ip from the node's observed
    'ip:port' address: the port split must come from the RIGHT (an IPv6
    ip contains colons) or every peer edge silently degrades to relay."""
    hl = HostLoader(None, pool_nodes=3)
    try:
        hl.membership.register("n6", "::1:41234", peer_port=7001)
        hl.membership.register("n4", "10.0.0.5:555", peer_port=7002)
        hl.membership.register("nb", "[fe80::2]:99", peer_port=7003)
        hl.membership.register("noport", "127.0.0.1:1", peer_port=0)
        d = hl._peer_dir()
        assert d["n6"] == ("::1", 7001)
        assert d["n4"] == ("10.0.0.5", 7002)
        assert d["nb"] == ("fe80::2", 7003)
        assert "noport" not in d  # no data-plane port: not routable
    finally:
        hl._listener.close()


# ---------------------------------------------------------------------------
# e2e: peer-routed jobs on a live pool
# ---------------------------------------------------------------------------


def test_peer_hop_relays_zero_payload_bytes_through_host():
    n = 40
    with _service() as svc:
        h = svc.submit(_two_stage(n), timeout=60)
        assert h.result() == sorted(2 * i + 1 for i in range(n))
        st = h.stats()
        assert st["peer_forwarded"] == n
        assert st["host_relay_bytes"] == 0
        assert st["duplicates_dropped"] == 0
    assert svc.orphaned() == []


def test_host_routed_hop_still_relays_and_counts_bytes():
    """The control: same pipeline without route='peer' moves every hop
    payload through the host, and the counter says so."""
    n = 20
    with _service() as svc:
        h = svc.submit(_two_stage(n, route=None), timeout=60)
        assert h.result() == sorted(2 * i + 1 for i in range(n))
        st = h.stats()
        assert st["peer_forwarded"] == 0
        assert st["host_relay_bytes"] > 0
    assert svc.orphaned() == []


def test_keyed_shuffle_partitions_and_matches():
    n = 30
    with _service() as svc:
        h = svc.submit(_two_stage(n, key_fn=lambda v: v % 4), timeout=60)
        assert h.result() == sorted(2 * i + 1 for i in range(n))
        st = h.stats()
        assert st["peer_forwarded"] == n
        assert st["host_relay_bytes"] == 0
    assert svc.orphaned() == []


def test_chained_peer_hops_relay_zero_bytes_and_terminate():
    """Two CONSECUTIVE route='peer' stages: a node's stage-s input arrives
    over a peer edge and its result leaves over another.  The host's
    exactly-once ledger must follow the item across both hops (the acks
    resolve against peer_inflight, not inflight) or the job deadlocks."""
    n = 40
    with _service() as svc:
        h = svc.submit(_three_stage(n), timeout=60)
        assert h.result() == sorted(3 * (2 * i + 1) for i in range(n))
        st = h.stats()
        assert st["peer_forwarded"] == 2 * n  # both hops, every item
        assert st["host_relay_bytes"] == 0
        assert st["duplicates_dropped"] == 0
    assert svc.orphaned() == []


def test_kill_node_mid_run_chained_peer_hops_exactly_once():
    """A mid-run kill while items sit mid-chain: the stranded ledger
    entries hold the LAST input the host saw (possibly several stages
    back), so recompute restarts there under the same ids and dedup keeps
    delivery exactly-once."""
    n = 60
    with _service(nodes=3, workers=1) as svc:
        h = svc.submit(_three_stage(n, stage2=_slow_times_three),
                       timeout=120)
        hl = svc.host_loader
        deadline = time.monotonic() + 30
        while hl.stats.items_total < 5:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        svc.kill_node("node2")
        assert h.result() == sorted(3 * (2 * i + 1) for i in range(n))
        assert hl.stats.deaths_detected == 1
        assert h.stats()["items_collected"] == n
    assert svc.orphaned() == []


def test_kill_peer_target_mid_run_exactly_once():
    """Killing a node that receives peer-forwarded items mid-run: the host
    requeues its peer-ledger items upstream under the same ids, survivors
    recompute, and dedup keeps delivery exactly-once."""
    n = 80
    with _service(nodes=3, workers=1) as svc:
        h = svc.submit(_two_stage(n, stage1=_slow_plus_one), timeout=120)
        hl = svc.host_loader
        deadline = time.monotonic() + 30
        while hl.stats.items_total < 5:
            assert time.monotonic() < deadline
            time.sleep(0.005)
        svc.kill_node("node2")
        assert h.result() == sorted(2 * i + 1 for i in range(n))
        assert hl.stats.deaths_detected == 1
        st = h.stats()
        assert st["items_collected"] == n
        assert st["host_relay_bytes"] == 0
    assert svc.orphaned() == []


def test_broadcast_block_readable_in_work_fn_and_peer_fetched():
    """publish_block before submit: every node assembles the block (host
    stripe + peer trades), and the work function reads it by name."""
    data = bytes(range(256)) * 64  # 16 KiB, still multi-node relevant
    n = 12

    def scaled(x):
        blob = peer.get_block("peer-test-weights", timeout=30.0)
        return x * len(blob)

    with _service() as svc:
        digest = svc.publish_block("peer-test-weights", data)
        assert digest == peer.block_digest(data)
        spec = _two_stage(n, stage1=scaled)
        h = svc.submit(spec, timeout=60)
        assert h.result() == sorted(2 * i * len(data) for i in range(n))
        # The stripe fetches run concurrently with the job and their REPORT
        # can land a beat after result() — poll briefly for the counters.
        deadline = time.monotonic() + 5.0
        fetched = 0
        while time.monotonic() < deadline:
            snap = svc.metrics_snapshot()
            reports = [v.get("report") or {} for v in snap["nodes"].values()]
            fetched = sum(r.get("blocks_fetched_from_peers", 0) +
                          r.get("blocks_fetched_from_host", 0)
                          for r in reports)
            if fetched >= svc.nodes:
                break
            time.sleep(0.02)
        # every node had to pull the block over the wire
        assert fetched >= 1
    assert svc.orphaned() == []


def test_report_frames_keep_gauges_fresh_without_heartbeat():
    """Satellite invariant: node gauges ride dedicated REPORT frames pushed
    on result activity, so with a glacial heartbeat the host still sees
    fresh per-node peer counters right after a job completes."""
    n = 20
    with _service(heartbeat_interval=30.0, heartbeat_misses=4) as svc:
        h = svc.submit(_two_stage(n), timeout=60)
        assert h.result() == sorted(2 * i + 1 for i in range(n))
        deadline = time.monotonic() + 2.0  # << one 30s heartbeat
        while time.monotonic() < deadline:
            snap = svc.metrics_snapshot()
            reports = [v.get("report") or {}
                       for v in snap["nodes"].values()]
            if sum(r.get("peer_items_sent", 0) for r in reports) >= n:
                break
            time.sleep(0.02)
        else:
            pytest.fail("peer gauges never arrived ahead of the heartbeat")
    assert svc.orphaned() == []
