"""Pure-jnp oracle for the flash-attention kernel: full-materialisation
causal (optionally sliding-window) softmax attention, f32 accumulation."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, H, Skv, D]
    v: jax.Array,  # [B, H, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    D = q.shape[-1]
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    Sq, Skv = q.shape[2], k.shape[2]
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
