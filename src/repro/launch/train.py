"""Training launcher: deploy a ClusterBuilder training application.

Examples::

    # CPU-sized run (reduced config), with checkpointing + fault tolerance:
    python -m repro.launch.train --arch yi-9b --smoke --steps 50

    # Inject a crash at step 20 and watch the restore path:
    python -m repro.launch.train --arch yi-9b --smoke --steps 40 --crash-at 20

    # Print the generated deployment plan (HNL/NL bootstrap of paper fig. 1):
    python -m repro.launch.train --arch yi-9b --plan
"""

from __future__ import annotations

import argparse
import logging

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, get_shape
from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails
from repro.optim.adamw import AdamWConfig
from repro.runtime.executor import Trainer, TrainerConfig
from repro.runtime.failures import FailureEvent, FailurePlan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--plan", action="store_true",
                    help="print the deployment plan and exit")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))

    if args.plan:
        spec = ClusterSpec.simple(
            host="192.168.1.176", nclusters=16, workers_per_node=16,
            emit_details=EmitDetails(name="data", create=lambda s: (None, s)),
            work_function=lambda x: x,
            result_details=ResultDetails(name="metrics", collect=lambda a, x: a),
        )
        print(ClusterBuilder().deployment_plan(spec).describe())
        return

    if args.smoke:
        shape = ShapeConfig("smoke", seq_len=args.seq,
                            global_batch=args.batch, kind="train")
    else:
        shape = get_shape(args.shape)

    plan = FailurePlan(
        [FailureEvent(step=args.crash_at, kind="crash")]
        if args.crash_at >= 0 else []
    )
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(
            num_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            peak_lr=args.lr,
            warmup_steps=max(args.steps // 10, 1),
        ),
        opt_cfg=AdamWConfig(),
        failure_plan=plan,
    )
    out = trainer.run()
    print("=== training finished ===")
    print(f"final step: {out['final_step']}  restarts: {out['restarts']}")
    for k, v in out["last_metrics"].items():
        print(f"  {k}: {v:.6g}")
    print(out["timing"])


if __name__ == "__main__":
    main()
