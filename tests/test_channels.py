"""Sharding-rule derivation: divisibility, exclusivity, fallbacks, padding.

Includes hypothesis property tests — the derivation must be *total* and
*sound* for any shape (this is requirement 4: the builder, not the user,
wires the network, so it must never produce an invalid spec)."""

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.channels import (
    Channel,
    ShardingRules,
    decode_rules,
    long_context_rules,
    padded_size,
    training_rules,
)
from repro.launch.mesh import make_smoke_mesh


@pytest.fixture(scope="module")
def mesh():
    # 1 CPU device: logical mesh (1, 1); rule derivation is pure math over
    # axis *sizes*, so we also exercise a fake 16x16 axis table directly.
    return make_smoke_mesh(1, 1)


class FakeRules(ShardingRules):
    """ShardingRules over a synthetic axis-size table (no real devices)."""

    def __init__(self, axis_sizes, rules):
        self.mesh = None
        self.axis_sizes = dict(axis_sizes)
        self.rules = []
        for name, axes in rules:
            if axes is None:
                self.rules.append((name, None))
            else:
                kept = tuple(a for a in axes if a in self.axis_sizes)
                self.rules.append((name, kept if kept else None))


RULES_16x16 = [
    ("batch", ("pod", "data")),
    ("batch", ("data",)),
    ("seq_sp", ("model",)),
    ("vocab", ("model",)),
    ("d_ff", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("kv_seq", ("model",)),
    ("d_model_fsdp", ("pod", "data")),
    ("d_model_fsdp", ("data",)),
]


def fake(pod=None):
    sizes = {"data": 16, "model": 16}
    if pod:
        sizes["pod"] = pod
    return FakeRules(sizes, RULES_16x16)


def test_divisible_dims_get_sharded():
    r = fake(pod=2)
    spec = r.partition_spec((256, 4096, 4096), ("batch", "seq", "d_model"))
    assert spec == P(("pod", "data"))
    spec = r.partition_spec((4096, 22528), ("d_model_fsdp", "d_ff"))
    assert spec == P(("pod", "data"), "model")


def test_indivisible_falls_back():
    r = fake()
    # 10 heads don't divide 16 -> replicate (batch 32 shards over data)
    assert r.partition_spec((32, 1, 10, 256), ("batch", "seq", "heads", "head_dim")) \
        == P("data")
    # batch=1 (long_500k) unshardable -> fully replicated
    assert r.partition_spec((1, 128), ("batch", "seq")) == P()


def test_exclusivity_kv_fallback_to_seq():
    """kv_heads=8 can't take the 16-way model axis -> kv_seq takes it
    (FlashDecoding split), exactly one of them."""
    r = fake()
    spec = r.partition_spec(
        (128, 8, 32768, 128), ("batch", "kv_heads", "kv_seq", "head_dim")
    )
    assert spec == P("data", None, "model")
    # kv_heads=16 divides: it wins and kv_seq stays unsharded
    spec = r.partition_spec(
        (128, 16, 32768, 128), ("batch", "kv_heads", "kv_seq", "head_dim")
    )
    assert spec == P("data", "model")


def test_missing_pod_axis_degrades():
    r = fake(pod=None)
    assert r.partition_spec((256, 16), ("batch", "seq")) == P("data")


@given(
    shape=st.lists(st.integers(1, 4096), min_size=1, max_size=5),
    names=st.lists(
        st.sampled_from(
            ["batch", "seq", "d_model", "d_ff", "heads", "kv_heads",
             "kv_seq", "vocab", "d_model_fsdp", None]
        ),
        min_size=1, max_size=5,
    ),
    pod=st.sampled_from([None, 2, 4]),
)
@settings(max_examples=200, deadline=None)
def test_derivation_total_and_sound(shape, names, pod):
    """For ANY shape x axis-name combination the derivation must produce a
    valid PartitionSpec: every sharded dim divisible, no mesh axis reused."""
    n = min(len(shape), len(names))
    shape, names = tuple(shape[:n]), tuple(names[:n])
    r = fake(pod=pod)
    spec = r.partition_spec(shape, names)
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (n - len(spec))):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
            prod *= r.axis_sizes[a]
        assert dim % prod == 0, f"dim {dim} not divisible by {prod} in {spec}"


@given(n=st.integers(1, 10**7), m=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_padded_size_properties(n, m):
    p = padded_size(n, m)
    assert p >= n
    assert p % m == 0
    assert p - n < m


def test_real_mesh_struct_roundtrip(mesh):
    rules = training_rules(mesh)
    ch = Channel("tokens", (8, 128), jax.numpy.int32, ("batch", "seq"))
    struct = rules.struct(ch)
    assert struct.shape == (8, 128)
    assert struct.sharding is not None


def test_preset_rules_exist(mesh):
    for r in (training_rules(mesh), decode_rules(mesh), long_context_rules(mesh)):
        assert r.partition_spec((4, 4), ("batch", "seq")) is not None
