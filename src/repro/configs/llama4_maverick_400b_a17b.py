"""llama4-maverick-400b-a17b [moe] — MoE 128 experts top-1 + shared expert,
early fusion (hf:meta-llama/Llama-4 family; unverified).  48L d_model=5120
40H (GQA kv=8) d_ff=8192 vocab=202048.  Maverick interleaves dense and
MoE layers (1:1), which with 128 routed experts lands at the nominal ~400B
total / ~17B active.  Head plan: 40 q heads / g=5 breaks
16-way grouping padding, so attention uses the expanded-KV path (Hp=48)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    layer_pattern=("attn", "moe"),
    num_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    rope_theta=500000.0,
)
