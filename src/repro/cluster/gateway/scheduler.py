"""Weighted-fair admission over tenants (deficit round robin + aging).

The warm service's dispatcher schedules *admitted* jobs strictly
priority-then-FIFO — fine for one user, starvation for many: a wide
high-priority job drains every node credit first.  The gateway therefore
meters **admission**: queued tickets enter the pool in an order decided
here, per tenant, and raw submit priority only ranks tickets *within* a
tenant (cross-tenant ordering is the weights' job).

The mechanism is deficit round robin.  Every eligible tenant accrues
credit in proportion to its weight; admitting one job costs one credit;
the tenant with the most accumulated credit goes next (ties break to the
least-recently-served, so equal weights alternate).  Credit is clamped at
``max(1, weight)`` and reset when a tenant's queue empties, so an idle
tenant cannot bank a burst.  Starvation-proofing *within* a tenant is
aging: a ticket's effective priority is ``priority + age/aging_s``, so any
queued ticket eventually outranks fresher high-priority ones.

``mode="fifo"`` keeps the whole structure but admits strictly
priority-then-FIFO across all tenants — the PR 6 behaviour, kept as the
benchmark baseline (``benchmarks/run.py gateway_fairness`` reports both).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

__all__ = ["TenantPolicy", "QueueEntry", "FairScheduler"]


@dataclass
class TenantPolicy:
    """Per-tenant shares and caps, keyed by tenant name in the gateway.

    * ``weight`` — DRR share; a weight-2 tenant is admitted twice per
      weight-1 admission when both have work;
    * ``max_active_jobs`` — concurrently *admitted* jobs (None = only the
      gateway-wide cap applies);
    * ``max_inflight`` — item-level credit cap enforced inside
      ``host_loader._answer``: the tenant's jobs together may hold at most
      this many host-dispatched items in flight, so one wide job cannot
      monopolise node credits (None = uncapped).
    """

    weight: float = 1.0
    max_active_jobs: int | None = None
    max_inflight: int | None = None

    def validate(self) -> None:
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.max_active_jobs is not None and self.max_active_jobs < 0:
            raise ValueError("max_active_jobs must be >= 0")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")


@dataclass
class QueueEntry:
    """One queued ticket as the scheduler sees it."""

    ticket: str
    tenant: str
    priority: int
    submitted_at: float  # epoch seconds (matches the store rows)
    timeout: float | None = None
    retries: int = 0
    spec: Any = None  # the live object when enqueued this process
    seq: int = 0  # FIFO tiebreak, assigned by push()

    def deadline(self) -> float | None:
        if self.timeout is None:
            return None
        return self.submitted_at + self.timeout


@dataclass
class _TenantQueue:
    entries: list = field(default_factory=list)
    deficit: float = 0.0
    served: int = 0


class FairScheduler:
    """In-memory admission queue (see module docstring).

    Not thread-safe by itself — the owning gateway serializes access
    under its lock.  Pure data structure: no clocks of its own (callers
    pass ``now``), no threads, so it unit-tests deterministically.
    """

    def __init__(self, policies: dict[str, TenantPolicy] | None = None, *,
                 default: TenantPolicy | None = None, mode: str = "fair",
                 aging_s: float = 30.0):
        if mode not in ("fair", "fifo"):
            raise ValueError(f"mode must be 'fair' or 'fifo', got {mode!r}")
        if aging_s <= 0:
            raise ValueError(f"aging_s must be > 0, got {aging_s}")
        self.mode = mode
        self.aging_s = aging_s
        self.default = default or TenantPolicy()
        self.default.validate()
        self.policies = dict(policies or {})
        for pol in self.policies.values():
            pol.validate()
        self._queues: dict[str, _TenantQueue] = {}
        self._seq = 0
        self._pops = 0  # global serve counter (least-recently-served ties)

    def policy(self, tenant: str) -> TenantPolicy:
        return self.policies.get(tenant, self.default)

    # -- queue maintenance ---------------------------------------------------

    def push(self, entry: QueueEntry) -> None:
        self._seq += 1
        entry.seq = self._seq
        self._queues.setdefault(entry.tenant, _TenantQueue()) \
            .entries.append(entry)

    def remove(self, ticket: str) -> QueueEntry | None:
        for tq in self._queues.values():
            for i, entry in enumerate(tq.entries):
                if entry.ticket == ticket:
                    del tq.entries[i]
                    if not tq.entries:
                        tq.deficit = 0.0
                    return entry
        return None

    def drop_expired(self, now: float | None = None) -> list[QueueEntry]:
        """Remove every queued entry whose submit timeout elapsed while it
        waited — the fix for ``submit(timeout=)`` on a still-queued job:
        it must leave the queue (and report ``cancelled``), not hold a
        scheduler slot forever."""
        now = time.time() if now is None else now
        expired = []
        for tq in self._queues.values():
            keep = []
            for entry in tq.entries:
                deadline = entry.deadline()
                if deadline is not None and now >= deadline:
                    expired.append(entry)
                else:
                    keep.append(entry)
            tq.entries = keep
            if not keep:
                tq.deficit = 0.0
        return expired

    # -- admission -----------------------------------------------------------

    def _effective_priority(self, entry: QueueEntry, now: float) -> float:
        return entry.priority + max(0.0, now - entry.submitted_at) / self.aging_s

    def _pop_best(self, tenant: str, now: float) -> QueueEntry:
        tq = self._queues[tenant]
        best = max(range(len(tq.entries)), key=lambda i: (
            self._effective_priority(tq.entries[i], now),
            -tq.entries[i].seq,
        ))
        entry = tq.entries.pop(best)
        self._pops += 1
        tq.served = self._pops
        if not tq.entries:
            tq.deficit = 0.0
        return entry

    def pop_next(self, active_by_tenant: dict[str, int] | None = None,
                 now: float | None = None) -> QueueEntry | None:
        """The next ticket to admit, or None when everything queued is
        blocked by a per-tenant ``max_active_jobs`` cap (or empty).
        ``active_by_tenant`` is the gateway's live count of admitted jobs
        per tenant."""
        now = time.time() if now is None else now
        active = active_by_tenant or {}

        def capped(tenant: str) -> bool:
            cap = self.policy(tenant).max_active_jobs
            return cap is not None and active.get(tenant, 0) >= cap

        eligible = [t for t, tq in self._queues.items()
                    if tq.entries and not capped(t)]
        if not eligible:
            return None
        if self.mode == "fifo":
            # The baseline: strict priority then FIFO across ALL tenants.
            best = max(
                eligible,
                key=lambda t: max(
                    (self._effective_priority(e, now), -e.seq)
                    for e in self._queues[t].entries
                ),
            )
            return self._pop_best(best, now)
        # DRR: everyone eligible accrues weight until someone can afford
        # an admission, then the richest (ties: least recently served,
        # then name for determinism) pays one credit and goes.
        while all(self._queues[t].deficit < 1.0 for t in eligible):
            for t in eligible:
                tq = self._queues[t]
                w = self.policy(t).weight
                tq.deficit = min(tq.deficit + w, max(1.0, w))
        winner = max(eligible, key=lambda t: (
            self._queues[t].deficit, -self._queues[t].served, t))
        self._queues[winner].deficit -= 1.0
        return self._pop_best(winner, now)

    # -- introspection -------------------------------------------------------

    def depth(self) -> int:
        return sum(len(tq.entries) for tq in self._queues.values())

    def depth_by_tenant(self) -> dict[str, int]:
        return {t: len(tq.entries) for t, tq in self._queues.items()
                if tq.entries}

    def oldest_wait(self, now: float | None = None) -> float:
        """Seconds the longest-queued ticket has waited (0 when empty) —
        the autoscaler's primary scale-up signal."""
        now = time.time() if now is None else now
        oldest = min(
            (e.submitted_at for tq in self._queues.values()
             for e in tq.entries),
            default=None,
        )
        return 0.0 if oldest is None else max(0.0, now - oldest)
