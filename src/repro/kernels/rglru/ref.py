"""Pure-jnp oracle for the RG-LRU scan kernel: the plain sequential
recurrence h_t = a_t * h_{t-1} + b_t, returning all h and the final state."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_reference(a: jax.Array, b: jax.Array, h0=None):
    """a, b: [B, S, W] f32 -> (h [B, S, W], h_last [B, W])."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, t):
        h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
        return h, h

    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), h_last
