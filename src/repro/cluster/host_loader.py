"""The Host-Node-Loader (HNL): paper §4 / Figure 1, over real sockets.

Bootstrap sequence (the load network):

1. HNL listens on the configurable "port 2000" and waits for one REGISTER
   frame per expected node (many-to-one input channel — input end created
   before any output end exists, §4's ordering rule).
2. As *each* node registers, the HNL immediately sends it the serialized
   deployment on a LOAD frame — the JCSP *code-loading channel* analogue
   (§4.1).  Early registrants therefore deserialize code and pull in heavy
   imports while stragglers are still connecting, instead of the whole
   cluster idling until the last REGISTER.
3. The application network then runs the demand-driven onrl/nrfa
   client-server protocol model-checked in ``core.verify``, pipelined:
   a WORK_REQUEST carries a *credit count* and the host answers with up to
   that many items in one WORK_BATCH frame; each RESULT_BATCH a node sends
   both delivers results and (piggybacked ``credits``) re-requests that
   many replacement items.  The CSP obligation is unchanged — every demand
   is answered in finite time with items or, once the node's input stream
   is exhausted and nothing is in flight, with UT — the window is just
   wider than one.
4. On UT each node returns its (boot_ms, load_ms, run_ms, items) timing
   record (requirement 7) and the HNL folds results via the user's
   ResultDetails.

Multi-stage routing (``PipelineSpec``): every node belongs to one stage;
the host keeps *per-stage* pending/in-flight/dedup state and answers a
node's credits only from its own stage's queue.  A RESULT_BATCH from a
stage-*s* node is deduplicated and its values re-enter the host as fresh
WORK items of stage *s+1* (the final stage folds into the collector) — the
host is the rendezvous between hops, exactly as the chained CSP model has
reducer *s* feeding server *s+1*.  Stage *s*'s input is exhausted once the
emit stream (s = 0) or stage *s-1* (s > 0) has fully drained, at which
point parked credits of stage-*s* nodes are answered with UT.  Exactly-once
holds per stage: result-id dedup before forwarding means a redispatched
zombie's duplicate can neither double-collect nor double-forward.

Beyond the paper: heartbeat liveness (``membership``) — a node-loader that
dies mid-job is detected by missed beats, its in-flight items re-queued and
re-dispatched to surviving nodes (their parked credits answered first), with
result-id dedup guaranteeing no item is lost or double-collected.

Single-threaded protocol core: per-connection reader threads and a ticker
only *enqueue* events; one dispatcher consumes them.  That makes the state
machine deterministic and trivially deadlock-free (no locks around protocol
state).
"""

from __future__ import annotations

import collections
import queue
import socket
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

from repro.cluster.deploy.base import PlacementPolicy
from repro.cluster.membership import LAUNCHING, Membership, NodeRecord
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    LOAD_WIRE_CHANNEL,
    Frame,
    FrameConnection,
    FrameType,
)
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor, WorkFunctionError

__all__ = ["HostLoader", "HostStats", "WorkFunctionError"]


@dataclass
class HostStats:
    items_total: int = 0
    duplicates_dropped: int = 0
    redispatched: int = 0
    deaths_detected: int = 0
    forwarded: int = 0  # stage-s results re-entered as stage-s+1 work items
    # Data-plane counters (credit pipeline).
    work_requests: int = 0  # explicit WORK_REQUEST frames received
    work_batches: int = 0  # WORK_BATCH frames sent
    result_batches: int = 0  # RESULT/RESULT_BATCH frames received
    max_batch: int = 0  # largest WORK_BATCH dispatched
    # Placement-policy counters (deployment layer).
    respawns: int = 0  # silent launches relaunched elsewhere
    late_joins: int = 0  # nodes admitted after the run started
    degraded_start: bool = False  # job admitted below full strength


class HostLoader:
    """Runs the host side of one emit/cluster/collect deployment."""

    def __init__(
        self,
        spec,
        timing: TimingCollector | None = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat: HeartbeatMonitor | None = None,
        register_timeout: float = 30.0,
        job_timeout: float | None = None,
        slowdown: dict[str, float] | None = None,
        artifacts: dict[str, bytes] | None = None,
        prefetch: int | None = None,
        flush_items: int = 8,
        flush_interval: float = 0.005,
        placement: PlacementPolicy | None = None,
        expected_nodes: Sequence[str] | None = None,
        relaunch: Callable[[str, str], bool] | None = None,
    ):
        if hasattr(spec, "as_pipeline"):
            spec = spec.as_pipeline()
        spec.validate()
        self.spec = spec
        self.stages = spec.stages
        # node_id -> stage index; respawn replacements resolve via base id.
        self._stage_by_node = dict(spec.node_assignments())
        self.timing = timing or TimingCollector()
        self.host = host
        self.membership = Membership(heartbeat or HeartbeatMonitor())
        self.register_timeout = register_timeout
        self.placement = placement or PlacementPolicy()
        self.placement.validate(spec.total_nodes)
        # Launch announcements: expected node ids become LAUNCHING records
        # at start(), which is what arms respawn tracking and late join.
        self.expected_nodes = list(expected_nodes or [])
        # Deployment-layer callback: relaunch(old_node_id, new_node_id) ->
        # bool, provided by the application so the barrier can respawn a
        # silent launch without knowing what a launcher is.
        self.relaunch = relaunch
        self.job_timeout = job_timeout
        self.slowdown = dict(slowdown or {})
        self.artifacts = dict(artifacts or {})
        self.prefetch = prefetch
        self.flush_items = flush_items
        self.flush_interval = flush_interval
        self.stats = HostStats()
        self.result: Any = None

        self._events: queue.Queue = queue.Queue()
        self._early_events: list = []  # app frames arriving mid-bootstrap
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(spec.total_nodes + 4)
        self.port = self._listener.getsockname()[1]
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    # -- bootstrap ----------------------------------------------------------

    def start(self) -> None:
        """Open the load network (accept + ticker threads)."""
        for node_id in self.expected_nodes:
            self.membership.expect(node_id)
        for fn, name in ((self._accept_loop, "hnl-accept"),
                         (self._tick_loop, "hnl-ticker")):
            t = threading.Thread(target=fn, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            t = threading.Thread(
                target=self._conn_reader, args=(conn, f"{addr[0]}:{addr[1]}"),
                name=f"hnl-reader-{addr[1]}", daemon=True,
            )
            t.start()
            self._threads.append(t)

    def _conn_reader(self, conn: FrameConnection, addr: str) -> None:
        node_id = None
        try:
            first = conn.recv()
            if first.ftype is not FrameType.REGISTER:
                conn.close()
                return
            node_id = first.payload["node_id"]
            self._events.put(("register", node_id, addr, conn, first.payload))
            while True:
                frame = conn.recv()
                self._events.put(("frame", node_id, frame))
        except (ConnectionError, OSError, ValueError):
            if node_id is not None:
                self._events.put(("disconnect", node_id))

    def _tick_loop(self) -> None:
        interval = self.membership.monitor.interval_s / 2
        while not self._stop.wait(interval):
            self._events.put(("tick",))

    # -- the dispatcher -----------------------------------------------------

    def run(self) -> Any:
        """Bootstrap, run the farm to termination, return the final result."""
        spec = self.spec
        deadline = (
            time.monotonic() + self.job_timeout if self.job_timeout else None
        )

        with self.timing.phase("host", "load"):
            self._await_registrations()
        # Demand that raced the bootstrap (an early node finishing its LOAD
        # while stragglers registered) re-enters the event stream here.
        for ev in self._early_events:
            self._events.put(ev)
        self._early_events.clear()

        S = len(self.stages)
        details = spec.emit.e_details
        emit_state = details.initial_state()
        emit_done = False
        # Per-stage farm state.  Item ids are per-stage (a stage-s result
        # forwarded to stage s+1 gets a fresh id in s+1's id space), so
        # dedup and loss accounting stay local to one hop.
        next_id = [0] * S
        pending: list[collections.deque] = [collections.deque()
                                            for _ in range(S)]
        inflight: list[dict[int, tuple[str, Any]]] = [{} for _ in range(S)]
        done_ids: list[set[int]] = [set() for _ in range(S)]
        r_details = spec.collector.r_details
        acc = r_details.init()

        def input_exhausted(s: int) -> bool:
            """Stage ``s`` will receive no further input items."""
            if s == 0:
                return emit_done
            return (input_exhausted(s - 1) and not pending[s - 1]
                    and not inflight[s - 1])

        def stage_done(s: int) -> bool:
            return input_exhausted(s) and not pending[s] and not inflight[s]

        def next_item(s: int):
            nonlocal emit_state, emit_done
            if pending[s]:
                return pending[s].popleft()
            if s == 0 and not emit_done:
                obj, emit_state = details.create(emit_state)
                if obj is None:
                    emit_done = True
                    return None
                item = (next_id[0], obj)
                next_id[0] += 1
                return item
            return None  # upstream hasn't produced (or is exhausted)

        def send_batch(rec: NodeRecord, batch: list, s: int) -> bool:
            try:
                rec.conn.send(Frame(
                    FrameType.WORK_BATCH,
                    {"items": [{"id": i, "obj": o} for i, o in batch]},
                    APP_WIRE_CHANNEL,
                ))
            except OSError:
                # Never lose an item on a dead pipe: all of them go back to
                # the front of the queue; the node itself is reaped shortly.
                # Encode errors (ValueError: unencodable/oversized payload)
                # are a *user payload* problem, not a node death — requeueing
                # would loop forever, so they propagate and fail the job.
                for item in reversed(batch):
                    pending[s].appendleft(item)
                return False
            for item_id, obj in batch:
                inflight[s][item_id] = (rec.node_id, obj)
            self.stats.work_batches += 1
            self.stats.max_batch = max(self.stats.max_batch, len(batch))
            return True

        def send_ut(node_id: str) -> None:
            rec = self.membership.nodes[node_id]
            try:
                rec.conn.send(Frame(FrameType.UT, None, APP_WIRE_CHANNEL))
            except (OSError, ValueError):
                pass

        def answer(node_id: str, credits: int) -> None:
            """Answer demand (the onrl server obligation), up to ``credits``
            + any previously parked credits, in one WORK_BATCH drawn from the
            node's own stage queue."""
            rec = self.membership.nodes.get(node_id)
            if rec is None or not rec.alive:
                return
            s = self._stage_of(node_id)
            want = credits + rec.credits
            rec.credits = 0
            if want <= 0:
                return
            batch = []
            while len(batch) < want:
                item = next_item(s)
                if item is None:
                    break
                batch.append(item)
            if batch and not send_batch(rec, batch, s):
                return  # dead pipe: items requeued, node about to be reaped
            leftover = want - len(batch)
            if leftover:
                if stage_done(s):
                    send_ut(node_id)
                else:
                    rec.credits = leftover  # parked until items (re)appear

        def flush_waiting() -> None:
            for rec in list(self.membership.nodes.values()):
                if rec.alive and rec.credits > 0:
                    answer(rec.node_id, 0)

        def items_collected() -> int:
            return len(done_ids[S - 1])

        def reap(now: float | None = None) -> None:
            newly_dead = self.membership.reap(now, at_item=items_collected())
            for rec in newly_dead:
                self.stats.deaths_detected += 1
                s = self._stage_of(rec.node_id)
                lost = [iid for iid, (nid, _) in inflight[s].items()
                        if nid == rec.node_id]
                for iid in lost:
                    _, obj = inflight[s].pop(iid)
                    pending[s].append((iid, obj))
                    self.stats.redispatched += 1
            if newly_dead:
                flush_waiting()

        def collect_results(node_id: str, results: list, credits: int) -> None:
            nonlocal acc
            self.stats.result_batches += 1
            s = self._stage_of(node_id)
            for p in results:
                if "error" in p:
                    raise WorkFunctionError(
                        f"work function raised on {node_id} for item "
                        f"{p['id']}: {p['error']}\n"
                        f"{p.get('traceback', '')}"
                    )
                # Always clear inflight — a redispatched item can complete
                # twice (zombie result + survivor result) and both entries
                # must go or termination stalls.
                inflight[s].pop(p["id"], None)
                if p["id"] in done_ids[s]:
                    self.stats.duplicates_dropped += 1
                else:
                    done_ids[s].add(p["id"])
                    if s + 1 < S:
                        # The hop rendezvous: this result *is* stage s+1's
                        # next work item (dedup above makes it exactly once).
                        pending[s + 1].append((next_id[s + 1], p["value"]))
                        next_id[s + 1] += 1
                        self.stats.forwarded += 1
                    else:
                        acc = r_details.collect(acc, p["value"])
                        self.stats.items_total += 1
                    rec = self.membership.nodes[node_id]
                    rec.items_done += 1
                    self.timing.count_item(node_id)
            if credits:
                answer(node_id, credits)
            # Forwarded items may satisfy parked downstream demand, and a
            # stage draining may owe its nodes UT: both are answered here.
            flush_waiting()

        def check_liveness() -> None:
            """A stage with obligations left but no live nodes can never
            finish — fail fast instead of idling to job_timeout.  LAUNCHING
            members keep a stage eligible: a degraded start's straggler (or
            a respawned launch) may still register and carry the stage."""
            for s in range(S):
                if stage_done(s):
                    continue
                members = [rec for rec in self.membership.nodes.values()
                           if self._stage_of(rec.node_id) == s]
                if any(rec.alive or rec.state == LAUNCHING
                       for rec in members):
                    continue
                raise RuntimeError(
                    f"all node-loaders of stage {self.stages[s].name!r} "
                    f"died with work outstanding ({len(inflight[s])} "
                    f"in flight, {len(pending[s])} queued; no launch "
                    "pending)"
                )

        with self.timing.phase("host", "run"):
            while True:
                if stage_done(S - 1) and self.membership.finished():
                    break
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"cluster job exceeded {self.job_timeout}s "
                        f"(done={items_collected()}, "
                        f"inflight={[len(f) for f in inflight]}, "
                        f"membership:\n{self.membership.describe()})"
                    )
                try:
                    event = self._events.get(
                        timeout=self.membership.monitor.interval_s
                    )
                except queue.Empty:
                    continue
                kind = event[0]
                if kind == "frame":
                    _, node_id, frame = event
                    if frame.ftype is FrameType.WORK_REQUEST:
                        self.stats.work_requests += 1
                        p = frame.payload or {}
                        answer(node_id, int(p.get("credits", 1)))
                    elif frame.ftype is FrameType.RESULT_BATCH:
                        p = frame.payload
                        collect_results(
                            node_id, p["results"], int(p.get("credits", 0))
                        )
                    elif frame.ftype is FrameType.RESULT:
                        # Legacy single-result form (one frame per item).
                        collect_results(node_id, [frame.payload], 0)
                    elif frame.ftype is FrameType.HEARTBEAT:
                        self.membership.beat(node_id)
                    elif frame.ftype is FrameType.UT:
                        self._node_finished(node_id, frame.payload)
                elif kind == "loaded":
                    # A straggler's LOAD send completing after bootstrap.
                    self._apply_load_result(event[1], event[2])
                elif kind == "tick":
                    reap()
                elif kind == "disconnect":
                    # The socket died; death itself is declared by the
                    # heartbeat threshold (reap), keeping one detection path.
                    pass
                elif kind == "register":
                    # Late join: a node registering after the run started is
                    # shipped LOAD immediately (the per-registration LOAD
                    # path always supported this — the membership barrier
                    # was what blocked it) and its first WORK_REQUEST is
                    # answered with items or, if the stream already drained,
                    # with UT.  Exactly-once is untouched: result-id dedup
                    # never depended on when a node joined.
                    _, node_id, addr, conn, payload = event
                    if not self.placement.allow_late_join:
                        conn.close()
                        continue
                    try:
                        rec = self.membership.register(
                            node_id, addr,
                            cores=int(payload.get("cores", 1)),
                            pid=int(payload.get("pid", 0)),
                            conn=conn,
                        )
                    except ValueError:
                        conn.close()  # duplicate of a live member
                        continue
                    self.stats.late_joins += 1
                    self._send_load(rec)
                check_liveness()

        self._collect_wire_stats()
        self.result = r_details.finalise(acc)
        return self.result

    def _stage_of(self, node_id: str) -> int:
        """Stage index of a node (respawn replacements via their base id;
        unknown elastic joiners default to stage 0)."""
        s = self._stage_by_node.get(node_id)
        if s is not None:
            return s
        base = node_id.split("r", 1)[0]
        return self._stage_by_node.get(base, 0)

    # -- bootstrap helpers --------------------------------------------------

    def _await_registrations(self) -> None:
        """The membership barrier, driven by the placement policy.

        Strict mode (the default policy) reproduces the seed behaviour:
        block until all ``nclusters`` launches registered or raise at
        ``register_timeout``.  The policy relaxes it three ways:

        * *respawn-on-silent-node* — an announced launch quiet past its
          ``respawn_after`` window is retired (REPLACED) and relaunched
          elsewhere through the deployment layer's ``relaunch`` callback,
          up to ``max_respawns`` times cluster-wide;
        * *degraded start* — at the timeout the job is admitted with the
          survivors if at least ``min_nodes`` arrived, instead of raising;
          the missing stragglers stay LAUNCHING and may still late-join;
        * a launch arriving *during* the barrier under a REPLACED id is
          re-admitted (membership handles the transition) — first
          registration wins, extra capacity is never turned away.
        """
        pol = self.placement
        expected = self.spec.total_nodes
        min_nodes = expected if pol.min_nodes is None else pol.min_nodes
        respawn_after = pol.respawn_after
        if respawn_after is None:
            respawn_after = self.register_timeout / (pol.max_respawns + 1)
        respawns_left = pol.max_respawns
        t0 = time.monotonic()
        deadline = t0 + self.register_timeout
        # The silence clock starts *now*: launch announcements were stamped
        # at start(), before the launcher's prepare() (possibly a slow code
        # sync to many machines) and the sequential launch() calls — judging
        # silence from that stamp would respawn healthy just-launched nodes.
        for rec in self.membership.launching_nodes():
            rec.launched_at = t0
        while self.membership.arrived_count() < expected:
            now = time.monotonic()
            next_respawn_due: float | None = None
            if self.relaunch is not None and respawns_left > 0:
                for rec in self.membership.launching_nodes():
                    if respawns_left <= 0:
                        break
                    due = rec.launched_at + respawn_after
                    if now >= due:
                        if self._respawn(rec):
                            respawns_left -= 1
                    elif next_respawn_due is None or due < next_respawn_due:
                        next_respawn_due = due
            if now >= deadline:
                arrived = self.membership.arrived_count()
                if arrived >= min_nodes:
                    # Degraded start: the survivors carry the job; the
                    # demand-driven protocol needs no topology change.
                    self.stats.degraded_start = arrived < expected
                    return
                raise TimeoutError(
                    f"only {arrived}/{expected} node-loaders registered "
                    f"within {self.register_timeout}s (min_nodes="
                    f"{min_nodes}, respawns used="
                    f"{pol.max_respawns - respawns_left})"
                )
            timeout = deadline - now
            if next_respawn_due is not None:
                timeout = min(timeout, next_respawn_due - now)
            try:
                event = self._events.get(timeout=max(0.01, timeout))
            except queue.Empty:
                continue
            if event[0] == "loaded":
                self._apply_load_result(event[1], event[2])
                continue
            if event[0] == "frame":
                # Early heartbeats (nodes beat from REGISTER onwards) must
                # count, or a node registering early could be declared dead
                # while the stragglers are still connecting.  Other early
                # frames (a loaded node's first WORK_REQUEST) are replayed
                # into the dispatcher once bootstrap completes.
                _, node_id, frame = event
                if frame.ftype is FrameType.HEARTBEAT:
                    self.membership.beat(node_id)
                else:
                    self._early_events.append(event)
                continue
            if event[0] != "register":
                continue  # pre-bootstrap noise
            _, node_id, addr, conn, payload = event
            try:
                rec = self.membership.register(
                    node_id, addr,
                    cores=int(payload.get("cores", 1)),
                    pid=int(payload.get("pid", 0)),
                    conn=conn,
                )
            except ValueError:
                conn.close()  # duplicate node_id: reject it, keep waiting
                continue
            # Overlapped load: ship code the moment a node shows up, so its
            # deserialization/imports run while stragglers still register.
            self._send_load(rec)

    def _respawn(self, rec: NodeRecord) -> bool:
        """Retire a silent launch and start a replacement elsewhere."""
        new_id = f"{rec.node_id}r{rec.attempts + 1}"
        try:
            ok = self.relaunch(rec.node_id, new_id)
        except Exception:
            ok = False
        if not ok:
            # Could not place a replacement: re-arm the silence window so
            # the original keeps its chance instead of burning the budget
            # in a tight loop.
            rec.launched_at = time.monotonic()
            return False
        self.membership.replace(rec.node_id)
        nrec = self.membership.expect(new_id)
        nrec.attempts = rec.attempts + 1
        self.stats.respawns += 1
        return True

    def _send_load(self, rec: NodeRecord) -> None:
        """Ship the deployment to one node from a dedicated sender thread.

        A node booting heavy deps drains its socket only once its preloader
        finishes; a large LOAD (MBs of artifacts) would therefore block a
        synchronous send past the kernel buffer — and block the dispatcher
        with it, re-serializing the very bootstrap the overlap parallelizes.
        The sender thread reports back through the event queue
        (``("loaded", node_id, ok)``) so membership stays single-writer.
        """
        stage = self.stages[self._stage_of(rec.node_id)]
        payload = {
            "node_id": rec.node_id,
            "workers": stage.workers_per_node,
            "function": stage.function,
            "stage": stage.name,
            "heartbeat_interval": self.membership.monitor.interval_s,
            "slowdown": float(self.slowdown.get(rec.node_id, 0.0)),
            "artifacts": self.artifacts,
            "prefetch": self.prefetch,
            "flush_items": self.flush_items,
            "flush_interval": self.flush_interval,
        }

        def sender() -> None:
            try:
                rec.conn.send(Frame(FrameType.LOAD, payload, LOAD_WIRE_CHANNEL))
            except Exception:
                # Dead pipe or an unserializable deployment: either way the
                # node can never load — report it so it is marked dead
                # (unloadable everywhere -> "all node-loaders died") rather
                # than leaving the job to idle until job_timeout.
                self._events.put(("loaded", rec.node_id, False))
                return
            self._events.put(("loaded", rec.node_id, True))

        t = threading.Thread(target=sender, name=f"hnl-load-{rec.node_id}",
                             daemon=True)
        t.start()
        self._threads.append(t)

    def _apply_load_result(self, node_id: str, ok: bool) -> None:
        rec = self.membership.nodes.get(node_id)
        if ok:
            if rec is not None and rec.alive:  # never resurrect a reaped node
                self.membership.mark_loaded(node_id)
            return
        # Died between REGISTER and LOAD: a bootstrap-time node loss,
        # handled like any other — survivors run the job.
        if self.membership.mark_dead(node_id) is not None:
            self.stats.deaths_detected += 1

    def _node_finished(self, node_id: str, payload: Any) -> None:
        timing = payload or {}
        self.membership.mark_done(node_id, timing)
        self.timing.add(node_id, "boot", float(timing.get("boot_ms", 0.0)))
        self.timing.add(node_id, "load", float(timing.get("load_ms", 0.0)))
        self.timing.add(node_id, "run", float(timing.get("run_ms", 0.0)))

    def _collect_wire_stats(self) -> None:
        """Fold per-connection traffic counters + protocol counters into the
        timing collector (reported by benchmarks/run.py)."""
        agg = {"bytes_sent": 0, "bytes_recv": 0,
               "frames_sent": 0, "frames_recv": 0}
        for rec in self.membership.nodes.values():
            if rec.conn is None:
                continue
            for key, val in rec.conn.counters.as_dict().items():
                agg[key] += val
        agg["work_requests"] = self.stats.work_requests
        agg["work_batches"] = self.stats.work_batches
        agg["result_batches"] = self.stats.result_batches
        agg["max_batch"] = self.stats.max_batch
        # One round-trip = one host-bound demand frame (explicit request or
        # piggybacked result batch) plus its answer.
        agg["round_trips"] = self.stats.work_requests + self.stats.result_batches
        self.timing.add_wire(**agg)

    # -- teardown -----------------------------------------------------------

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for rec in self.membership.nodes.values():
            if rec.conn is not None:
                rec.conn.close()
