"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialisation.

Target: TPU v5e pods.  Single pod = 16 x 16 = 256 chips ("data", "model");
multi-pod = 2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod"
axis crosses DCN, which is why the rules put only batch (gradient
all-reduce) on it.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2**30  # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device unless XLA_FLAGS raised it)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def model_axis_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
