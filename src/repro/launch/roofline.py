import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis per (architecture x shape), single-pod mesh.

Methodology (DESIGN.md section 5) — XLA's ``cost_analysis`` counts loop
bodies once, so totals are reconstructed from *unrolled probe programs*:

* decode / long shapes: the decode step is already layer-unrolled and scan
  free -> one compile gives exact per-device FLOPs / bytes / collectives.
* train / prefill shapes: three probes with ``scan_layers=False,
  unroll_scans=True`` and ``num_layers`` in {p, 2p, p+r} (p = pattern
  period, r = remainder).  Every cost is linear in the layer counts, so

      cost(L) = fixed + n_full * period_cost + remainder_cost

  with period_cost = C(2p) - C(p), fixed = C(p) - period_cost,
  remainder_cost = C(p+r) - C(p).
* xlstm's sLSTM core is a time-sequential scan that cannot be unrolled at
  S=4k (HLO blow-up); its recurrent FLOPs are added analytically and the
  cell is flagged ``slstm_analytic_correction``.

Terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):

    compute   = FLOPs_per_device / peak
    memory    = bytes_per_device / hbm_bw          (cost_analysis estimate)
    collective= ring link bytes_per_device / ici_bw (parsed from HLO)

``MODEL_FLOPS`` = 6 N_active D (train) / 2 N_active D (+ cache reads for
decode); the reported ``roofline_fraction`` = time(MODEL_FLOPS at peak) /
max(term) is the MFU *upper bound* the compiled program permits — the
number the perf loop drives up.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import all_cells, get_config, get_shape
from repro.core.builder import ClusterBuilder
from repro.launch.dryrun import build_cell
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    model_axis_size,
)
from repro.models.flops import step_flops


def _compile_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    fn, args, donate, rules, tp = build_cell(cfg, shape, mesh)
    builder = ClusterBuilder(mesh=mesh, rules=rules)
    art = builder.build_step(fn, args, name="probe", donate_argnums=donate)
    cost = art.cost()
    colls = art.collectives()
    return {
        "flops": cost["flops_per_device"],
        "bytes": cost["bytes_per_device"],
        "coll": colls.total_link_bytes,
        "coll_by_kind": colls.by_kind(),
        "n_colls": len(colls.ops),
    }


def _combine(c1, c2, c3, n_full, has_rem):
    out = {}
    for key in ("flops", "bytes", "coll"):
        period = c2[key] - c1[key]
        fixed = c1[key] - period
        rem = (c3[key] - c1[key]) if has_rem else 0.0
        out[key] = max(fixed + n_full * period + rem, 0.0)
        out[key + "_per_layer_period"] = period
        out[key + "_fixed"] = fixed
    return out


def _slstm_correction(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic recurrent FLOPs for sLSTM layers (scan body counted once)."""
    n_slstm = cfg.layer_counts().get("slstm", 0)
    if n_slstm == 0 or shape.kind not in ("train", "prefill"):
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    hd = (cfg.num_heads * cfg.head_dim) // cfg.num_heads
    per_layer = 4 * 2 * B * S * cfg.num_heads * hd * hd
    mult = 3.0 if shape.kind == "train" else 1.0
    return n_slstm * per_layer * (S - 1) / S * mult


def analyze_cell(arch: str, shape_name: str) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=False)
    chips = mesh.devices.size
    tp = model_axis_size(mesh)
    t0 = time.perf_counter()

    flags = []
    # All kinds use unrolled layer-count probes: every step program scans
    # over layers in production form, so totals are reconstructed from the
    # linear cost model (module docstring).  xLSTM blocks keep their inner
    # scans (unrolling the 64-chunk mLSTM backward is a compile tarpit);
    # their FLOPs are replaced by the analytic model and flagged.
    p = len(cfg.layer_pattern)
    r = cfg.num_layers % p
    n_full = cfg.num_layers // p
    inner_unrollable = not any(k in ("mlstm", "slstm")
                               for k in cfg.layer_pattern)

    def probe_cfg(n_layers: int) -> ModelConfig:
        repl = dict(num_layers=n_layers, scan_layers=False,
                    unroll_scans=inner_unrollable)
        if cfg.encoder_layers:
            repl["encoder_layers"] = n_layers
        return dataclasses.replace(cfg, **repl)

    c1 = _compile_costs(probe_cfg(p), shape, mesh)
    c2 = _compile_costs(probe_cfg(2 * p), shape, mesh)
    c3 = _compile_costs(probe_cfg(p + r), shape, mesh) if r else None
    totals = _combine(c1, c2, c3, n_full, r > 0)
    coll_by_kind = c2["coll_by_kind"]
    probes = 3 if r else 2
    if not inner_unrollable:
        # inner scans counted once by cost_analysis: use analytic FLOPs.
        totals["flops"] = step_flops(cfg, shape, tp=tp).total / chips
        flags.append("analytic_flops")
    else:
        corr = _slstm_correction(cfg, shape)
        if corr:
            # correction is global: convert to per-device
            totals["flops"] += corr / chips
            flags.append("slstm_analytic_correction")

    t_compute = totals["flops"] / PEAK_FLOPS_BF16
    t_memory = totals["bytes"] / HBM_BW
    t_coll = totals["coll"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]

    fl = step_flops(cfg, shape, tp=tp)
    t_model = (fl.model_flops / chips) / PEAK_FLOPS_BF16
    hlo_flops_global = totals["flops"] * chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "16x16",
        "chips": chips,
        "kind": shape.kind,
        "ok": True,
        "analysis_s": round(time.perf_counter() - t0, 1),
        "probes": probes,
        "flags": flags,
        "per_device": {
            "flops": totals["flops"],
            "bytes": totals["bytes"],
            "collective_link_bytes": totals["coll"],
        },
        "terms_seconds": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_global": fl.model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": round(fl.model_flops / max(hlo_flops_global, 1), 4),
        "roofline_fraction": round(t_model / max(bound, 1e-12), 4),
        "collectives_by_kind": {
            k: {"count": n, "link_MiB": round(b / 2**20, 2)}
            for k, (n, b) in coll_by_kind.items()
        },
    }
    return result


def render_table(out_dir: str) -> str:
    rows = []
    for name in sorted(os.listdir(out_dir)):
        if name.endswith(".json"):
            with open(os.path.join(out_dir, name)) as fh:
                rows.append(json.load(fh))
    lines = [
        f"{'arch':<28}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
        f"{'coll_s':>11}{'dominant':>11}{'useful':>8}{'roofline':>9}",
    ]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"{r['arch']:<28}{r['shape']:<13}  FAILED: {r.get('error','')[:60]}")
            continue
        t = r["terms_seconds"]
        lines.append(
            f"{r['arch']:<28}{r['shape']:<13}{t['compute']:>11.4f}"
            f"{t['memory']:>11.4f}{t['collective']:>11.4f}"
            f"{r['dominant']:>11}{r['useful_ratio']:>8.3f}"
            f"{r['roofline_fraction']:>9.3f}"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--render", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.render and not (args.all or args.arch):
        print(render_table(args.out))
        return

    if args.all:
        cells = [
            (cfg.name, shape.name)
            for cfg, shape, runnable in all_cells()
            if runnable
        ]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all/--render")
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[roofline] {tag} ...", flush=True)
        try:
            result = analyze_cell(arch, shape_name)
            t = result["terms_seconds"]
            print(
                f"  compute {t['compute']:.4f}s | memory {t['memory']:.4f}s | "
                f"collective {t['collective']:.4f}s -> {result['dominant']} "
                f"(useful {result['useful_ratio']:.3f}, "
                f"roofline {result['roofline_fraction']:.3f})",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            result = {
                "arch": arch, "shape": shape_name, "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"  FAILED: {result['error']}", flush=True)
        with open(path, "w") as fh:
            json.dump(result, fh, indent=2)

    if args.render:
        print()
        print(render_table(args.out))


if __name__ == "__main__":
    main()
