"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from repro.configs.base import ALL_SHAPES, ModelConfig, ShapeConfig
from repro.configs.command_r_35b import CONFIG as COMMAND_R_35B
from repro.configs.gemma3_4b import CONFIG as GEMMA3_4B
from repro.configs.internvl2_2b import CONFIG as INTERNVL2_2B
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.phi3_medium_14b import CONFIG as PHI3_MEDIUM_14B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.yi_9b import CONFIG as YI_9B

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        RECURRENTGEMMA_2B,
        PHI3_MEDIUM_14B,
        COMMAND_R_35B,
        YI_9B,
        GEMMA3_4B,
        LLAMA4_MAVERICK,
        OLMOE_1B_7B,
        XLSTM_350M,
        INTERNVL2_2B,
        SEAMLESS_M4T,
    )
}

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).smoke()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig, bool]]:
    """Every (arch, shape, runnable) cell — 40 total, skips flagged False."""
    cells = []
    for cfg in ARCHS.values():
        run_names = {s.name for s in cfg.shapes()}
        for shape in ALL_SHAPES:
            cells.append((cfg, shape, shape.name in run_names))
    return cells
