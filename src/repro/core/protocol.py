"""CSP process model of the ClusterBuilder application network (Listing 3).

This is a direct transliteration of the paper's CSPm specification into a
labelled-transition-system (LTS) form that ``core.verify`` can exhaustively
check, generalised from the paper's ``W = 1`` worker per node to ``W >= 1``
(the deployed network of Figure 2 has ``cores`` workers behind every
``nrfa``).

Processes and channels (paper Figure 3):

    Emit --a--> Server(onrl) --c.i--> Client_i(nrfa) --d.i--> Worker_{i,w}
                      ^------b.i--------|
    Worker_{i,w} --e.i--> Reducer(afoc+afo) --f--> Collect --finished--> env

All channels are synchronous, unbuffered and unidirectional (CSP semantics:
a communication happens only when writer and reader are simultaneously
ready).  Channels ``a..f`` are hidden when checking refinement against
``TestSystem = finished -> TestSystem``; ``finished`` is the only visible
event — exactly the setup of Listing 3 lines 50-58.

NOTE — paper erratum: Listing 3 line 28 reads ``Server_End(y) = b?y.S ->
c!y.UT -> if y == N then SKIP else Server_End(y+1)``.  Taken literally, with
clients indexed ``0..N-1`` the recursion reaches ``Server_End(N)`` and blocks
on the non-existent channel ``b.N`` — a deadlock FDR would flag.  We
implement the evidently-intended ``if y == N-1 then SKIP`` and the verifier
(tests) demonstrates that the literal version deadlocks while the corrected
one passes all assertions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Iterable

# The Universal Terminator object (paper's ``UT``).
UT = "UT"

# Process-state sentinel equivalent to CSP SKIP (successful termination).
SKIP = ("SKIP",)

Event = tuple  # (channel_key, value)
State = Hashable


@dataclass(frozen=True)
class Output:
    chan: Hashable
    value: Any
    next_state: State


@dataclass(frozen=True)
class Input:
    chan: Hashable
    # accept(value) -> next_state, or None to refuse the value.
    accept: Callable[[Any], State | None]


class Process:
    """A process = initial state + ready-output/ready-input functions."""

    name: str = "proc"

    def initial(self) -> State:
        raise NotImplementedError

    def outputs(self, state: State) -> list[Output]:
        return []

    def inputs(self, state: State) -> list[Input]:
        return []

    def is_terminated(self, state: State) -> bool:
        return state == SKIP


# ---------------------------------------------------------------------------
# The six process kinds of Listing 3.
# ---------------------------------------------------------------------------


class EmitProc(Process):
    """Emit(o) = a!o -> if o == UT then SKIP else Emit(create(o))  {3:22}."""

    def __init__(self, num_objects: int):
        self.name = "emit"
        self.num_objects = num_objects

    def initial(self) -> State:
        return ("emit", 0)

    def outputs(self, state: State) -> list[Output]:
        if state == SKIP:
            return []
        _, k = state
        if k < self.num_objects:
            return [Output(("a",), k, ("emit", k + 1))]
        return [Output(("a",), UT, SKIP)]


class ServerProc(Process):
    """The ``onrl`` server {3:24-29} (with the line-28 erratum corrected).

    ``literal_paper_model=True`` reproduces Listing 3 exactly (including the
    off-by-one) so the verifier can exhibit the deadlock.
    """

    def __init__(self, nclusters: int, literal_paper_model: bool = False):
        self.name = "server"
        self.n = nclusters
        self.literal = literal_paper_model

    def initial(self) -> State:
        return ("idle",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("idle",):
            # Server() = a?o -> ...
            def accept(o: Any) -> State:
                return ("end", 0) if o == UT else ("have", o)

            return [Input(("a",), accept)]
        if state[0] == "have":
            # Server_Choice(o) = [] x : {0..N-1} @ Service(x, o); Service
            # begins b?i.S.
            o = state[1]
            return [
                Input(("b", i), lambda _s, i=i, o=o: ("serve", i, o))
                for i in range(self.n)
            ]
        if state[0] == "end":
            # Server_End(y) = b?y.S -> c!y.UT -> ...
            y = state[1]
            if y < self.n:
                return [Input(("b", y), lambda _s, y=y: ("end_serve", y))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "serve":
            _, i, o = state
            return [Output(("c", i), o, ("idle",))]
        if state and state[0] == "end_serve":
            y = state[1]
            if self.literal:
                # Literal Listing 3: `if y == N then SKIP else Server_End(y+1)`
                nxt = SKIP if y == self.n else ("end", y + 1)
            else:
                nxt = SKIP if y == self.n - 1 else ("end", y + 1)
            return [Output(("c", y), UT, nxt)]
        return []


class ClientProc(Process):
    """The ``nrfa`` client of node ``i`` {3:30-31}, generalised to W workers.

    Client(i) = b!i.S -> c?i.o -> if o == UT then (d!i.UT * W -> SKIP)
                                  else (d!i.o -> Client(i))

    The one-place-buffer invariant is structural: the client re-enters the
    requesting state only *after* the d.i communication completes, so the
    server can never be blocked by a node with an idle worker (paper §5).
    """

    def __init__(self, i: int, workers: int):
        self.name = f"client{i}"
        self.i = i
        self.workers = workers

    def initial(self) -> State:
        return ("req",)

    def outputs(self, state: State) -> list[Output]:
        if state == ("req",):
            return [Output(("b", self.i), "S", ("wait",))]
        if state and state[0] == "deliver":
            o = state[1]
            if o == UT:
                # First of W terminators — one per worker behind this client.
                nxt = SKIP if self.workers == 1 else ("term", 1)
                return [Output(("d", self.i), UT, nxt)]
            return [Output(("d", self.i), o, ("req",))]
        if state and state[0] == "term":
            w = state[1]
            nxt = SKIP if w + 1 == self.workers else ("term", w + 1)
            return [Output(("d", self.i), UT, nxt)]
        return []

    def inputs(self, state: State) -> list[Input]:
        if state == ("wait",):
            return [Input(("c", self.i), lambda o: ("deliver", o))]
        return []


class WorkerProc(Process):
    """Worker {3:35-36}: d?i.o -> (e!i.o ->) with UT termination."""

    def __init__(self, i: int, w: int):
        self.name = f"worker{i}.{w}"
        self.i = i

    def initial(self) -> State:
        return ("work",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("work",):
            return [Input(("d", self.i), lambda o: ("fwd", o))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "fwd":
            o = state[1]
            nxt = SKIP if o == UT else ("work",)
            return [Output(("e", self.i), o, nxt)]
        return []


class ReducerProc(Process):
    """Reducer {3:39-45}, generalised: forwards non-UT objects from any e.i,
    counts ``N*W`` UTs (one per worker), then emits a single f!UT."""

    def __init__(self, nclusters: int, workers: int):
        self.name = "reducer"
        self.n = nclusters
        self.remaining = nclusters * workers

    def initial(self) -> State:
        return ("read", self.remaining)

    def inputs(self, state: State) -> list[Input]:
        if state and state[0] == "read":
            k = state[1]

            def accept(o: Any, k: int = k) -> State:
                if o == UT:
                    return ("fwd_ut",) if k == 1 else ("read", k - 1)
                return ("fwd", o, k)

            return [Input(("e", i), accept) for i in range(self.n)]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "fwd":
            _, o, k = state
            return [Output(("f",), o, ("read", k))]
        if state == ("fwd_ut",):
            return [Output(("f",), UT, SKIP)]
        return []


class CollectProc(Process):
    """Collect {3:46-48}: reads f until UT, then loops on finished!True."""

    def __init__(self) -> None:
        self.name = "collect"

    def initial(self) -> State:
        return ("run",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("run",):
            return [Input(("f",), lambda o: ("done",) if o == UT else ("run",))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state == ("done",):
            return [Output(("finished",), True, ("done",))]
        return []

    def is_terminated(self, state: State) -> bool:
        return state == ("done",)


# ---------------------------------------------------------------------------
# Network assembly.
# ---------------------------------------------------------------------------


@dataclass
class ProtocolNetwork:
    """The composed System of Listing 3 lines 50-51."""

    processes: list[Process]
    visible_channels: frozenset = frozenset({("finished",)})

    @staticmethod
    def build(
        nclusters: int,
        workers_per_node: int = 1,
        num_objects: int = 5,
        literal_paper_model: bool = False,
    ) -> "ProtocolNetwork":
        procs: list[Process] = [
            EmitProc(num_objects),
            ServerProc(nclusters, literal_paper_model=literal_paper_model),
        ]
        for i in range(nclusters):
            procs.append(ClientProc(i, workers_per_node))
        for i in range(nclusters):
            for w in range(workers_per_node):
                procs.append(WorkerProc(i, w))
        procs.append(ReducerProc(nclusters, workers_per_node))
        procs.append(CollectProc())
        return ProtocolNetwork(processes=procs)

    def initial(self) -> tuple:
        return tuple(p.initial() for p in self.processes)

    def successors(self, state: tuple) -> Iterable[tuple[Event, tuple]]:
        """All enabled synchronisations from a global state.

        A transition exists for every (writer, reader) pair that is ready on
        the same channel and whose reader accepts the offered value.
        """
        procs = self.processes
        # Gather ready outputs and inputs per channel.
        outs: dict[Hashable, list[tuple[int, Output]]] = {}
        ins: dict[Hashable, list[tuple[int, Input]]] = {}
        for pi, proc in enumerate(procs):
            for out in proc.outputs(state[pi]):
                outs.setdefault(out.chan, []).append((pi, out))
            for inp in proc.inputs(state[pi]):
                ins.setdefault(inp.chan, []).append((pi, inp))
        for chan, writers in outs.items():
            if chan in self.visible_channels:
                # Environment always willing to observe visible events.
                for pi, out in writers:
                    ns = list(state)
                    ns[pi] = out.next_state
                    yield (chan, out.value), tuple(ns)
                continue
            for pi, out in writers:
                for qi, inp in ins.get(chan, []):
                    if pi == qi:
                        continue
                    nxt = inp.accept(out.value)
                    if nxt is None:
                        continue
                    ns = list(state)
                    ns[pi] = out.next_state
                    ns[qi] = nxt
                    yield (chan, out.value), tuple(ns)

    def is_hidden(self, event: Event) -> bool:
        return event[0] not in self.visible_channels

    def all_terminated(self, state: tuple) -> bool:
        return all(p.is_terminated(s) for p, s in zip(self.processes, state))
