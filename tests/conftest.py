"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun + launch/roofline request 512 placeholder devices)."""

import jax
import pytest

jax.config.update("jax_default_matmul_precision", "float32")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
