"""Serving launcher: the demand-driven continuous-batching engine.

Example::

    python -m repro.launch.serve --arch yi-9b --requests 16 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.common import init_params
from repro.runtime.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()  # serving demo is CPU-sized
    params = init_params(
        lm.lm_param_specs(cfg, 1), jax.random.PRNGKey(args.seed), jnp.float32
    )
    engine = ServingEngine(
        cfg, params, max_slots=args.slots, max_seq=args.max_seq
    )
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(Request(
            rid=rid,
            prompt=list(map(int, rng.integers(0, cfg.vocab_size, plen))),
            max_new_tokens=args.max_new,
        ))
    done = engine.shutdown()
    dt = time.perf_counter() - t0
    n_tokens = sum(len(c.tokens) - c.prompt_len for c in done)
    print(f"=== served {len(done)} requests, {n_tokens} tokens "
          f"in {dt:.2f}s ({n_tokens / dt:.1f} tok/s) ===")
    lat = sorted(c.latency_s for c in done)
    print(f"latency p50 {lat[len(lat) // 2]:.3f}s  p99 {lat[-1]:.3f}s")
    print(engine.timing.report())


if __name__ == "__main__":
    main()
