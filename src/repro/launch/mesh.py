"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialisation.

Target: TPU v5e pods.  Single pod = 16 x 16 = 256 chips ("data", "model");
multi-pod = 2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod"
axis crosses DCN, which is why the rules put only batch (gradient
all-reduce) on it.
"""

from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit/auto axis types on the mesh
    from jax.sharding import AxisType

    HAVE_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    HAVE_AXIS_TYPES = False


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,)*n`` where supported, ``{}`` otherwise.

    On older jax (e.g. 0.4.x) every mesh axis is Auto already, so omitting
    the kwarg preserves semantics exactly.
    """
    if HAVE_AXIS_TYPES:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def compat_make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types on jax versions that have them."""
    return jax.make_mesh(tuple(shape), tuple(axis_names),
                         **axis_types_kwargs(len(axis_names)))


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` where it exists,
    the ``Mesh`` context-manager protocol on older jax, no-op for ``None``."""
    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax<=0.4.x: Mesh is itself a context manager

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 2**30  # per chip


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device unless XLA_FLAGS raised it)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return compat_make_mesh((data, model), ("data", "model"))


def model_axis_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
