"""Render EXPERIMENTS.md sections from results/dryrun + results/roofline."""

from __future__ import annotations

import json
import os

from repro.configs.registry import ARCHS, all_cells


def _load(dirname: str) -> dict:
    out = {}
    if not os.path.isdir(dirname):
        return out
    for name in os.listdir(dirname):
        if name.endswith(".json"):
            with open(os.path.join(dirname, name)) as fh:
                r = json.load(fh)
            out[name[: -len(".json")]] = r
    return out


def dryrun_table(dir_="results/dryrun") -> str:
    res = _load(dir_)
    lines = [
        "| arch | shape | mesh | compile_s | GiB/device | HBM% | collectives | link MiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cfg, shape, runnable in all_cells():
        for mesh, tag in (("16x16", "single"), ("2x16x16", "multi")):
            key = f"{cfg.name}__{shape.name}__{tag}"
            if not runnable:
                if tag == "single":
                    why = dict(cfg.skipped_shapes()).get(shape.name, "skip")
                    lines.append(
                        f"| {cfg.name} | {shape.name} | — | — | — | — | "
                        f"SKIP: {why[:60]} | — |"
                    )
                continue
            r = res.get(key)
            if r is None:
                lines.append(f"| {cfg.name} | {shape.name} | {mesh} | pending | | | | |")
            elif not r.get("ok"):
                lines.append(
                    f"| {cfg.name} | {shape.name} | {mesh} | FAILED | | | "
                    f"{r.get('error', '')[:50]} | |"
                )
            else:
                m = r["memory"]
                c = r["collectives"]
                lines.append(
                    f"| {cfg.name} | {shape.name} | {mesh} | "
                    f"{r['load_compile_s']} | "
                    f"{m['live_bytes_per_device'] / 2**30:.2f} | "
                    f"{100 * m['hbm_fraction']:.0f}% | "
                    f"{c['total_ops']} | "
                    f"{c['total_link_MiB_per_device']:.0f} |"
                )
    return "\n".join(lines)


def roofline_table(dir_="results/roofline") -> str:
    res = _load(dir_)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful | roofline | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        "collective": "cut cross-shard repartitions (constraint placement, "
                      "comm/compute overlap, grad compression on pod axis)",
        "memory": "larger per-step arithmetic intensity (fuse, bf16 cache, "
                  "batch more tokens per weight fetch)",
        "compute": "near bound — reduce padding waste / remat recompute",
    }
    for cfg, shape, runnable in all_cells():
        if not runnable:
            continue
        r = res.get(f"{cfg.name}__{shape.name}")
        if r is None:
            lines.append(f"| {cfg.name} | {shape.name} | pending | | | | | | |")
            continue
        if not r.get("ok"):
            lines.append(
                f"| {cfg.name} | {shape.name} | FAILED | | | | | | "
                f"{r.get('error', '')[:40]} |"
            )
            continue
        t = r["terms_seconds"]
        lines.append(
            f"| {cfg.name} | {shape.name} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{fixes[r['dominant']][:70]} |"
        )
    return "\n".join(lines)


def main() -> None:
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run table\n")
        print(dryrun_table())
        print()
    if which in ("all", "roofline"):
        print("### Roofline table\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
