"""RG-LRU linear recurrence as a Pallas TPU kernel.

Hardware adaptation: a GPU implementation would block over sequence with a
chunked parallel scan across SMs.  On TPU the natural decomposition is
*channel-parallel, time-serial*: the recurrence is elementwise over the
width W, so

* grid = (B, W / BLOCK_W): each program owns a channel stripe;
* the stripe's (a, b) panels [S, BLOCK_W] are VMEM-resident (BlockSpec);
* a ``fori_loop`` walks time *in-register*: the VPU processes 8x128 lanes
  of channels per tick while the loop carries h — no HBM round-trips inside
  the scan, one store of the h panel at the end;
* the carried state enters via a third input (decode/chunk chaining) and the
  final state exits as a second output.

This keeps the MXU out (no matmuls here) but saturates VPU lanes; the
sequential dimension costs S VPU ticks per stripe, amortised across the
B x W/BLOCK_W grid — the same trade Griffin's TPU kernel makes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_W = 128


def _rglru_kernel(a_ref, b_ref, h0_ref, h_ref, hlast_ref):
    # a_ref/b_ref/h_ref: [S, BLOCK_W]; h0_ref/hlast_ref: [1, BLOCK_W]
    S = a_ref.shape[0]

    def body(t, h):
        a_t = pl.load(a_ref, (pl.dslice(t, 1), slice(None)))
        b_t = pl.load(b_ref, (pl.dslice(t, 1), slice(None)))
        h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)
        pl.store(h_ref, (pl.dslice(t, 1), slice(None)), h.astype(h_ref.dtype))
        return h

    h = h0_ref[...].astype(jnp.float32)
    h = jax.lax.fori_loop(0, S, body, h)
    hlast_ref[...] = h.astype(hlast_ref.dtype)


def rglru_scan_pallas(
    a: jax.Array,  # [B, S, W]
    b: jax.Array,
    h0: jax.Array | None = None,  # [B, W]
    *,
    block_w: int = BLOCK_W,
    interpret: bool = True,
):
    B, S, W = a.shape
    if W % block_w:
        raise ValueError(f"W={W} must tile by block_w={block_w}")
    if h0 is None:
        h0 = jnp.zeros((B, W), a.dtype)
    grid = (B, W // block_w)
    panel = pl.BlockSpec((None, S, block_w), lambda bi, wi: (bi, 0, wi))
    state = pl.BlockSpec((None, 1, block_w), lambda bi, wi: (bi, 0, wi))
    h, hlast = pl.pallas_call(
        _rglru_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, 1, W), a.dtype),
        ),
        grid=grid,
        in_specs=[panel, panel, state],
        out_specs=(panel, state),
        interpret=interpret,
    )(a, b, h0[:, None, :])
    return h, hlast[:, 0, :]
