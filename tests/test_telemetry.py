"""Live telemetry (repro.cluster.telemetry): bus, endpoint, traces.

Registry units run against an injected clock (deterministic Prometheus
golden output, ring/cursor semantics); the integration tests boot a real
ClusterService over an InProcessLauncher, run two concurrent jobs, and
check that what ``GET /metrics`` reports sums consistently with the jobs'
own final ``stats()`` — the acceptance invariant of the observability
layer.  Everything stays on 127.0.0.1 with stdlib HTTP only.
"""

import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.cluster.deploy.inprocess import InProcessLauncher
from repro.cluster.membership import Membership
from repro.cluster.service import ClusterService
from repro.cluster.telemetry import (
    Telemetry,
    TelemetryServer,
    TraceWriter,
    read_trace,
)
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails

FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _list_collect():
    return ResultDetails(name="list", init=lambda: [],
                         collect=lambda a, x: a + [x], finalise=sorted)


def _spec(work, n_items, *, nclusters=2, workers=2):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_list_collect(),
    )


def _service(**kw):
    kw.setdefault("nodes", 2)
    kw.setdefault("workers", 2)
    kw.setdefault("launcher", InProcessLauncher())
    kw.update(FAST)
    return ClusterService(**kw)


def _double(x):
    return x * 2


def _triple(x):
    return x * 3


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.headers.get("Content-Type", ""), resp.read()


def _get_json(url):
    status, ctype, body = _get(url)
    assert status == 200
    assert ctype.startswith("application/json")
    return json.loads(body)


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------


def test_event_ring_ordering_and_since_cursor():
    t = Telemetry(ring_size=8, clock=lambda: 1000.0)
    for i in range(5):
        t.emit("step", n=i)
    events = t.events_since(0)
    assert [e["seq"] for e in events] == [1, 2, 3, 4, 5]
    assert [e["n"] for e in events] == [0, 1, 2, 3, 4]
    # The cursor contract: pass the largest seq seen, get only what's new.
    cursor = events[-1]["seq"]
    assert t.events_since(cursor) == []
    t.emit("step", n=5)
    newer = t.events_since(cursor)
    assert [e["seq"] for e in newer] == [6]
    # limit truncates from the oldest end.
    assert [e["seq"] for e in t.events_since(0, limit=2)] == [1, 2]


def test_event_ring_bounded_and_drop_accounted():
    t = Telemetry(ring_size=4, clock=lambda: 0.0)
    for i in range(10):
        t.emit("e", n=i)
    events = t.events_since(0)
    # Only the newest ring_size survive, in order, seq still monotonic.
    assert [e["seq"] for e in events] == [7, 8, 9, 10]
    snap = t.snapshot()
    assert snap["events"]["next"] == 10
    assert snap["events"]["dropped"] == 6


def test_emit_is_thread_safe_seq_unique():
    t = Telemetry(ring_size=4096)
    threads = [threading.Thread(
        target=lambda: [t.emit("x") for _ in range(200)])
        for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    seqs = [e["seq"] for e in t.events_since(0, limit=1000)]
    assert len(seqs) == 800
    assert seqs == sorted(seqs) and len(set(seqs)) == 800


def test_snapshot_merges_push_and_pull():
    t = Telemetry(clock=lambda: 50.0)
    t.set_node("node0", state="loaded", report={"boot_ms": 3.0})
    t.set_job(1, pending=[2], items_collected=7)
    t.inc("jobs_completed")
    # Samplers merge at snapshot time; node dicts merge one level deep so
    # sampled fields join the pushed report instead of replacing it.
    t.set_sampler("nodes", lambda: {
        "node0": {"credits": 4, "wire": {"bytes_sent": 100, "bytes_recv": 40}},
    })
    t.set_sampler("cluster", lambda: {"nodes_alive": 1})
    snap = t.snapshot()
    n = snap["nodes"]["node0"]
    assert n["state"] == "loaded" and n["credits"] == 4
    assert n["report"] == {"boot_ms": 3.0}
    assert snap["cluster"]["jobs_completed"] == 1
    assert snap["cluster"]["nodes_alive"] == 1
    # Cluster-wide wire totals are summed from the per-node wire dicts.
    assert snap["cluster"]["wire_bytes_sent"] == 100
    assert snap["jobs"]["1"]["items_collected"] == 7
    with pytest.raises(ValueError):
        t.set_sampler("bogus", dict)


def test_broken_sampler_never_breaks_snapshot():
    t = Telemetry()

    def exploding():
        raise RuntimeError("sampler bug")

    t.set_sampler("nodes", exploding)
    assert t.snapshot()["nodes"] == {}


def test_prometheus_golden():
    """Deterministic exposition: fixed clock, sorted families and labels."""
    clk = [100.0]
    t = Telemetry(clock=lambda: clk[0])
    clk[0] = 102.5
    t.inc("jobs_completed", 2)
    t.set_job(1, pending=[3, 1], items_collected=5, done=False)
    t.set_node("node0", state="loaded",
               report={"cache_hits": 2, "cache_misses": 1},
               wire={"bytes_sent": 10})
    got = t.prometheus()
    expected = "\n".join([
        "# TYPE repro_cluster_jobs_completed gauge",
        "repro_cluster_jobs_completed 2",
        "# TYPE repro_cluster_wire_bytes_sent gauge",
        "repro_cluster_wire_bytes_sent 10",
        "# TYPE repro_job_done gauge",
        'repro_job_done{job="1"} 0',
        "# TYPE repro_job_items_collected gauge",
        'repro_job_items_collected{job="1"} 5',
        "# TYPE repro_job_pending gauge",
        'repro_job_pending{job="1",stage="0"} 3',
        'repro_job_pending{job="1",stage="1"} 1',
        "# TYPE repro_node_report_cache_hits gauge",
        'repro_node_report_cache_hits{node="node0"} 2',
        "# TYPE repro_node_report_cache_misses gauge",
        'repro_node_report_cache_misses{node="node0"} 1',
        "# TYPE repro_node_state gauge",
        'repro_node_state{node="node0",state="loaded"} 1',
        "# TYPE repro_node_wire_bytes_sent gauge",
        'repro_node_wire_bytes_sent{node="node0"} 10',
        "# TYPE repro_uptime_seconds gauge",
        "repro_uptime_seconds 2.5",
    ]) + "\n"
    assert got == expected


def test_histogram_buckets_cumulate_and_expose():
    t = Telemetry(clock=lambda: 0.0)
    assert "histograms" not in t.snapshot()  # absent until first observe
    for v in (1, 2, 3, 5, 300):  # 300 overflows the largest bound (256)
        t.observe("result_batch_items", v)
    h = t.snapshot()["histograms"]["result_batch_items"]
    assert h["count"] == 5 and h["sum"] == 311
    cum = dict((le, n) for le, n in h["buckets"])
    # cumulative ``le`` semantics: <=1 is 1 obs; <=2 is 2; <=4 adds the 3;
    # <=8 adds the 5; the 300 only shows up in +Inf (count).
    assert cum[1.0] == 1 and cum[2.0] == 2 and cum[4.0] == 3
    assert cum[8.0] == 4 and cum[256.0] == 4
    prom = t.prometheus()
    assert "# TYPE repro_result_batch_items histogram" in prom
    assert 'repro_result_batch_items_bucket{le="4"} 3' in prom
    assert 'repro_result_batch_items_bucket{le="+Inf"} 5' in prom
    assert "repro_result_batch_items_sum 311" in prom
    assert "repro_result_batch_items_count 5" in prom


def test_histogram_unknown_family_gets_default_grid():
    t = Telemetry(clock=lambda: 0.0)
    t.observe("made_up_metric", 0.05)
    h = t.snapshot()["histograms"]["made_up_metric"]
    assert h["buckets"][0] == [0.1, 1]  # default grid starts at 0.1


def test_trace_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    t = Telemetry(trace_path=path, clock=lambda: 7.0)
    t.emit("job_submit", job=1)
    t.emit("job_done", job=1, items=3)
    t.close()
    events = read_trace(path)
    assert [e["kind"] for e in events] == ["job_submit", "job_done"]
    assert events[0]["seq"] == 1 and events[1]["items"] == 3
    # Append mode: a second run on the same path extends, never truncates.
    w = TraceWriter(path)
    w.write({"seq": 99, "kind": "extra"})
    w.close()
    w.close()  # idempotent
    assert [e["kind"] for e in read_trace(path)][-1] == "extra"


# ---------------------------------------------------------------------------
# membership transition timestamps
# ---------------------------------------------------------------------------


def test_membership_transitions_timestamped():
    m = Membership()
    seen = []
    m.on_transition = lambda rec, old: seen.append((rec.node_id, old,
                                                    rec.state))
    m.expect("n0", now=1.0)
    m.register("n0", "127.0.0.1:1", now=2.0)
    m.mark_loaded("n0")
    m.mark_done("n0")
    rec = m.nodes["n0"]
    states = [s for s, _ in rec.transitions]
    assert states == ["launching", "registered", "loaded", "done"]
    times = [at for _, at in rec.transitions]
    assert times == sorted(times) and rec.state_changed_at == times[-1]
    assert rec.transitions[1] == ("registered", 2.0)
    # expect() stamps the record directly; the hook fires on real changes.
    assert [old for _, old, _ in seen] == ["launching", "registered",
                                          "loaded"]
    assert "in-state" in m.describe()


# ---------------------------------------------------------------------------
# the HTTP endpoint (unit: handcrafted registry)
# ---------------------------------------------------------------------------


def test_endpoint_routes_and_error_paths():
    t = Telemetry(clock=lambda: 10.0)
    t.set_job(1, items_collected=2)
    t.set_node("node0", state="loaded")
    t.emit("e1")
    t.emit("e2")
    srv = TelemetryServer(t, port=0)
    try:
        status, ctype, body = _get(srv.url + "/")
        assert status == 200 and ctype.startswith("text/html")
        assert b"cluster telemetry" in body

        snap = _get_json(srv.url + "/metrics")
        assert snap["jobs"]["1"]["items_collected"] == 2
        assert _get_json(srv.url + "/jobs") == {"jobs": snap["jobs"]}
        assert _get_json(srv.url + "/nodes") == {"nodes": snap["nodes"]}

        status, ctype, body = _get(srv.url + "/metrics?format=prom")
        assert status == 200 and "0.0.4" in ctype
        assert b"# TYPE repro_uptime_seconds gauge" in body

        ev = _get_json(srv.url + "/events?since=0")
        assert [e["kind"] for e in ev["events"]] == ["e1", "e2"]
        assert ev["next"] == 2
        ev2 = _get_json(srv.url + "/events?since=2")
        assert ev2 == {"events": [], "next": 2}

        for bad, code in (("/nope", 404), ("/events?since=x", 400),
                          ("/metrics?format=xml", 400)):
            with pytest.raises(urllib.error.HTTPError) as exc:
                _get(srv.url + bad)
            assert exc.value.code == code
    finally:
        srv.close()
        srv.close()  # idempotent


# ---------------------------------------------------------------------------
# integration: a live service with two concurrent jobs
# ---------------------------------------------------------------------------


def test_service_metrics_consistent_with_job_stats(tmp_path):
    """The acceptance invariant: with two concurrent jobs on one pool,
    /metrics per-job gauges and per-node counters sum consistently with
    each job's final stats(), the dashboard renders, and the JSONL trace
    replays the full lifecycle."""
    trace = str(tmp_path / "svc.jsonl")
    with _service(http_port=0, trace_path=trace) as svc:
        assert svc.http_url is not None

        def slow_double(x):
            time.sleep(0.002)
            return x * 2

        def slow_triple(x):
            time.sleep(0.002)
            return x * 3

        n = 40
        h1 = svc.submit(_spec(slow_double, n), timeout=120)
        h2 = svc.submit(_spec(slow_triple, n), timeout=120)

        # Mid-run: the endpoint answers while the dispatcher is hot.
        mid = _get_json(svc.http_url + "/metrics")
        assert mid["cluster"]["nodes_total"] == 2

        assert h1.result() == [2 * i for i in range(n)]
        assert h2.result() == [3 * i for i in range(n)]

        snap = _get_json(svc.http_url + "/metrics")
        s1, s2 = h1.stats(), h2.stats()
        for h, s in ((h1, s1), (h2, s2)):
            g = snap["jobs"][str(h.job_id)]
            assert g["done"] is True and g["error"] is None
            assert g["items_collected"] == s["items_collected"] == n
            assert g["pending"] == [0] and g["inflight"] == [0]
            assert g["code_shipped"] == s["code_shipped"]
            assert g["code_cached"] == s["code_cached"]
            # Per-node attribution reconciles with the job totals.
            assert sum(d["items"] for d in s["nodes"].values()) \
                == s["items_collected"] + s["forwarded"]
            assert sum(d.get("cache_hits", 0)
                       for d in s["nodes"].values()) == s["code_cached"]
            assert sum(d.get("cache_misses", 0)
                       for d in s["nodes"].values()) == s["code_shipped"]
        # Cluster rollups agree with the sum over jobs.
        assert snap["cluster"]["items_total"] == 2 * n
        assert snap["cluster"]["jobs_completed"] == 2
        assert snap["cluster"]["jobs_submitted"] == 2
        assert snap["cluster"]["jobs_active"] == 0
        # Every pool node reported wire traffic, and the heartbeat-carried
        # node report eventually reflects both jobs' code loads (the beat
        # cadence is FAST; poll until the piggybacked counters catch up).
        want_misses = s1["code_shipped"] + s2["code_shipped"]
        deadline = time.monotonic() + 10
        while True:
            nodes = _get_json(svc.http_url + "/nodes")["nodes"]
            misses = sum(d.get("report", {}).get("cache_misses", 0)
                         for d in nodes.values())
            if misses == want_misses:
                break
            assert time.monotonic() < deadline
            time.sleep(0.05)
        assert set(nodes) == {"node0", "node1"}
        for d in nodes.values():
            assert d["state"] == "loaded"
            assert d["wire"]["bytes_sent"] > 0
            assert d["transitions"][-1]["state"] == "loaded"

        # The event stream saw the full lifecycle, in order per job.
        events = _get_json(svc.http_url + "/events?since=0&limit=500")
        kinds = [e["kind"] for e in events["events"]]
        assert "pool_ready" in kinds
        assert kinds.count("job_submit") == 2
        assert kinds.count("job_done") == 2
        assert kinds.index("job_submit") < kinds.index("job_done")
        # expect() stamps LAUNCHING on the record silently; the bus sees
        # the transitions from REGISTER onward.
        member_states = [e["state"] for e in events["events"]
                         if e["kind"] == "membership"
                         and e["node"] == "node0"]
        assert member_states[:2] == ["registered", "loaded"]
    assert svc.orphaned() == []

    # Trace replay: the JSONL file holds the same lifecycle, seq-ordered.
    trail = read_trace(trace)
    seqs = [e["seq"] for e in trail]
    assert seqs == sorted(seqs)
    tkinds = [e["kind"] for e in trail]
    assert tkinds.count("job_submit") == 2 and tkinds.count("job_done") == 2
    assert "pool_ready" in tkinds and "membership" in tkinds


def test_one_shot_cluster_app_serves_metrics():
    """backend="cluster" observability: ProcessClusterApplication exposes
    the same endpoint and snapshot for a pinned one-shot run."""
    from repro.core.builder import ClusterBuilder

    app = ClusterBuilder().build_application(
        _spec(_double, 20), backend="cluster",
        launcher=InProcessLauncher(), http_port=0, **FAST,
    )
    app.start()
    try:
        url = app.http_url
        assert url is not None
        snap = _get_json(url + "/metrics")
        assert snap["cluster"]["nodes_total"] == 2
        assert app.run() == [2 * i for i in range(20)]
    finally:
        pass  # run() already shut the cluster down
    final = app.metrics_snapshot()
    assert final["cluster"]["items_total"] == 20
    assert final["jobs"]["1"]["done"] is True
    assert app.orphaned() == []


def test_service_without_endpoint_has_no_server():
    with _service() as svc:
        assert svc.http_url is None
        h = svc.submit(_spec(_double, 10), timeout=60)
        assert h.result() == [2 * i for i in range(10)]
        # The bus still collected everything for metrics_snapshot().
        snap = svc.metrics_snapshot()
        assert snap["cluster"]["jobs_completed"] == 1
    assert svc.orphaned() == []


def test_sse_stream_pushes_snapshots_and_bus_events():
    """/events/stream: a snapshot frame arrives up front, emitted bus
    events are pushed without polling, and close() ends the stream rather
    than hanging on the open connection."""
    import http.client

    telem = Telemetry()
    telem.inc("nodes_alive", 2)
    server = TelemetryServer(telem)
    conn = http.client.HTTPConnection(server.host, server.port, timeout=5.0)
    try:
        conn.request("GET", "/events/stream")
        resp = conn.getresponse()
        assert resp.status == 200
        assert resp.headers["Content-Type"] == "text/event-stream"

        def read_frame():
            lines = []
            while True:
                line = resp.fp.readline().decode("utf-8").rstrip("\n")
                if not line:
                    if lines:
                        return lines
                    continue
                lines.append(line)

        first = read_frame()
        assert first[0] == "event: snapshot"
        snap = json.loads(first[1][len("data: "):])
        assert snap["cluster"]["nodes_alive"] == 2

        telem.emit("node_registered", node="node7")
        deadline = time.monotonic() + 5
        kinds = []
        while time.monotonic() < deadline:
            frame = read_frame()
            if frame[0] == "event: bus":
                ev = json.loads(frame[1][len("data: "):])
                kinds.append(ev["kind"])
                if "node_registered" in kinds:
                    break
        assert "node_registered" in kinds
    finally:
        server.close()  # must not hang on the live stream
        conn.close()


def test_sse_stream_rejects_bad_cursor():
    telem = Telemetry()
    server = TelemetryServer(telem)
    try:
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(
                f"{server.url}/events/stream?since=x", timeout=5.0)
        assert err.value.code == 400
    finally:
        server.close()
