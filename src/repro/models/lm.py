"""Decoder-only language model covering every assigned family.

One parameter-spec builder + one forward covers dense (GQA/RoPE/SwiGLU),
sliding-window & hybrid patterns (gemma3, recurrentgemma), MoE (llama4,
olmoe), and xLSTM — the layer *pattern* from the config decides which block
types exist and in which order.  Per-type parameters are stacked
``[count, ...]`` so full periods run under ``lax.scan`` (compact HLO, fast
compile) with the pattern remainder unrolled; decode paths unroll everything
(small graphs, exact cost analysis).

TP head policy (see DESIGN.md):
  * q heads padded to ``padded_size(H, tp)``; zero-initialised extra heads
    feed zero ``w_o`` columns, so outputs are exact.
  * KV heads padded to ``Hp / q_per_kv`` when that keeps GQA grouping intact;
    otherwise (llama4's g=5) the *expanded-KV* path gathers K/V per q head
    (``kv_index``), which shards over any head count.
  * vocab padded to the TP degree; padded logits masked at the loss.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.channels import ShardingRules, padded_size
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import ParamSpec, fan_in_normal
from repro.models.layers import (
    chunked_cross_entropy,
    embed_tokens,
    lm_logits,
    mlp_specs,
    rms_norm,
    swiglu,
)

ATTN_KINDS = ("attn", "local", "global", "moe")


def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# Head-padding policy
# ---------------------------------------------------------------------------


def head_plan(cfg: ModelConfig, tp: int) -> dict:
    """Resolve the TP attention plan: padded head counts + grouping mode."""
    H, KV, g = cfg.num_heads, cfg.num_kv_heads, cfg.q_per_kv
    Hp = padded_size(H, tp) if tp > 1 else H
    if KV == 1:
        return {"Hp": Hp, "Kp": 1, "mode": "grouped"}
    if Hp % g == 0 and Hp // g >= KV:
        return {"Hp": Hp, "Kp": Hp // g, "mode": "grouped"}
    return {"Hp": Hp, "Kp": KV, "mode": "expand_kv"}


def _kv_index(cfg: ModelConfig, Hp: int) -> jnp.ndarray:
    """Static per-(padded)-q-head KV head assignment (expand_kv mode)."""
    idx = [min(h // cfg.q_per_kv, cfg.num_kv_heads - 1) for h in range(cfg.num_heads)]
    idx += [0] * (Hp - cfg.num_heads)
    return jnp.asarray(idx, jnp.int32)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, n: int, tp: int) -> dict:
    hp = head_plan(cfg, tp)
    D, hd = cfg.d_model, cfg.head_dim
    specs = {
        "ln1": ParamSpec((n, D), ("layers", "d_model"), init="zeros"),
        "wq": ParamSpec((n, D, hp["Hp"] * hd),
                        ("layers", "d_model_fsdp", "d_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wk": ParamSpec((n, D, hp["Kp"] * hd),
                        ("layers", "d_model_fsdp", "d_kv_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wv": ParamSpec((n, D, hp["Kp"] * hd),
                        ("layers", "d_model_fsdp", "d_kv_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wo": ParamSpec((n, hp["Hp"] * hd, D),
                        ("layers", "d_attn", "d_model_fsdp"),
                        stddev=fan_in_normal((hp["Hp"] * hd, 0), fan_axis=0)),
    }
    if cfg.use_qk_norm:
        specs["q_norm"] = ParamSpec((n, hd), ("layers", None), init="zeros")
        specs["k_norm"] = ParamSpec((n, hd), ("layers", None), init="zeros")
    return specs


def _block_specs(cfg: ModelConfig, kind: str, n: int, tp: int) -> dict:
    D = cfg.d_model
    if kind in ("attn", "local", "global"):
        specs = _attn_specs(cfg, n, tp)
        if cfg.d_ff > 0:
            specs["ln2"] = ParamSpec((n, D), ("layers", "d_model"), init="zeros")
            specs["mlp"] = mlp_specs(D, cfg.d_ff, n)
        return specs
    if kind == "moe":
        specs = _attn_specs(cfg, n, tp)
        specs["ln2"] = ParamSpec((n, D), ("layers", "d_model"), init="zeros")
        specs["moe"] = moe_mod.moe_param_specs(
            n, D, cfg.moe_d_ff, cfg.num_experts,
            cfg.num_shared_experts, cfg.moe_d_ff,
        )
        return specs
    if kind == "rec":
        width = cfg.rnn_width or cfg.d_model
        specs = {
            "ln1": ParamSpec((n, D), ("layers", "d_model"), init="zeros"),
            "rec": rec_mod.recurrent_block_specs(n, D, width, cfg.conv1d_width),
        }
        if cfg.d_ff > 0:
            specs["ln2"] = ParamSpec((n, D), ("layers", "d_model"), init="zeros")
            specs["mlp"] = mlp_specs(D, cfg.d_ff, n)
        return specs
    if kind == "mlstm":
        return {
            "ln1": ParamSpec((n, D), ("layers", "d_model"), init="zeros"),
            "core": xlstm_mod.mlstm_block_specs(n, D, cfg.num_heads, cfg.head_dim),
        }
    if kind == "slstm":
        return {
            "ln1": ParamSpec((n, D), ("layers", "d_model"), init="zeros"),
            "core": xlstm_mod.slstm_block_specs(n, D, cfg.num_heads, cfg.head_dim),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def lm_param_specs(cfg: ModelConfig, tp: int = 1) -> dict:
    Vp = cfg.padded_vocab(tp)
    specs: dict[str, Any] = {
        "embed": ParamSpec((Vp, cfg.d_model), ("vocab", "d_model_fsdp"),
                           stddev=0.02),
        "final_norm": ParamSpec((cfg.d_model,), ("d_model",), init="zeros"),
        "blocks": {
            kind: _block_specs(cfg, kind, n, tp)
            for kind, n in cfg.layer_counts().items()
        },
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec(
            (cfg.d_model, Vp), ("d_model_fsdp", "vocab"),
            stddev=fan_in_normal((cfg.d_model, Vp)),
        )
    return specs


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _constrain(rules: ShardingRules | None, x, axes):
    if rules is None:
        return x
    return rules.constraint(x, axes)


def _attention_part(cfg, p, x, positions, *, kind, tp, rules, cache, cache_len,
                    return_state=False):
    """Shared attention sub-block. Returns (attn_out, state).

    ``cache`` (decode): {"k","v"} [B, Scache, Kp, hd].  ``local`` layers use
    a *ring buffer* of exactly the window size — keys carry RoPE for their
    true positions, so slot order is irrelevant (attention is permutation
    invariant over KV) and no window mask is needed.
    ``return_state`` (prefill): returns this segment's fresh {"k","v"}.
    """
    hp = head_plan(cfg, tp)
    Hp, Kp, hd = hp["Hp"], hp["Kp"], cfg.head_dim
    B, S, D = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,da->bsa", h, p["wq"].astype(cdt)).reshape(B, S, Hp, hd)
    k = jnp.einsum("bsd,da->bsa", h, p["wk"].astype(cdt)).reshape(B, S, Kp, hd)
    v = jnp.einsum("bsd,da->bsa", h, p["wv"].astype(cdt)).reshape(B, S, Kp, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = attn_mod.apply_rope(q, positions, cfg.rope_theta)
    k = attn_mod.apply_rope(k, positions, cfg.rope_theta)
    if cfg.constrain_attn:
        q = _constrain(rules, q, ("batch", "seq", "heads", "head_dim"))
    window = cfg.window_size if kind == "local" else 0

    def expand(kx, vx, full=False):
        """Expand KV heads to the padded q-head count.

        ``full`` (train/prefill): ALWAYS expand, so the attention einsums
        see one head axis of size Hp (divisible by tp).  The grouped
        (kv, g) factorisation leaves neither factor divisible by the model
        axis for most archs (yi: 4 x 8 vs tp=16) and GSPMD then replicates
        the f32 score tensors.  Decode keeps the grouped layout: its cache
        is sequence-sharded by the rules, so heads need not shard.
        """
        if hp["mode"] == "expand_kv":
            idx = _kv_index(cfg, Hp)
            return jnp.take(kx, idx, axis=2), jnp.take(vx, idx, axis=2)
        if full and Kp != Hp:
            return (jnp.repeat(kx, Hp // Kp, axis=2),
                    jnp.repeat(vx, Hp // Kp, axis=2))
        return kx, vx

    state = None
    if cache is not None:
        # Decode: append one token to the cache, attend over it.  cache_len
        # may be scalar (lockstep decode shapes) or [B] (continuous batching:
        # every serving slot has its own length).
        ck, cv = cache["k"], cache["v"]
        size = ck.shape[1]
        slot = jnp.mod(cache_len, size) if kind == "local" else cache_len
        if jnp.ndim(cache_len) == 0:
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), slot, axis=1)
        else:
            bidx = jnp.arange(B)
            ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
        valid = jnp.minimum(cache_len + S, size)
        k_att, v_att = expand(ck, cv)
        out = attn_mod.decode_attention(q, k_att, v_att, valid)
        state = {"k": ck, "v": cv}
    else:
        k_att, v_att = expand(k, v, full=True)
        if cfg.constrain_attn:
            k_att = _constrain(rules, k_att,
                               ("batch", "seq", "heads", "head_dim"))
            v_att = _constrain(rules, v_att,
                               ("batch", "seq", "heads", "head_dim"))
        out = attn_mod.attention(
            q, k_att, v_att, causal=True, window=window,
            q_chunk=cfg.attn_q_chunk, unroll=cfg.unroll_scans,
        )
        if return_state:
            state = {"k": k, "v": v}
    if cfg.constrain_attn:
        out = _constrain(rules, out, ("batch", "seq", "heads", "head_dim"))
    out = out.reshape(B, S, Hp * hd)
    out = jnp.einsum("bsa,ad->bsd", out, p["wo"].astype(cdt))
    return out.astype(x.dtype), state


def apply_block(cfg, kind, p, x, positions, *, tp=1, rules=None,
                cache=None, cache_len=None, return_state=False):
    """One residual block of the given kind.  Returns (x, new_cache, aux)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    aux: dict[str, jax.Array] = {}
    new_cache = None
    if kind in ATTN_KINDS:
        attn_out, new_kv = _attention_part(
            cfg, p, x, positions, kind=kind, tp=tp, rules=rules,
            cache=cache, cache_len=cache_len, return_state=return_state,
        )
        x = x + attn_out
        if kind == "moe":
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            moe_out, aux = moe_mod.moe_ffn(
                h, p["moe"], num_experts=cfg.num_experts,
                top_k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor, compute_dtype=cdt,
                dispatch=cfg.moe_dispatch,
            )
            x = x + moe_out
        elif cfg.d_ff > 0:
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                           p["mlp"]["w_down"], cdt).astype(x.dtype)
        new_cache = new_kv
    elif kind == "rec":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        rec_out, rec_state = rec_mod.recurrent_block(
            p["rec"], h, compute_dtype=cdt, state=cache,
        )
        x = x + rec_out
        if cfg.d_ff > 0:
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                           p["mlp"]["w_down"], cdt).astype(x.dtype)
        new_cache = rec_state
    elif kind == "mlstm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        out, st = xlstm_mod.mlstm_block(
            p["core"], h, heads=cfg.num_heads, compute_dtype=cdt, state=cache,
            unroll=cfg.unroll_scans,
        )
        x = x + out
        new_cache = st
    elif kind == "slstm":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        out, st = xlstm_mod.slstm_block(
            p["core"], h, heads=cfg.num_heads, compute_dtype=cdt, state=cache,
        )
        x = x + out
        new_cache = st
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    x = _constrain(rules, x, ("batch", "seq_sp", "d_model"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Pattern iteration: scan over full periods, unroll the remainder
# ---------------------------------------------------------------------------


def _period_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...], dict]:
    """(n_full_periods, period, per-type counts inside one period)."""
    period = cfg.layer_pattern
    n_full = cfg.num_layers // len(period)
    per = {}
    for k in period:
        per[k] = per.get(k, 0) + 1
    return n_full, period, per


def _tree_slice(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def forward_hidden(cfg: ModelConfig, params, tokens, *, tp=1, rules=None,
                   extra_embeds=None):
    """Full-sequence forward to final hidden states (train / prefill body).

    ``extra_embeds`` ([B, F, D]) replace the first F token positions (VLM
    patch / audio frame stub inputs).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt) * math.sqrt(cfg.d_model)
    if extra_embeds is not None:
        F = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(cdt), x[:, F:]], axis=1)
    x = _constrain(rules, x, ("batch", "seq_sp", "d_model"))
    B, S = tokens.shape
    positions = jnp.arange(S)

    n_full, period, per = _period_layout(cfg)
    aux_total: dict[str, jax.Array] = {}

    def block_with_remat(kind):
        fn = lambda p, x: apply_block(  # noqa: E731
            cfg, kind, p, x, positions, tp=tp, rules=rules
        )
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=_remat_policy(cfg))
        return fn

    def period_body(carry, pslices):
        x, aux_acc = carry
        cursor = {k: 0 for k in per}
        for kind in period:
            p = _tree_slice(pslices[kind], cursor[kind])
            cursor[kind] += 1
            x, _c, aux = block_with_remat(kind)(p, x)
            for k2, v2 in aux.items():
                aux_acc = {**aux_acc, k2: aux_acc.get(k2, 0.0) + v2}
        return (x, aux_acc), None

    aux0 = {k: jnp.zeros((), jnp.float32)
            for k in ("moe_lb_loss", "moe_z_loss", "moe_drop_fraction")} \
        if "moe" in per else {}

    if cfg.scan_layers and n_full > 1:
        period_stacks = {
            kind: jax.tree.map(
                lambda a: a[: n_full * per[kind]].reshape(
                    (n_full, per[kind]) + a.shape[1:]
                ),
                params["blocks"][kind],
            )
            for kind in per
        }
        (x, aux_total), _ = jax.lax.scan(
            period_body, (x, aux0), period_stacks
        )
    else:
        cursor = {k: 0 for k in per}
        aux_total = dict(aux0)
        for _ in range(n_full):
            for kind in period:
                p = _tree_slice(params["blocks"][kind], cursor[kind])
                cursor[kind] += 1
                x, _c, aux = block_with_remat(kind)(p, x)
                for k2, v2 in aux.items():
                    aux_total[k2] = aux_total.get(k2, 0.0) + v2

    # Remainder layers (pattern prefix), always unrolled.
    rem = cfg.num_layers - n_full * len(period)
    if rem:
        cursor2 = {k: n_full * per.get(k, 0) for k in params["blocks"]}
        for kind in period[:rem]:
            p = _tree_slice(params["blocks"][kind], cursor2[kind])
            cursor2[kind] += 1
            x, _c, aux = block_with_remat(kind)(p, x)
            for k2, v2 in aux.items():
                aux_total[k2] = aux_total.get(k2, 0.0) + v2

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def lm_head_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(cfg: ModelConfig, params, batch, *, tp=1, rules=None):
    """Mean CE over next-token targets + MoE aux losses."""
    x, aux = forward_hidden(
        cfg, params, batch["tokens"], tp=tp, rules=rules,
        extra_embeds=batch.get("extra_embeds"),
    )
    ce = chunked_cross_entropy(
        x, lm_head_weight(cfg, params), batch["targets"],
        vocab_size=cfg.vocab_size, seq_chunk=cfg.loss_seq_chunk,
        softcap=cfg.logit_softcap,
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_scans,
    )
    loss = ce
    metrics = {"ce_loss": ce}
    if "moe_lb_loss" in aux:
        loss = loss + 0.01 * aux["moe_lb_loss"] + 0.001 * aux["moe_z_loss"]
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


def logits_from_hidden(cfg, params, x):
    return lm_logits(x, lm_head_weight(cfg, params),
                     jnp.dtype(cfg.compute_dtype), cfg.logit_softcap)


# ---------------------------------------------------------------------------
# KV-cache / state decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1,
               dtype=None) -> dict:
    """Allocation-free cache description: leaf -> (shape, dtype, logical
    axes, fill value).  Single source of truth for ``init_cache`` and the
    dry-run structs (which must NEVER materialise multi-TB caches)."""
    if dtype is None:
        dtype = jnp.dtype(cfg.compute_dtype)
    hp = head_plan(cfg, tp)
    width = cfg.rnn_width or cfg.d_model
    xw = cfg.num_heads * cfg.head_dim  # xlstm inner width
    hd = xw // cfg.num_heads
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    spec: dict[str, Any] = {}
    for kind, n in cfg.layer_counts().items():
        if kind in ATTN_KINDS:
            # ``local`` layers ring-buffer exactly ``window`` slots: every
            # resident token is then within the window of the current query
            # and no window mask is needed (keys carry true-position RoPE).
            seq = max_seq if kind != "local" else min(max_seq, cfg.window_size)
            shp = (n, batch, seq, hp["Kp"], cfg.head_dim)
            spec[kind] = {"k": (shp, dtype, kv_axes, 0.0),
                          "v": (shp, dtype, kv_axes, 0.0)}
        elif kind == "rec":
            spec[kind] = {
                "h": ((n, batch, width), jnp.float32,
                      ("layers", "batch", "rnn_state"), 0.0),
                "conv": ((n, batch, cfg.conv1d_width - 1, width), dtype,
                         ("layers", "batch", None, "rnn_state"), 0.0),
            }
        elif kind == "mlstm":
            spec[kind] = {
                "conv": ((n, batch, 3, xw), dtype,
                         ("layers", "batch", None, "rnn_state"), 0.0),
                "C": ((n, batch, cfg.num_heads, hd, hd), jnp.float32,
                      ("layers", "batch", "heads", None, None), 0.0),
                "n": ((n, batch, cfg.num_heads, hd), jnp.float32,
                      ("layers", "batch", "heads", None), 0.0),
                "m": ((n, batch, cfg.num_heads), jnp.float32,
                      ("layers", "batch", "heads"), -1e30),
            }
        elif kind == "slstm":
            st = ((n, batch, cfg.num_heads, hd), jnp.float32,
                  ("layers", "batch", "heads", None))
            spec[kind] = {"c": st + (0.0,), "n": st + (1.0,),
                          "m": st + (0.0,), "h": st + (0.0,)}
    return spec


def _is_spec_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 4 and isinstance(x[0], tuple)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1,
               dtype=None) -> dict:
    """Decode state per layer type, stacked over that type's layer count."""
    spec = cache_spec(cfg, batch, max_seq, tp, dtype)
    return jax.tree.map(
        lambda s: jnp.full(s[0], s[3], s[1]), spec, is_leaf=_is_spec_leaf
    )


def _cache_kind_state(cache_slice, kind):
    if cache_slice is None:
        return None
    if kind in ATTN_KINDS:
        return cache_slice
    if kind == "rec":
        return {"h": cache_slice["h"], "conv": cache_slice["conv"]}
    if kind == "mlstm":
        return (cache_slice["conv"],
                (cache_slice["C"], cache_slice["n"], cache_slice["m"]))
    if kind == "slstm":
        return (cache_slice["c"], cache_slice["n"], cache_slice["m"],
                cache_slice["h"])
    raise ValueError(kind)


def _state_to_cache(state, kind):
    if kind in ATTN_KINDS:
        return state
    if kind == "rec":
        return {"h": state["h"], "conv": state["conv"]}
    if kind == "mlstm":
        conv, (C, n, m) = state
        return {"conv": conv, "C": C, "n": n, "m": m}
    if kind == "slstm":
        c, n, m, h = state
        return {"c": c, "n": n, "m": m, "h": h}
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_len,
                *, tp=1, rules=None):
    """One decode step. tokens: [B, 1]; cache_len: scalar int32 (tokens
    already in the cache).  Returns (logits [B, 1, Vp], new_cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt) * math.sqrt(cfg.d_model)
    x = _constrain(rules, x, ("batch", "seq_sp", "d_model"))
    if jnp.ndim(cache_len) == 0:
        positions = jnp.reshape(cache_len, (1,)) + jnp.arange(1)
    else:
        positions = cache_len[:, None]  # [B, 1] per-slot positions

    n_full, period, per = _period_layout(cfg)

    def run_layer(kind, p, cslice, x):
        state = _cache_kind_state(cslice, kind)
        x, st, _aux = apply_block(
            cfg, kind, p, x, positions, tp=tp, rules=rules,
            cache=state, cache_len=cache_len,
        )
        return x, _state_to_cache(st, kind)

    if cfg.scan_layers and n_full > 1:
        # Scan over full periods: the per-layer cache slices travel as scan
        # xs and the updated slices return as ys (compact HLO — no
        # whole-stack copies per layer).
        def reshape_periods(tree, count):
            return jax.tree.map(
                lambda a: a[: n_full * count].reshape(
                    (n_full, count) + a.shape[1:]),
                tree,
            )

        param_stacks = {k: reshape_periods(params["blocks"][k], per[k])
                        for k in per}
        cache_stacks = {k: reshape_periods(cache[k], per[k]) for k in per}

        def period_body(x, inp):
            pslices, cslices = inp
            cursor = {k: 0 for k in per}
            upd: dict[str, list] = {k: [] for k in per}
            for kind in period:
                i = cursor[kind]
                cursor[kind] += 1
                x, new_slice = run_layer(
                    kind, _tree_slice(pslices[kind], i),
                    _tree_slice(cslices[kind], i), x,
                )
                upd[kind].append(new_slice)
            stacked = {
                k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                for k, v in upd.items()
            }
            # preserve cache dtypes
            stacked = {
                k: jax.tree.map(lambda n, o: n.astype(o.dtype), stacked[k],
                                _tree_slice(cslices[k], slice(None)))
                for k in stacked
            }
            return x, stacked

        x, scanned = jax.lax.scan(period_body, x, (param_stacks, cache_stacks))
        new_cache = {
            k: jax.tree.map(
                lambda a: a.reshape((n_full * per[k],) + a.shape[2:]),
                scanned[k],
            )
            for k in per
        }
        rem = cfg.num_layers - n_full * len(period)
        if rem:
            cursor2 = {k: n_full * per.get(k, 0) for k in cache}
            # append remainder slices (unrolled)
            tails: dict[str, list] = {k: [] for k in period[:rem]}
            for kind in period[:rem]:
                i = cursor2[kind]
                cursor2[kind] += 1
                x, new_slice = run_layer(
                    kind, _tree_slice(params["blocks"][kind], i),
                    _tree_slice(cache[kind], i), x,
                )
                tails[kind].append(new_slice)
            for kind, slices in tails.items():
                tail = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
                new_cache[kind] = jax.tree.map(
                    lambda a, t: jnp.concatenate(
                        [a, t.astype(a.dtype)], axis=0),
                    new_cache[kind], tail,
                )
    else:
        new_cache = {k: dict(v) for k, v in cache.items()}
        counters = {k: 0 for k in cfg.layer_counts()}
        for kind in cfg.pattern_for_layers:
            i = counters[kind]
            counters[kind] += 1
            x, upd = run_layer(
                kind, _tree_slice(params["blocks"][kind], i),
                _tree_slice(cache[kind], i), x,
            )
            for leaf_key, leaf_val in upd.items():
                new_cache[kind][leaf_key] = new_cache[kind][leaf_key].at[i].set(
                    leaf_val.astype(new_cache[kind][leaf_key].dtype)
                )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params, tokens, max_seq, *, tp=1, rules=None):
    """Run the full prompt, returning (last-token logits, filled cache).

    Every block returns its terminal state (``return_state=True``): K/V for
    attention kinds (written ring-consistently for ``local``), recurrent
    state for rec/mlstm/slstm.  Used by the serving engine; the dry-run
    lowers ``prefill_32k`` through the full forward instead.
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_seq, tp)
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt) * math.sqrt(cfg.d_model)
    positions = jnp.arange(S)
    counters = {k: 0 for k in cfg.layer_counts()}
    for kind in cfg.pattern_for_layers:
        i = counters[kind]
        counters[kind] += 1
        p = _tree_slice(params["blocks"][kind], i)
        x, st, _aux = apply_block(cfg, kind, p, x, positions,
                                  tp=tp, rules=rules, return_state=True)
        if kind in ATTN_KINDS:
            kk = cache[kind]["k"]
            size = kk.shape[2]
            nfit = min(S, size)
            tail_pos = jnp.arange(S - nfit, S)
            slots = jnp.mod(tail_pos, size) if kind == "local" else tail_pos
            cache[kind]["k"] = kk.at[i, :, slots].set(
                jnp.moveaxis(st["k"][:, -nfit:], 1, 0).astype(kk.dtype))
            cache[kind]["v"] = cache[kind]["v"].at[i, :, slots].set(
                jnp.moveaxis(st["v"][:, -nfit:], 1, 0).astype(kk.dtype))
        else:
            upd = _state_to_cache(st, kind)
            for leaf_key, leaf_val in upd.items():
                cache[kind][leaf_key] = cache[kind][leaf_key].at[i].set(
                    leaf_val.astype(cache[kind][leaf_key].dtype))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    return logits, cache
