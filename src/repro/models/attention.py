"""Attention: GQA with RoPE, blockwise (memory-bounded) softmax, sliding
windows, KV-cache decode with sequence-sharded caches.

Three execution paths, one semantics (tested against each other):

* ``attention_reference`` — plain O(S^2) jnp, the oracle;
* ``attention_blockwise`` — lax.scan over query chunks with running
  (max, denominator) accumulation: never materialises an S x S tensor, so
  remat + long prefill stay within HBM.  This is the XLA path used by the
  dry-run; the Pallas flash kernel (``repro.kernels.flash_attention``)
  implements the same tiling for the TPU target;
* ``decode_attention`` — single-token attention against a cache whose
  sequence axis may be sharded (FlashDecoding-style: XLA inserts the tiny
  max/sum all-reduces when the sharding rules put ``kv_seq`` on a mesh axis).

Shapes follow the [B, S, H, D] convention; GQA folds q heads into
``(kv_heads, q_per_kv)`` groups for the einsums.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] or [S]."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B?, S, half]
    if angles.ndim == 2:  # [S, half] -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]  # [B, S, 1, half]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference attention (oracle)
# ---------------------------------------------------------------------------


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D].  Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    if logit_softcap > 0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (flash-style in XLA; memory O(chunk * S))
# ---------------------------------------------------------------------------


def attention_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_chunk: int = 512,
    q_offset: int = 0,
    unroll: bool = False,
) -> jax.Array:
    """Scan over query chunks; softmax with running max/denominator.

    For ``window > 0`` only a fixed-size KV slice (window + chunk, dynamic
    start) is touched per query chunk, making sliding-window layers
    O(S * window) in both FLOPs and memory.  ``unroll`` replaces the scan
    with a python loop (exact XLA cost_analysis; roofline probes only).
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk != 0:
        raise ValueError(f"Sq={Sq} not divisible by q_chunk={q_chunk}")
    n_chunks = Sq // q_chunk
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, n_chunks, q_chunk, KV, G, D)
    kv_pos_full = jnp.arange(Skv)

    use_window_slice = window > 0 and Skv > (window + q_chunk)
    slice_len = min(Skv, window + q_chunk) if window > 0 else Skv

    def chunk_body(carry, inputs):
        del carry
        ci, q_i = inputs  # q_i: [B, q_chunk, KV, G, D]
        q_start = ci * q_chunk + q_offset
        q_pos = q_start + jnp.arange(q_chunk)
        if use_window_slice:
            # KV slice covering [q_start - window + 1, q_start + q_chunk).
            start = jnp.clip(q_start + q_chunk - slice_len, 0, Skv - slice_len)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, slice_len, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, slice_len, axis=1)
            kv_pos = start + jnp.arange(slice_len)
        else:
            k_i, v_i, kv_pos = k, v, kv_pos_full
        scores = (
            jnp.einsum(
                "bqkgd,bskd->bkgqs",
                q_i.astype(jnp.float32),
                k_i.astype(jnp.float32),
            )
            * scale
        )
        if logit_softcap > 0:
            scores = logit_softcap * jnp.tanh(scores / logit_softcap)
        mask = jnp.ones((q_chunk, kv_pos.shape[0]), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m = jnp.max(scores, axis=-1, keepdims=True)
        # Rows fully masked (can happen for padded heads) -> max == NEG_INF.
        m = jnp.maximum(m, -1e29)
        p = jnp.exp(scores - m)
        denom = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p, v_i.astype(jnp.float32))
        o = o / jnp.maximum(denom, 1e-30)
        return None, o.astype(q.dtype)  # [B, KV, G, q_chunk, D]

    if unroll:
        outs = jnp.stack(
            [chunk_body(None, (ci, qg[:, ci]))[1] for ci in range(n_chunks)]
        )
    else:
        _, outs = jax.lax.scan(
            chunk_body,
            None,
            (jnp.arange(n_chunks), jnp.moveaxis(qg, 1, 0)),
        )
    # outs: [n_chunks, B, KV, G, q_chunk, D] -> [B, Sq, H, D]
    out = jnp.moveaxis(outs, 0, 3)  # [B, KV, G, n_chunks, q_chunk, D]
    out = out.reshape(B, KV, G, Sq, D)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, D)
    return out


# ---------------------------------------------------------------------------
# Decode attention (single new token vs cache; cache seq may be sharded)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_len,
    *,
    window: int = 0,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """q: [B, 1, H, D]; cache_k/v: [B, Skv, KV, D]; cache_len: scalar or [B].

    Softmax reduces over the (possibly sharded) cache sequence axis; under
    sequence sharding XLA emits small all-reduces for the max/denominator
    and the weighted-value sum — the FlashDecoding pattern.
    """
    B, _, H, D = q.shape
    Skv, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), cache_k.astype(jnp.float32)
    ) / math.sqrt(D)
    if logit_softcap > 0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    kv_pos = jnp.arange(Skv)
    valid = kv_pos[None] < jnp.reshape(cache_len, (-1, 1))  # [B, Skv]
    if window > 0:
        valid &= kv_pos[None] >= jnp.reshape(cache_len, (-1, 1)) - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cache_v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention(
    q, k, v, *, causal=True, window=0, logit_softcap=0.0, q_chunk=512,
    q_offset=0, unroll=False,
):
    """Dispatch: blockwise when the chunking pays, reference otherwise."""
    Sq = q.shape[1]
    if Sq % q_chunk != 0:  # ragged tail (e.g. serving prefill): best divisor
        q_chunk = max(
            (d for d in range(1, q_chunk + 1) if Sq % d == 0), default=1
        )
    if Sq <= q_chunk or q_chunk == 1:
        return attention_reference(
            q, k, v, causal=causal, window=window,
            logit_softcap=logit_softcap, q_offset=q_offset,
        )
    return attention_blockwise(
        q, k, v, causal=causal, window=window,
        logit_softcap=logit_softcap, q_chunk=q_chunk, q_offset=q_offset,
        unroll=unroll,
    )
