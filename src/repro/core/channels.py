"""Typed channels and automatic sharding derivation.

Paper requirement 4: *"define and build application network interconnections
with no user intervention"*.  In the JCSP original this meant constructing
net-channel addresses (ip:port/channel) between processes.  In the SPMD
adaptation the "interconnections" are XLA collectives, which are induced by
the shardings of every tensor flowing between (virtual) nodes — so the
builder's job becomes: derive a sound ``PartitionSpec`` for every tensor from
*logical axis names* alone.  Users annotate tensors with names like
``("batch", "seq", "d_model")``; they never write a ``PartitionSpec`` (the
analogue of never writing a channel address).

Derivation walks an ordered rule table (first applicable rule wins) with two
soundness checks per dimension:

* **divisibility** — the dimension size must divide evenly over the mesh axes
  (no silent GSPMD padding; padded archs are handled explicitly upstream via
  :func:`padded_size`);
* **exclusivity** — a mesh axis may shard at most one dimension of a tensor.

Fallback entries in the table make the derivation total: e.g. a KV cache with
8 KV heads on a 16-way model axis falls through ``kv_heads -> model`` to
``kv_seq -> model`` (FlashDecoding-style sequence sharding), which is exactly
the re-wiring a human expert would do.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# A rule maps a logical axis name to a tuple of mesh axis names (applied
# together, e.g. ("pod", "data") for global data parallelism) or to None
# (replicate).  Rules earlier in the table take priority.
Rule = tuple[str, tuple[str, ...] | None]


@dataclass(frozen=True)
class Channel:
    """A typed channel: the unit the builder wires between stages.

    Mirrors the paper's net channel (named, typed, single-reader); ``shape``
    and ``dtype`` replace the serialised object class, ``logical_axes``
    replaces the address — the builder resolves it to a physical placement.
    """

    name: str
    shape: tuple[int, ...]
    dtype: Any
    logical_axes: tuple[str | None, ...]

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"channel {self.name!r}: shape {self.shape} and logical axes "
                f"{self.logical_axes} have different ranks"
            )


class ShardingRules:
    """Ordered logical-axis -> mesh-axes rule table bound to a mesh."""

    def __init__(self, mesh: Mesh, rules: Sequence[Rule]):
        self.mesh = mesh
        self.axis_sizes: dict[str, int] = dict(
            zip(mesh.axis_names, np.shape(mesh.devices))
        )
        # Keep only mesh axes that exist (lets one table serve single- and
        # multi-pod meshes: ("pod","data") degrades to ("data",) off-pod).
        self.rules: list[Rule] = []
        for name, axes in rules:
            if axes is None:
                self.rules.append((name, None))
            else:
                kept = tuple(a for a in axes if a in self.axis_sizes)
                self.rules.append((name, kept if kept else None))

    # -- core derivation -----------------------------------------------------

    def partition_spec(
        self,
        shape: Sequence[int],
        logical_axes: Sequence[str | None],
    ) -> P:
        if len(shape) != len(logical_axes):
            raise ValueError(f"rank mismatch: {shape} vs {logical_axes}")
        used: set[str] = set()
        entries: list[Any] = []
        for size, name in zip(shape, logical_axes):
            entries.append(self._dim_axes(size, name, used))
        # Trim trailing None entries (canonical PartitionSpec form).
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def _dim_axes(
        self, size: int, name: str | None, used: set[str]
    ) -> tuple[str, ...] | str | None:
        if name is None:
            return None
        for rule_name, axes in self.rules:
            if rule_name != name:
                continue
            if axes is None:
                return None
            if any(a in used for a in axes):
                continue
            prod = math.prod(self.axis_sizes[a] for a in axes)
            if prod == 0 or size % prod != 0:
                continue
            used.update(axes)
            return axes if len(axes) > 1 else axes[0]
        return None  # no applicable rule: replicate (always sound)

    def sharding(self, channel_or_shape, logical_axes=None) -> NamedSharding:
        if isinstance(channel_or_shape, Channel):
            spec = self.partition_spec(
                channel_or_shape.shape, channel_or_shape.logical_axes
            )
        else:
            spec = self.partition_spec(channel_or_shape, logical_axes)
        return NamedSharding(self.mesh, spec)

    def struct(self, channel: Channel) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct stand-in (dry-run input: no allocation)."""
        return jax.ShapeDtypeStruct(
            channel.shape, channel.dtype, sharding=self.sharding(channel)
        )

    def constraint(self, x, logical_axes: Sequence[str | None]):
        """``with_sharding_constraint`` via logical names (models use this)."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(tuple(x.shape), tuple(logical_axes))
        )

    # -- diagnostics ----------------------------------------------------------

    def describe(self, channels: Sequence[Channel]) -> str:
        lines = [f"{'channel':<28}{'shape':<28}{'partition spec'}"]
        for ch in channels:
            spec = self.partition_spec(ch.shape, ch.logical_axes)
            lines.append(f"{ch.name:<28}{str(ch.shape):<28}{spec}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Preset rule tables (one per execution shape-kind).
# ---------------------------------------------------------------------------

def _common_weight_rules() -> list[Rule]:
    return [
        # Tensor parallelism: feature/head/expert dims over the model axis.
        ("vocab", ("model",)),
        ("d_ff", ("model",)),
        ("d_attn", ("model",)),  # flattened q heads * head_dim (projections)
        ("d_kv_attn", ("model",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("experts", ("model",)),
        ("rnn_state", ("model",)),
        # FSDP (ZeRO-3): the non-TP dim of every weight over the data axes.
        ("d_model_fsdp", ("pod", "data")),
        ("d_model_fsdp", ("data",)),
        ("layers", None),
        ("head_dim", None),
    ]


def training_rules(mesh: Mesh) -> ShardingRules:
    """train_4k / prefill_32k: batch over (pod, data), TP over model.

    ``seq_sp`` is the *residual-stream* sequence axis (the tensor carried
    between blocks and saved for backward): sharding it over the model axis
    is Megatron-style sequence parallelism — XLA turns the block-boundary
    all-reduce into reduce-scatter + all-gather (same bytes) while the saved
    activations shrink by the TP degree.  Attention-internal ``seq`` stays
    unsharded (full context per shard).
    """
    return ShardingRules(
        mesh,
        [
            ("batch", ("pod", "data")),
            ("batch", ("data",)),
            ("seq_sp", ("model",)),
            ("seq", None),
            ("d_model", None),  # activations replicated on feature dim
        ]
        + _common_weight_rules(),
    )


def decode_rules(mesh: Mesh) -> ShardingRules:
    """decode_32k: batch over (pod, data); KV heads over model when they
    divide, otherwise KV *sequence* over model (FlashDecoding split)."""
    return ShardingRules(
        mesh,
        [
            ("batch", ("pod", "data")),
            ("batch", ("data",)),
            ("kv_seq", ("model",)),  # consumed only if kv_heads didn't take it
            ("seq", None),
            ("d_model", None),
        ]
        + _common_weight_rules(),
    )


def long_context_rules(mesh: Mesh) -> ShardingRules:
    """long_500k: batch==1 is unshardable; the KV cache / state shards over
    (data, model) sequence-wise — the whole pod serves one stream."""
    return ShardingRules(
        mesh,
        [
            ("batch", None),
            ("kv_seq", ("data", "model")),
            ("kv_seq", ("data",)),
            ("seq", None),
            ("d_model", None),
        ]
        + _common_weight_rules(),
    )


def rules_for_shape_kind(mesh: Mesh, kind: str) -> ShardingRules:
    if kind in ("train", "prefill"):
        return training_rules(mesh)
    if kind == "decode":
        return decode_rules(mesh)
    if kind == "long":
        return long_context_rules(mesh)
    raise ValueError(f"unknown shape kind {kind!r}")


# ---------------------------------------------------------------------------
# Padding helpers (automatic vocab/head padding — builder, not user, pads).
# ---------------------------------------------------------------------------

def padded_size(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= n."""
    if multiple <= 0:
        raise ValueError("multiple must be positive")
    return ((n + multiple - 1) // multiple) * multiple


def pad_axis_to(x, size: int, axis: int):
    """Zero-pad ``x`` along ``axis`` to ``size`` (no-op when already there)."""
    import jax.numpy as jnp

    cur = x.shape[axis]
    if cur == size:
        return x
    if cur > size:
        raise ValueError(f"cannot pad axis {axis} from {cur} down to {size}")
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - cur)
    return jnp.pad(x, pads)
