"""Quickstart — the paper's own example, end to end, in both spec APIs.

Part 1 builds the Mandelbrot application from a textual ``.cgpp``
specification (Listing 2 of the paper), verifies the deployment formally
(section 7), prints the generated deployment plan (section 4 / figure 1),
runs it on the chosen backend and reports the paper's counts + per-node
timing (requirement 7).

Part 2 builds the same workload as a *two-stage pipeline* with the fluent
Python API — Mandelbrot lines rendered by stage 1, reduced per line by
stage 2 — and runs it on the same backend: the generalised spec layer with
the paper's network as its one-stage special case.

Run:  PYTHONPATH=src python examples/quickstart.py
      PYTHONPATH=src python examples/quickstart.py cluster   # real subprocesses

Instance sizes are env-tunable (CI's examples-smoke job shrinks them):
QUICKSTART_WIDTH / QUICKSTART_LINES / QUICKSTART_ITERS.
"""

import os
import sys

import jax.numpy as jnp

from repro.core.builder import ClusterBuilder
from repro.core.dsl import Pipeline, parse_cgpp
from repro.core.processes import EmitDetails, ResultDetails
from repro.core.verify import verify_spec
from repro.kernels.mandelbrot.ops import mandelbrot
from repro.kernels.mandelbrot.ref import line_coords

WIDTH = int(os.environ.get("QUICKSTART_WIDTH", "700"))    # paper: 5600
LINES = int(os.environ.get("QUICKSTART_LINES", "400"))    # paper: 3200
MAX_ITERATIONS = int(os.environ.get("QUICKSTART_ITERS", "250"))  # paper: 1000

SPEC = """
# Mandelbrot DSL specification (paper Listing 2), python-flavoured .cgpp
cores = 4
clusters = 2
max_iterations = %(iters)d
width = %(width)d

//@emit 192.168.1.176
emit_details = DataDetails(
    name="Mdata",
    init=lambda width, iters: (0, %(lines)d),
    init_data=(width, max_iterations),
    create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
)
emit = Emit(e_details=emit_details)
onrl = OneNodeRequestedList()

//@cluster clusters
nrfa = NodeRequestingFanAny(destinations=cores)
group = AnyGroupAny(workers=cores, function=CALCULATE)
afoc = AnyFanOne(sources=cores)

//@collect
result_details = ResultDetails(
    name="Mcollect",
    init=lambda: dict(points=0, white=0, black=0, total_iters=0),
    collect=COLLECTOR,
    finalise=lambda acc: acc,
)
afo = AnyFanOne(sources=clusters)
collector = Collect(r_details=result_details)
"""


def calculate(line_y: int):
    """The user's sequential data method (paper Mdata.calculateColour)."""
    x0, y0 = line_coords(WIDTH, line_y)
    iters, colour = mandelbrot(x0[None], y0[None], max_iters=MAX_ITERATIONS)
    return {
        "points": WIDTH,
        "white": int(jnp.sum(colour)),
        "total_iters": int(jnp.sum(iters)),
    }


def collector(acc, item):
    acc["points"] += item["points"]
    acc["white"] += item["white"]
    acc["black"] += item["points"] - item["white"]
    acc["total_iters"] += item["total_iters"]
    return acc


def reduce_line(item):
    """Stage-2 work: collapse one line's stats into a compact record."""
    return (item["points"], item["white"], item["total_iters"])


def fluent_pipeline_demo(backend: str) -> None:
    """The same workload as a two-stage pipeline via the fluent API."""
    lines = max(LINES // 4, 8)  # a smaller instance: this is the API demo

    emit = EmitDetails(
        name="Mdata",
        init=lambda n: (0, n),
        init_data=(lines,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )

    def fold(acc, item):
        points, white, iters = item
        acc["points"] += points
        acc["white"] += white
        acc["black"] += points - white
        acc["total_iters"] += iters
        return acc

    spec = (Pipeline(host="192.168.1.176")
            .emit(emit)
            .stage(calculate, nodes=2, workers=2, name="render")
            .stage(reduce_line, nodes=1, workers=1, name="reduce")
            .collect(ResultDetails(
                name="Mcollect",
                init=lambda: dict(points=0, white=0, black=0, total_iters=0),
                collect=fold,
            ))
            .build())
    print(f"fluent pipeline: "
          + " -> ".join(f"{st.name}[{st.nclusters}x{st.workers_per_node}]"
                        for st in spec.stages))

    report = verify_spec(spec)
    print(report.summary(), "\n")
    assert report.ok, "the chained network must verify like the single hop"

    builder = ClusterBuilder()
    app = builder.build_application(spec, backend=backend)
    result = app.run()
    print(f"{result['points']}, {result['white']}, {result['black']}, "
          f"{result['total_iters']}")
    assert result["points"] == lines * WIDTH


def main() -> None:
    spec = parse_cgpp(
        SPEC % {"iters": MAX_ITERATIONS, "width": WIDTH, "lines": LINES},
        namespace={"CALCULATE": calculate, "COLLECTOR": collector},
    )
    print(f"parsed spec: {spec.nclusters} nodes x {spec.workers_per_node} workers\n")

    report = verify_spec(spec, num_objects=4)
    print(report.summary(), "\n")
    assert report.ok, "deployment must be provably deadlock/livelock free"

    builder = ClusterBuilder()
    print(builder.deployment_plan(spec).describe(), "\n")

    # "cluster" runs the identical spec over real node-loader subprocesses
    # connected by TCP (repro.cluster, paper §4) instead of threads.
    backend = sys.argv[1] if len(sys.argv) > 1 else "threads"
    app = builder.build_application(spec, backend=backend)
    result = app.run()
    # paper prints: points, whiteCount, blackCount, totalIters
    print(f"{result['points']}, {result['white']}, {result['black']}, "
          f"{result['total_iters']}")
    print()
    print(builder.timing.report())

    print("\n--- fluent two-stage pipeline (same workload, generalised "
          "spec API) ---\n")
    fluent_pipeline_demo(backend)


if __name__ == "__main__":
    main()
