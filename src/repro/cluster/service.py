"""ClusterService: a persistent warm node pool that runs many jobs.

The paper's deployment is one-shot — boot the cluster, run the farm, tear
everything down — so every run pays the full §8.2 boot/load bill.  This
module keeps the Host-Node-Loader topology *up* between jobs:

* the pool boots **once** (``start()``): launcher fan-out, REGISTER
  barrier, pool-config LOAD — the entire §4 bootstrap, paid exactly once;
* ``submit(spec, ...)`` hands a pipeline to the resident
  :class:`~repro.cluster.host_loader.HostLoader` dispatcher and returns a
  :class:`JobHandle` future immediately — jobs run back-to-back *and*
  concurrently, interleaved over the same nodes with exactly-once
  preserved per job (every wire frame carries its ``job_id``);
* resubmitting a pipeline whose stage functions the nodes still hold in
  their digest-keyed code cache ships no code at all — a warm job pays
  neither boot nor load (``JobHandle.cluster_boot_ms == 0`` and
  ``stats()["code_shipped"] == 0``);
* ``close()`` (or the context manager) terminates the pool: UT to every
  node, timing records collected, launcher resources reclaimed — the same
  no-orphan guarantee as the one-shot application.

Scheduling is FIFO-with-priority: when a node demands work, the dispatcher
answers from the highest-``priority`` admitted job first (ties in
submission order).  The pool's geometry (``nodes`` × ``workers``) is fixed
at boot — a submitted spec's ``nclusters``/``workers`` describe its
*logic*, not a reservation; every pool node serves every stage of every
job.  Likewise per-stage ``prefetch=``/``flush_ms=`` overrides apply to
the one-shot pinned deployment, not to a shared pool (whose data-plane
cadence is a pool property, set here).

``build_application(spec, backend="service")`` wraps this in the standard
application contract (:class:`ServiceClusterApplication`): an ephemeral
pool sized from the spec, or — pass ``service=`` — a caller-owned warm
pool that outlives the application.
"""

from __future__ import annotations

import random
import threading
import time
from typing import Any, Sequence

from repro.cluster.deploy.base import Launcher, NodeHandle, PlacementPolicy
from repro.cluster.host_loader import HostLoader, JobState
from repro.cluster.membership import LAUNCHING
from repro.cluster.telemetry import Telemetry, TelemetryServer
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor

__all__ = ["ClusterService", "JobHandle", "ServiceClusterApplication"]


class JobHandle:
    """A submitted job's future: wait on it, read its result and timings.

    When the submission carried a retry policy (``submit(..., retries=N)``),
    the handle spans *all* attempts: ``done()``/``wait()``/``result()``
    resolve only once the supervisor declares the job final (succeeded, or
    out of retries — the poisoned-job guard), and ``attempts`` /
    ``stats()["attempts"]`` record each attempt's outcome, failure cause,
    implicated node and timing.
    """

    def __init__(self, job: JobState, cluster_boot_ms: float,
                 host_loader: HostLoader | None = None):
        self._job = job
        self._host_loader = host_loader
        #: What this submission paid for cluster boot: the pool's boot time
        #: on the submission that triggered it, ``0.0`` on every warm one.
        self.cluster_boot_ms = cluster_boot_ms
        #: One record per finished attempt (retry submissions only fill
        #: more than one): attempt #, job_id, error, cause, node, timings.
        self.attempts: list[dict[str, Any]] = []
        # Retry mode: the supervisor sets this once no further attempt
        # will run; without retries the job's own event is the signal.
        self._final: threading.Event | None = None

    @property
    def job_id(self) -> int:
        return self._job.job_id

    def _event(self) -> threading.Event:
        return self._final if self._final is not None else self._job.done

    def done(self) -> bool:
        return self._event().is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event().wait(timeout)

    def result(self, timeout: float | None = None) -> Any:
        if not self._event().wait(timeout):
            raise TimeoutError(
                f"job {self._job.job_id} not finished within {timeout}s"
            )
        if self._job.error is not None:
            raise self._job.error
        return self._job.result

    @property
    def error(self) -> BaseException | None:
        return self._job.error

    @property
    def submit_to_first_result_ms(self) -> float | None:
        """Latency from submit() to the first collected result (None until
        one arrives) — the end-to-end figure the warm pool exists to cut."""
        if (self._job.submitted_at is None
                or self._job.first_result_at is None):
            return None
        return (self._job.first_result_at - self._job.submitted_at) * 1e3

    def stats(self) -> dict[str, Any]:
        # Per-node attribution: which pool members did this job's work,
        # whether each got its code warm, and (when the pool is reachable)
        # the node's connection-level wire counters.  Sums reconcile with
        # the job-level figures: sum(items) == items_collected + forwarded,
        # sum(cache_hits) == code_cached, sum(cache_misses) == code_shipped.
        nodes: dict[str, dict[str, Any]] = {}
        for nid, n in self._job.items_by_node.items():
            nodes.setdefault(nid, {})["items"] = n
        for nid, cache in self._job.cache_by_node.items():
            d = nodes.setdefault(nid, {})
            d["cache_hits"] = cache["hits"]
            d["cache_misses"] = cache["misses"]
        if self._host_loader is not None:
            for nid, d in nodes.items():
                rec = self._host_loader.membership.nodes.get(nid)
                if rec is not None and rec.conn is not None:
                    d["wire"] = rec.conn.counters.as_dict()
        # The attempt history always shows at least the current attempt,
        # even mid-flight or without a retry policy, so consumers need not
        # special-case the no-retry path.
        attempts = list(self.attempts)
        if not attempts or attempts[-1]["job_id"] != self._job.job_id:
            attempts.append(_attempt_record(self._job, len(attempts) + 1))
        stats = {
            "job_id": self._job.job_id,
            "priority": self._job.priority,
            "items_collected": self._job.items_collected,
            "duplicates_dropped": self._job.duplicates_dropped,
            "forwarded": self._job.forwarded,
            # Peer data plane: hop items shipped node-to-node vs the
            # payload bytes that still relayed through the host (0 on a
            # fully peer-routed hop — the acceptance figure).
            "peer_forwarded": self._job.peer_forwarded,
            "host_relay_bytes": self._job.host_relay_bytes,
            # Warm-load accounting: stage functions shipped by value vs
            # rebound from the nodes' digest-keyed code caches.
            "code_shipped": self._job.code_shipped,
            "code_cached": self._job.code_cached,
            "cluster_boot_ms": self.cluster_boot_ms,
            "submit_to_first_result_ms": self.submit_to_first_result_ms,
            "nodes": nodes,
            "attempts": attempts,
            "retries": max(0, len(attempts) - 1),
        }
        if self._host_loader is not None:
            # Pool-level healing the job rode through (cluster-wide
            # counters: the pool, not this job alone, was healed).
            stats["respawns"] = self._host_loader.stats.respawns
            stats["heals"] = self._host_loader.stats.heals
        return stats


def _attempt_record(job: JobState, attempt: int) -> dict[str, Any]:
    elapsed_ms = None
    if job.submitted_at is not None and job.ended_at is not None:
        elapsed_ms = round((job.ended_at - job.submitted_at) * 1e3, 3)
    return {
        "attempt": attempt,
        "job_id": job.job_id,
        "done": job.done.is_set(),
        "error": None if job.error is None else str(job.error),
        "error_type": (None if job.error is None
                       else type(job.error).__name__),
        "cause": job.failure_kind,
        "node": job.failed_node,
        "items_collected": job.items_collected,
        "elapsed_ms": elapsed_ms,
    }


class ClusterService:
    """A long-lived node pool multiplexing many jobs (see module docstring).

    Construction is cheap; ``start()`` (or the first ``submit``, or
    entering the context manager) boots the pool.
    """

    def __init__(
        self,
        *,
        nodes: int = 1,
        workers: int = 1,
        launcher: Launcher | None = None,
        hosts: Sequence[str] | None = None,
        bind_host: str = "127.0.0.1",
        port: int = 0,
        heartbeat_interval: float = 0.5,
        heartbeat_misses: int = 10,
        register_timeout: float = 30.0,
        prefetch: int | None = None,
        flush_items: int = 8,
        flush_interval: float = 0.005,
        preload: tuple[str, ...] = (),
        artifacts: dict[str, bytes] | None = None,
        min_nodes: int | None = None,
        max_respawns: int = 0,
        respawn_after: float | None = None,
        allow_late_join: bool = True,
        max_heals: int = 0,
        chaos: Any = None,
        shutdown_grace: float = 10.0,
        timing: TimingCollector | None = None,
        telemetry: Telemetry | None = None,
        trace_path: str | None = None,
        http_host: str = "127.0.0.1",
        http_port: int | None = None,
    ):
        if launcher is not None and hosts is not None:
            raise TypeError("pass either launcher= or hosts=, not both")
        if nodes < 1:
            raise ValueError(f"nodes must be >= 1, got {nodes}")
        self.nodes = nodes
        self.workers = workers
        self.launcher = launcher
        self.hosts = hosts
        self.bind_host = bind_host
        self.port = port
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_misses = heartbeat_misses
        self.register_timeout = register_timeout
        self.prefetch = prefetch
        self.flush_items = flush_items
        self.flush_interval = flush_interval
        self.preload = tuple(preload)
        self.artifacts = dict(artifacts or {})
        self.min_nodes = min_nodes
        self.max_respawns = max_respawns
        self.respawn_after = respawn_after
        self.allow_late_join = allow_late_join
        # Mid-run healing budget: a node dying while jobs run is answered
        # with a replacement launch (0 = shrink to survivors, the
        # historical behaviour).
        self.max_heals = max_heals
        # Optional fault injection: a repro.cluster.chaos.FaultPlan armed
        # against this pool once it is ready (tests, chaos-smoke CI).
        self.chaos = chaos
        self.chaos_controller: Any = None
        self.shutdown_grace = shutdown_grace
        self.timing = timing or TimingCollector()
        # Observability: one bus for the pool's whole life.  ``http_port``
        # None = no endpoint; 0 = an ephemeral port (read ``.http_url``);
        # ``trace_path`` appends every lifecycle event as one JSON line.
        self.telemetry = telemetry or Telemetry(trace_path=trace_path)
        self.http_host = http_host
        self.http_port = http_port
        self.http_server: TelemetryServer | None = None

        self.host_loader: HostLoader | None = None
        self.handles: dict[str, NodeHandle] = {}
        # Elastic growth: the next fresh node id (``grow()`` continues the
        # ``node<i>`` sequence past the boot-time pool).
        self._node_seq = nodes
        self.boot_ms: float | None = None
        self._boot_charged = False
        self._stop = threading.Event()
        self._serve_thread: threading.Thread | None = None
        self._closed = False
        self._lock = threading.Lock()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ClusterService":
        """Boot the pool: launch node-loaders, run the REGISTER barrier,
        ship the pool-config LOAD.  Idempotent; returns self."""
        with self._lock:
            if self.host_loader is not None:
                return self
            if self._closed:
                raise RuntimeError("service already closed")
            t0 = time.perf_counter()
            try:
                self._start_inner()
            except BaseException:
                self._teardown()
                raise
            self.boot_ms = (time.perf_counter() - t0) * 1e3
            return self

    def _start_inner(self) -> None:
        if self.launcher is None:
            if self.hosts is not None:
                from repro.cluster.deploy.ssh import SSHLauncher

                self.launcher = SSHLauncher(self.hosts,
                                            preload=self.preload)
            else:
                from repro.cluster.deploy.local import LocalLauncher

                self.launcher = LocalLauncher(preload=self.preload)
        node_ids = [f"node{i}" for i in range(self.nodes)]
        conn_wrapper = None
        if self.chaos is not None and self.chaos_controller is None:
            from repro.cluster.chaos import ChaosController

            self.chaos_controller = ChaosController(
                self.chaos,
                kill=self._chaos_kill,
                telemetry=self.telemetry,
                items_fn=self._chaos_items,
            )
            self.telemetry.set_sampler("chaos", self.chaos_controller.sample)
        if self.chaos_controller is not None:
            conn_wrapper = self.chaos_controller.wrap_connection
        self.host_loader = HostLoader(
            None,
            self.timing,
            host=self.bind_host,
            port=self.port,
            heartbeat=HeartbeatMonitor(
                interval_s=self.heartbeat_interval,
                misses=self.heartbeat_misses,
            ),
            register_timeout=self.register_timeout,
            artifacts=self.artifacts,
            prefetch=self.prefetch,
            flush_items=self.flush_items,
            flush_interval=self.flush_interval,
            placement=PlacementPolicy(
                min_nodes=self.min_nodes,
                max_respawns=self.max_respawns,
                respawn_after=self.respawn_after,
                allow_late_join=self.allow_late_join,
                max_heals=self.max_heals,
            ),
            expected_nodes=node_ids,
            relaunch=self._relaunch,
            pool_nodes=self.nodes,
            pool_workers=self.workers,
            telemetry=self.telemetry,
            conn_wrapper=conn_wrapper,
        )
        # The endpoint comes up before the barrier so an operator can watch
        # LAUNCHING -> REGISTERED -> LOADED roll in live.
        if self.http_port is not None and self.http_server is None:
            self.http_server = TelemetryServer(
                self.telemetry, host=self.http_host, port=self.http_port,
            )
        self.host_loader.start()
        self.launcher.prepare(self.bind_host, self.host_loader.port)
        for node_id in node_ids:
            self.handles[node_id] = self.launcher.launch(node_id)
        self._serve_thread = threading.Thread(
            target=self.host_loader.serve, args=(self._stop,),
            name="cluster-service", daemon=True,
        )
        self._serve_thread.start()
        # The barrier runs on the serve thread; block until the pool is
        # usable (or its bootstrap failed) so boot_ms means what it says.
        self.host_loader.pool_ready.wait()
        if self.host_loader.serve_error is not None:
            raise self.host_loader.serve_error
        # Arm chaos only against the *running* pool — faults during the
        # bootstrap barrier would test the launcher, not the protocol.
        if self.chaos_controller is not None:
            self.chaos_controller.arm()

    def _chaos_kill(self, node_id: str) -> bool:
        handle = self.handles.get(node_id)
        if handle is None:
            return False
        handle.kill()
        return True

    def _chaos_items(self) -> int:
        hl = self.host_loader
        return hl.stats.items_total if hl is not None else 0

    def _relaunch(self, old_node_id: str, new_node_id: str) -> bool:
        old = self.handles.get(old_node_id)
        avoid = (old.where,) if old is not None else ()
        try:
            handle = self.launcher.launch(new_node_id, avoid=avoid)
        except Exception:
            return False
        with self._lock:  # close()/orphaned() snapshot under it
            self.handles[new_node_id] = handle
        if old is not None:
            try:
                old.kill()  # best effort; it never joined the network
            except Exception:
                pass
        return True

    # -- jobs ---------------------------------------------------------------

    def submit(self, spec, *, priority: int = 0,
               timeout: float | None = None, retries: int = 0,
               backoff: float = 0.5, max_backoff: float = 30.0,
               tenant: str = "default",
               max_inflight: int | None = None) -> JobHandle:
        """Submit one pipeline; returns immediately with its future.

        The first submission is charged the pool's boot time in its
        ``cluster_boot_ms`` (booting lazily if ``start()`` was never
        called); every later one reports ``0.0`` — it ran warm.

        ``retries`` arms a per-job retry policy: a failed attempt is
        resubmitted up to that many times with exponential backoff
        (``backoff * 2**(attempt-1)``, capped at ``max_backoff``, with
        ±50% jitter so a burst of failed jobs doesn't resubmit in
        lockstep).  The handle resolves once an attempt succeeds or the
        budget is spent (the poisoned-job guard: a deterministically
        failing work function stops, with the full history on
        ``handle.attempts``).  Each attempt gets its own ``timeout``.

        ``tenant``/``max_inflight`` are the gateway's fairness plumbing:
        all jobs of one tenant share a host-dispatched in-flight item
        budget in the dispatcher (see ``JobState``); direct users can
        leave the defaults.
        """
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        if backoff < 0 or max_backoff < 0:
            raise ValueError("backoff and max_backoff must be >= 0")
        self.start()
        if self._stop.is_set() or self._closed:
            raise RuntimeError("cluster service is closed")
        job = self.host_loader.submit_job(spec, priority=priority,
                                          timeout=timeout, tenant=tenant,
                                          max_inflight=max_inflight)
        with self._lock:
            boot = 0.0 if self._boot_charged else (self.boot_ms or 0.0)
            self._boot_charged = True
        handle = JobHandle(job, cluster_boot_ms=boot,
                           host_loader=self.host_loader)
        if retries > 0:
            handle._final = threading.Event()
            t = threading.Thread(
                target=self._supervise_retries,
                args=(handle, spec, priority, timeout, retries, backoff,
                      max_backoff, tenant, max_inflight),
                name=f"job-retry-{job.job_id}", daemon=True,
            )
            t.start()
        return handle

    def _supervise_retries(self, handle: JobHandle, spec, priority: int,
                           timeout: float | None, retries: int,
                           backoff: float, max_backoff: float,
                           tenant: str = "default",
                           max_inflight: int | None = None) -> None:
        """Per-job retry loop (its own daemon thread; the dispatcher never
        blocks on a backoff).  Records every attempt on the handle and in
        the telemetry job gauges, resubmits failed attempts until the
        budget is spent, then declares the handle final."""
        rng = random.Random(handle._job.job_id)
        attempt = 1
        while True:
            job = handle._job
            job.done.wait()
            record = _attempt_record(job, attempt)
            handle.attempts.append(record)
            self.telemetry.set_job(job.job_id,
                                   attempts=list(handle.attempts),
                                   retries=attempt - 1)
            if (job.error is None or attempt > retries
                    or self._stop.is_set() or self._closed):
                break
            delay = min(max_backoff, backoff * (2 ** (attempt - 1)))
            delay *= rng.uniform(0.5, 1.5)
            record["backoff_ms"] = round(delay * 1e3, 3)
            self.telemetry.inc("job_retries")
            self.telemetry.emit("job_retry", job=job.job_id,
                                attempt=attempt, cause=record["cause"],
                                node=record["node"],
                                backoff_ms=record["backoff_ms"])
            if self._stop.wait(delay):
                break
            attempt += 1
            try:
                new_job = self.host_loader.submit_job(
                    spec, priority=priority, timeout=timeout,
                    tenant=tenant, max_inflight=max_inflight)
            except Exception:
                break  # service torn down under us: the last error stands
            handle._job = new_job
        handle._final.set()

    def run(self, spec, *, priority: int = 0,
            timeout: float | None = None) -> Any:
        """Submit and block: the one-shot ``run()`` as a single warm job."""
        return self.submit(spec, priority=priority, timeout=timeout).result()

    def kill_node(self, node_id: str) -> None:
        """Hard-kill one pool node: a real workstation loss, detected only
        by its heartbeats going silent (in-flight work is redispatched)."""
        self.handles[node_id].kill()

    # -- elasticity ----------------------------------------------------------

    def grow(self, n: int = 1, *, reason: str = "manual") -> list[str]:
        """Add ``n`` fresh nodes to the running pool via the mid-run
        late-join path: each launch is announced to the dispatcher first
        (so its REGISTER takes the expected-arrival path even with
        elastic late join disabled), then launched; on registration it
        receives the pool config, every active job's LOAD, and the peer
        directory broadcast.  Returns the launched node ids without
        waiting for them to boot; an announcement whose launch fails is
        retracted (never left as phantom LAUNCHING capacity), and if
        nothing launched at all the failure is re-raised."""
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        self.start()
        if self._stop.is_set() or self._closed:
            raise RuntimeError("cluster service is closed")
        with self._lock:
            new_ids = []
            for _ in range(n):
                new_ids.append(f"node{self._node_seq}")
                self._node_seq += 1
        self.host_loader.expect_nodes(new_ids)
        launched: list[str] = []
        failed: list[str] = []
        error: Exception | None = None
        for node_id in new_ids:
            try:
                handle = self.launcher.launch(node_id)
            except Exception as exc:
                failed.append(node_id)
                error = exc
                continue
            with self._lock:  # close()/orphaned() snapshot under it
                self.handles[node_id] = handle
            launched.append(node_id)
        if failed:
            # Withdraw the announcements: a LAUNCHING record with no
            # process behind it would count as capacity on its way
            # forever — suppressing autoscale scale-ups (pool_span) and
            # keeping stages eligible (_check_liveness).
            self.host_loader.retract_nodes(failed)
            self.telemetry.emit("scale_up_failed", nodes=failed,
                                reason=reason, error=str(error))
        if launched:
            self.telemetry.inc("scale_up_events", len(launched))
            self.telemetry.emit("scale_up", nodes=launched, reason=reason,
                                pool=len(self.handles))
        elif error is not None:
            raise error
        return launched

    def shrink(self, node_id: str | None = None, *,
               reason: str = "manual") -> str | None:
        """Gracefully retire one pool node (default: the newest live one):
        the dispatcher fences it from new work and sends UT — the node
        drains its queued items, flushes, returns its timing record and
        exits; in-flight items are requeued on the ack.  Returns the
        retired node id, or None when nothing is retirable (the last live
        node never is)."""
        if self.host_loader is None or self._stop.is_set() or self._closed:
            return None
        candidates = self.pool_alive()
        if len(candidates) <= 1:
            return None
        if node_id is None:
            node_id = candidates[-1]
        elif node_id not in candidates:
            return None
        self.host_loader.retire_node(node_id)
        return node_id

    def pool_alive(self) -> list[str]:
        """Live, non-retiring pool members in registration order (a
        cross-thread snapshot — authoritative checks re-run on the
        dispatcher)."""
        hl = self.host_loader
        if hl is None:
            return []
        for _ in range(8):
            try:
                recs = sorted(hl.membership.nodes.values(),
                              key=lambda r: r.index)
                return [r.node_id for r in recs
                        if r.alive and not r.retiring]
            except RuntimeError:
                continue
        return []

    def pool_span(self) -> tuple[int, int]:
        """(alive, launching) member counts — the autoscaler's view of
        capacity present and capacity already on its way.  A LAUNCHING
        record older than the register timeout is not counted: a launch
        whose process died before REGISTER would otherwise read as
        capacity forever and suppress every future scale-up."""
        hl = self.host_loader
        if hl is None:
            return (0, 0)
        now = time.monotonic()
        for _ in range(8):
            try:
                recs = list(hl.membership.nodes.values())
                alive = sum(1 for r in recs if r.alive and not r.retiring)
                launching = sum(
                    1 for r in recs
                    if r.state == LAUNCHING
                    and now - r.state_changed_at < hl.register_timeout)
                return (alive, launching)
            except RuntimeError:
                continue
        return (0, 0)

    def publish_block(self, name: str, data: bytes) -> str:
        """Publish a named read-only broadcast block to the pool.

        Returns its digest.  Nodes stripe the initial chunk fetches across
        themselves against the host and then trade chunks peer-to-peer, so
        the payload leaves the host roughly once regardless of pool size;
        work functions read it with ``repro.cluster.peer.get_block(name)``.
        """
        self.start()
        if self._stop.is_set() or self._closed:
            raise RuntimeError("cluster service is closed")
        return self.host_loader.publish_block(name, data)

    # -- observability ------------------------------------------------------

    @property
    def http_url(self) -> str | None:
        """Base URL of the status endpoint (None when not serving)."""
        return None if self.http_server is None else self.http_server.url

    def metrics_snapshot(self) -> dict[str, Any]:
        """The same JSON ``GET /metrics`` serves, as a dict — benchmarks
        record it next to their timing numbers."""
        return self.telemetry.snapshot()

    # -- teardown -----------------------------------------------------------

    def close(self) -> None:
        """Terminate the pool: UT every node, collect their timing records,
        reclaim launcher resources.  Pending jobs are failed, not leaked."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._teardown()

    def _teardown(self) -> None:
        # Chaos first: no new faults may fire into a pool being dismantled.
        if self.chaos_controller is not None:
            self.chaos_controller.disarm()
        if self.host_loader is not None:
            # Polite first: UT lets nodes flush + return timings and exit 0.
            self.host_loader.shutdown_nodes()
        self._stop.set()
        if self._serve_thread is not None:
            self._serve_thread.join(timeout=self.shutdown_grace)
        if self.host_loader is not None:
            self.host_loader.close()
        deadline = time.monotonic() + self.shutdown_grace
        with self._lock:
            # Snapshot: grow()/_relaunch() mutate handles from the
            # autoscaler and dispatcher threads.
            handles = list(self.handles.values())
        for handle in handles:
            remaining = max(0.0, deadline - time.monotonic())
            if handle.wait(timeout=remaining) is None:
                handle.kill()
                handle.wait(timeout=self.shutdown_grace)
        for handle in handles:
            join = getattr(handle, "join_drainers", None)
            if join is not None:  # EOF arrives once the child exits
                join()
        if self.launcher is not None:
            self.launcher.close()
        if self.http_server is not None:
            self.http_server.close()
        self.telemetry.close()  # flush the trace even if start() never ran

    def orphaned(self) -> list[str]:
        """Node-loaders still running after close (must be empty)."""
        with self._lock:
            items = list(self.handles.items())
        return [nid for nid, h in items if h.poll() is None]

    def __enter__(self) -> "ClusterService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


class ServiceClusterApplication:
    """``build_application(spec, backend="service")``: the app contract over
    a warm pool.

    With ``service=`` the caller's pool is used and **left running** —
    ``run()`` is just "submit this job and wait", which is how repeated
    builds of the same spec become warm resubmits.  Without it, an
    ephemeral pool sized from the spec (its total nodes, its widest
    stage's workers) boots for this run and closes after — behaviourally
    the one-shot cluster backend, routed through the service code path.
    """

    def __init__(self, spec: Any, plan: Any, timing: TimingCollector,
                 service: ClusterService | None = None,
                 priority: int = 0, job_timeout: float | None = 300.0,
                 **pool_options: Any):
        if hasattr(spec, "as_pipeline"):
            spec = spec.as_pipeline()
        spec.validate()
        self.spec = spec
        self.plan = plan
        self.timing = timing
        self.priority = priority
        self.job_timeout = job_timeout
        self.service = service
        self._owns_service = service is None
        self._pool_options = pool_options
        self.handle: JobHandle | None = None
        self.result: Any = None
        self._ran = False

    def run(self) -> Any:
        if self._ran:
            raise RuntimeError("application already ran; build a fresh one")
        self._ran = True
        if self.service is None:
            self.service = ClusterService(
                nodes=self.spec.total_nodes,
                workers=max(st.workers_per_node for st in self.spec.stages),
                timing=self.timing,
                **self._pool_options,
            )
        try:
            self.handle = self.service.submit(
                self.spec, priority=self.priority, timeout=self.job_timeout,
            )
            self.result = self.handle.result()
        finally:
            if self._owns_service:
                self.service.close()
        return self.result

    def orphaned(self) -> list[str]:
        if self.service is None or not self._owns_service:
            return []
        return self.service.orphaned()
