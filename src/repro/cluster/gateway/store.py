"""The durable ticket store: one SQLite table, one row per submission.

hyper-shell's client/cluster apps model the pattern this module follows: a
job submitted to a shared pool is a *database row first* — the client can
disconnect, the gateway can crash, and the row (spec blob, tenant, policy,
lifecycle state, eventually the result) is still there when either comes
back.  ``repro.cluster.gateway.JobGateway`` keeps its whole queue in here;
the in-memory scheduler is a cache of the ``queued`` rows, rebuilt on
restart.

Ticket lifecycle::

    queued -(admitted)-> running -(job done)---> done
       |                    |  \\-(job error)--> failed
       |                    \\-(gateway crash)-> queued   [recover()]
       \\-(cancel / queued-timeout)-----------> cancelled

Stdlib only (``sqlite3``); specs and results are cloudpickled with the
same :func:`repro.cluster.wire.dumps_code` codec the LOAD path ships stage
functions with, so anything submittable is persistable.  One connection,
serialized by a lock (the gateway pump, enqueuing clients, and attached
handles all read/write concurrently); every write commits — durability is
the point.
"""

from __future__ import annotations

import json
import sqlite3
import threading
import time
from dataclasses import dataclass
from typing import Any

from repro.cluster.wire import dumps_code, loads_code

__all__ = ["TicketRow", "TicketStore", "QUEUED", "RUNNING", "DONE",
           "FAILED", "CANCELLED"]

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
CANCELLED = "cancelled"

_SCHEMA = """
CREATE TABLE IF NOT EXISTS tickets (
    ticket       TEXT PRIMARY KEY,
    tenant       TEXT NOT NULL,
    priority     INTEGER NOT NULL DEFAULT 0,
    retries      INTEGER NOT NULL DEFAULT 0,
    timeout      REAL,
    state        TEXT NOT NULL,
    spec         BLOB NOT NULL,
    submitted_at REAL NOT NULL,
    started_at   REAL,
    finished_at  REAL,
    result       BLOB,
    error        TEXT,
    summary      TEXT
);
CREATE INDEX IF NOT EXISTS tickets_state ON tickets (state);
"""


@dataclass
class TicketRow:
    ticket: str
    tenant: str
    priority: int
    retries: int
    timeout: float | None
    state: str
    spec: bytes
    submitted_at: float
    started_at: float | None = None
    finished_at: float | None = None
    result: bytes | None = None
    error: str | None = None
    summary: dict[str, Any] | None = None

    def load_spec(self) -> Any:
        return loads_code(self.spec)

    def load_result(self) -> Any:
        return None if self.result is None else loads_code(self.result)


def _row(raw: sqlite3.Row) -> TicketRow:
    summary = raw["summary"]
    return TicketRow(
        ticket=raw["ticket"], tenant=raw["tenant"],
        priority=int(raw["priority"]), retries=int(raw["retries"]),
        timeout=raw["timeout"], state=raw["state"], spec=raw["spec"],
        submitted_at=float(raw["submitted_at"]),
        started_at=raw["started_at"], finished_at=raw["finished_at"],
        result=raw["result"], error=raw["error"],
        summary=json.loads(summary) if summary else None,
    )


class TicketStore:
    """The gateway's SQLite task table (see module docstring).

    ``path`` may be a filesystem path (durable) or ``":memory:"`` (tests
    of the scheduling machinery that don't exercise restart).
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._conn = sqlite3.connect(self.path, check_same_thread=False)
        self._conn.row_factory = sqlite3.Row
        with self._lock:
            self._conn.executescript(_SCHEMA)
            self._conn.commit()

    # -- writes --------------------------------------------------------------

    def add(self, ticket: str, spec: Any, *, tenant: str, priority: int,
            retries: int, timeout: float | None,
            now: float | None = None) -> TicketRow:
        now = time.time() if now is None else now
        blob = dumps_code(spec)
        with self._lock:
            self._conn.execute(
                "INSERT INTO tickets (ticket, tenant, priority, retries,"
                " timeout, state, spec, submitted_at)"
                " VALUES (?, ?, ?, ?, ?, ?, ?, ?)",
                (ticket, tenant, priority, retries, timeout, QUEUED,
                 blob, now),
            )
            self._conn.commit()
        return TicketRow(ticket=ticket, tenant=tenant, priority=priority,
                         retries=retries, timeout=timeout, state=QUEUED,
                         spec=blob, submitted_at=now)

    def mark_running(self, ticket: str, now: float | None = None) -> None:
        now = time.time() if now is None else now
        with self._lock:
            self._conn.execute(
                "UPDATE tickets SET state = ?, started_at = ?"
                " WHERE ticket = ?", (RUNNING, now, ticket))
            self._conn.commit()

    def finish(self, ticket: str, *, result: Any = None,
               error: str | None = None,
               summary: dict[str, Any] | None = None,
               now: float | None = None) -> None:
        """Terminal transition: ``done`` with a pickled result, or
        ``failed`` with the error string.  The summary (boot/latency
        figures from the live JobHandle) is persisted so a handle attached
        *after* the gateway restarts can still report them."""
        now = time.time() if now is None else now
        state = FAILED if error is not None else DONE
        blob = None if error is not None else dumps_code(result)
        with self._lock:
            self._conn.execute(
                "UPDATE tickets SET state = ?, finished_at = ?, result = ?,"
                " error = ?, summary = ? WHERE ticket = ?",
                (state, now, blob, error,
                 json.dumps(summary) if summary else None, ticket))
            self._conn.commit()

    def cancel(self, ticket: str, reason: str,
               now: float | None = None) -> bool:
        """Cancel a still-queued ticket (running/terminal rows refuse)."""
        now = time.time() if now is None else now
        with self._lock:
            cur = self._conn.execute(
                "UPDATE tickets SET state = ?, finished_at = ?, error = ?"
                " WHERE ticket = ? AND state = ?",
                (CANCELLED, now, reason, ticket, QUEUED))
            self._conn.commit()
        return cur.rowcount > 0

    def recover(self) -> list[TicketRow]:
        """Crash recovery, called once by a fresh gateway over an existing
        database: rows stuck ``running`` lost their pool job with the old
        gateway process, so they go back to ``queued`` (the attempt is
        charged nowhere — the ticket's own ``retries`` budget rides the
        resubmission); returns every queued row, oldest first."""
        with self._lock:
            self._conn.execute(
                "UPDATE tickets SET state = ?, started_at = NULL"
                " WHERE state = ?", (QUEUED, RUNNING))
            self._conn.commit()
            rows = self._conn.execute(
                "SELECT * FROM tickets WHERE state = ?"
                " ORDER BY submitted_at", (QUEUED,)).fetchall()
        return [_row(r) for r in rows]

    # -- reads ---------------------------------------------------------------

    def get(self, ticket: str) -> TicketRow | None:
        with self._lock:
            raw = self._conn.execute(
                "SELECT * FROM tickets WHERE ticket = ?",
                (ticket,)).fetchone()
        return None if raw is None else _row(raw)

    def counts(self) -> dict[str, int]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT state, COUNT(*) AS n FROM tickets"
                " GROUP BY state").fetchall()
        return {r["state"]: int(r["n"]) for r in rows}

    def close(self) -> None:
        with self._lock:
            self._conn.close()
