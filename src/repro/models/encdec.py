"""Encoder-decoder transformer backbone (seamless-m4t style).

The audio modality frontend is a **stub** per the assignment: ``input_specs``
supplies precomputed frame embeddings [B, S_enc, D].  The backbone is fully
real: a bidirectional encoder stack and a causal decoder with cross-attention,
sharing all layer machinery with ``models.lm``.

Decode state = per-layer self-attention KV cache + the (static) per-layer
cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.channels import ShardingRules
from repro.models import attention as attn_mod
from repro.models.common import ParamSpec, fan_in_normal
from repro.models.layers import chunked_cross_entropy, embed_tokens, rms_norm, swiglu
from repro.models.lm import (
    _constrain,
    _remat_policy,
    _tree_slice,
    head_plan,
    lm_head_weight,
    logits_from_hidden,
    mlp_specs,
)


def _proj_specs(cfg: ModelConfig, n: int, tp: int, prefix_kv_from_enc: bool = False):
    hp = head_plan(cfg, tp)
    D, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": ParamSpec((n, D, hp["Hp"] * hd), ("layers", "d_model_fsdp", "d_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wk": ParamSpec((n, D, hp["Kp"] * hd), ("layers", "d_model_fsdp", "d_kv_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wv": ParamSpec((n, D, hp["Kp"] * hd), ("layers", "d_model_fsdp", "d_kv_attn"),
                        stddev=fan_in_normal((D, 0))),
        "wo": ParamSpec((n, hp["Hp"] * hd, D), ("layers", "d_attn", "d_model_fsdp"),
                        stddev=fan_in_normal((hp["Hp"] * hd, 0), fan_axis=0)),
    }


def encdec_param_specs(cfg: ModelConfig, tp: int = 1) -> dict:
    D = cfg.d_model
    ne, nd = cfg.encoder_layers, cfg.num_layers
    Vp = cfg.padded_vocab(tp)
    enc_block = {
        "ln1": ParamSpec((ne, D), ("layers", "d_model"), init="zeros"),
        "self": _proj_specs(cfg, ne, tp),
        "ln2": ParamSpec((ne, D), ("layers", "d_model"), init="zeros"),
        "mlp": mlp_specs(D, cfg.d_ff, ne),
    }
    dec_block = {
        "ln1": ParamSpec((nd, D), ("layers", "d_model"), init="zeros"),
        "self": _proj_specs(cfg, nd, tp),
        "ln_x": ParamSpec((nd, D), ("layers", "d_model"), init="zeros"),
        "cross": _proj_specs(cfg, nd, tp),
        "ln2": ParamSpec((nd, D), ("layers", "d_model"), init="zeros"),
        "mlp": mlp_specs(D, cfg.d_ff, nd),
    }
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "d_model_fsdp"), stddev=0.02),
        "encoder": {"blocks": enc_block,
                    "final_norm": ParamSpec((D,), ("d_model",), init="zeros")},
        "decoder": {"blocks": dec_block,
                    "final_norm": ParamSpec((D,), ("d_model",), init="zeros")},
        "lm_head": ParamSpec((D, Vp), ("d_model_fsdp", "vocab"),
                             stddev=fan_in_normal((D, Vp))),
    }


def _mha(cfg, p, xq, xkv, positions_q, positions_kv, *, causal, tp, rules,
         cache=None, cache_len=None, rope=True):
    """Generic attention for enc/dec (optionally cached K/V)."""
    hp = head_plan(cfg, tp)
    Hp, Kp, hd = hp["Hp"], hp["Kp"], cfg.head_dim
    B, Sq, _ = xq.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,da->bsa", xq, p["wq"].astype(cdt)).reshape(B, Sq, Hp, hd)
    if rope:
        q = attn_mod.apply_rope(q, positions_q, cfg.rope_theta)
    if cache is not None and "k_static" in cache:  # cross-attention decode
        k, v = cache["k_static"], cache["v_static"]
        out = attn_mod.decode_attention(q, k, v, cache["len_static"])
        return out.reshape(B, Sq, Hp * hd), None
    k = jnp.einsum("bsd,da->bsa", xkv, p["wk"].astype(cdt)).reshape(
        B, -1, Kp, hd)
    v = jnp.einsum("bsd,da->bsa", xkv, p["wv"].astype(cdt)).reshape(
        B, -1, Kp, hd)
    if rope:
        k = attn_mod.apply_rope(k, positions_kv, cfg.rope_theta)
    if cache is not None:  # self-attention decode
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        out = attn_mod.decode_attention(q, ck, cv, cache_len + Sq)
        return out.reshape(B, Sq, Hp * hd), {"k": ck, "v": cv}
    out = attn_mod.attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
                             unroll=cfg.unroll_scans)
    return out.reshape(B, Sq, Hp * hd), {"k": k, "v": v}


def _enc_block(cfg, p, x, positions, tp, rules):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, _ = _mha(cfg, p["self"], h, h, positions, positions,
                causal=False, tp=tp, rules=rules)
    x = x + jnp.einsum("bsa,ad->bsd", a, p["self"]["wo"].astype(cdt)).astype(x.dtype)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"],
                   cdt).astype(x.dtype)
    return _constrain(rules, x, ("batch", "seq_sp", "d_model"))


def _dec_block(cfg, p, x, enc_out, pos_q, pos_enc, tp, rules,
               cache=None, cache_len=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    new_cache = None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    a, kv = _mha(cfg, p["self"], h, h, pos_q, pos_q, causal=True, tp=tp,
                 rules=rules, cache=self_cache, cache_len=cache_len)
    x = x + jnp.einsum("bsa,ad->bsd", a, p["self"]["wo"].astype(cdt)).astype(x.dtype)
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    if cache is not None:
        xc = {"k_static": cache["xk"], "v_static": cache["xv"],
              "len_static": cache["xk"].shape[1]}
        a, _ = _mha(cfg, p["cross"], h, None, pos_q, None, causal=False,
                    tp=tp, rules=rules, cache=xc, rope=False)
    else:
        a, _ = _mha(cfg, p["cross"], h, enc_out, pos_q, pos_enc,
                    causal=False, tp=tp, rules=rules, rope=False)
    x = x + jnp.einsum("bsa,ad->bsd", a, p["cross"]["wo"].astype(cdt)).astype(x.dtype)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"],
                   cdt).astype(x.dtype)
    if cache is not None and kv is not None:
        new_cache = {"k": kv["k"], "v": kv["v"]}
    return _constrain(rules, x, ("batch", "seq_sp", "d_model")), new_cache


def encode(cfg: ModelConfig, params, frames, *, tp=1, rules=None):
    """frames: [B, S_enc, D] stub embeddings -> encoder output."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = _constrain(rules, x, ("batch", "seq_sp", "d_model"))
    positions = jnp.arange(x.shape[1])
    blocks = params["encoder"]["blocks"]

    def body(x, pslice):
        fn = lambda p, x: _enc_block(cfg, p, x, positions, tp, rules)  # noqa: E731
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=_remat_policy(cfg))
        return fn(pslice, x), None

    if cfg.scan_layers and cfg.encoder_layers > 1:
        x, _ = jax.lax.scan(body, x, blocks)
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, _tree_slice(blocks, i))
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, tokens, enc_out, *, tp=1, rules=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt) * math.sqrt(cfg.d_model)
    x = _constrain(rules, x, ("batch", "seq_sp", "d_model"))
    pos_q = jnp.arange(tokens.shape[1])
    pos_enc = jnp.arange(enc_out.shape[1])
    blocks = params["decoder"]["blocks"]

    def body(x, pslice):
        fn = lambda p, x: _dec_block(  # noqa: E731
            cfg, p, x, enc_out, pos_q, pos_enc, tp, rules)[0]
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=_remat_policy(cfg))
        return fn(pslice, x), None

    if cfg.scan_layers and cfg.num_layers > 1:
        x, _ = jax.lax.scan(body, x, blocks)
    else:
        for i in range(cfg.num_layers):
            x, _ = body(x, _tree_slice(blocks, i))
    return rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)


def encdec_loss(cfg: ModelConfig, params, batch, *, tp=1, rules=None):
    """batch: frames [B, S_enc, D], tokens/targets [B, S_dec]."""
    enc_out = encode(cfg, params, batch["frames"], tp=tp, rules=rules)
    x = decode_train(cfg, params, batch["tokens"], enc_out, tp=tp, rules=rules)
    ce = chunked_cross_entropy(
        x, params["lm_head"], batch["targets"],
        vocab_size=cfg.vocab_size, seq_chunk=cfg.loss_seq_chunk,
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_scans,
    )
    return ce, {"ce_loss": ce, "loss": ce}


def init_encdec_cache(cfg: ModelConfig, params, enc_out, max_seq, tp=1):
    """Self-attn cache + per-layer static cross K/V from encoder output."""
    hp = head_plan(cfg, tp)
    B = enc_out.shape[0]
    cdt = jnp.dtype(cfg.compute_dtype)
    nd = cfg.num_layers
    xk, xv = [], []
    for i in range(nd):
        p = _tree_slice(params["decoder"]["blocks"], i)
        k = jnp.einsum("bsd,da->bsa", enc_out, p["cross"]["wk"].astype(cdt))
        v = jnp.einsum("bsd,da->bsa", enc_out, p["cross"]["wv"].astype(cdt))
        xk.append(k.reshape(B, -1, hp["Kp"], cfg.head_dim))
        xv.append(v.reshape(B, -1, hp["Kp"], cfg.head_dim))
    return {
        "k": jnp.zeros((nd, B, max_seq, hp["Kp"], cfg.head_dim), cdt),
        "v": jnp.zeros((nd, B, max_seq, hp["Kp"], cfg.head_dim), cdt),
        "xk": jnp.stack(xk),
        "xv": jnp.stack(xv),
    }


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens, cache_len,
                       *, tp=1, rules=None):
    """One decoder step against the cross/self caches."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, cdt) * math.sqrt(cfg.d_model)
    pos_q = jnp.reshape(cache_len, (1,)) + jnp.arange(1)
    new_cache = dict(cache)
    for i in range(cfg.num_layers):
        p = _tree_slice(params["decoder"]["blocks"], i)
        layer_cache = {"k": cache["k"][i], "v": cache["v"][i],
                       "xk": cache["xk"][i], "xv": cache["xv"][i]}
        x, kv = _dec_block(cfg, p, x, None, pos_q, None, tp, rules,
                           cache=layer_cache, cache_len=cache_len)
        new_cache["k"] = new_cache["k"].at[i].set(kv["k"])
        new_cache["v"] = new_cache["v"].at[i].set(kv["v"])
    x = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cdt),
                        params["lm_head"].astype(cdt))
    return logits, new_cache
