"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun + launch/roofline request 512 placeholder devices)."""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:  # deterministic fallback keeps the property tests running
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback

    _hypothesis_fallback.install(sys.modules)

import jax
import pytest

jax.config.update("jax_default_matmul_precision", "float32")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes, not seconds)"
    )


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
