"""The job gateway (repro.cluster.gateway): durable queue, fairness, scaling.

Scheduler and store units are pure (injected clocks, tmp databases); the
integration tests put a real JobGateway in front of a ClusterService over
an InProcessLauncher and exercise the three pillars end-to-end: tickets
that survive a gateway crash (enqueue → kill → restart → attach → result),
deficit-round-robin admission that keeps a narrow tenant from starving
behind a wide high-priority one, and the queue-driven autoscaler growing
and retiring pool nodes.  Everything stays on 127.0.0.1.
"""

import time

import pytest

from repro.cluster.deploy.inprocess import InProcessLauncher
from repro.cluster.gateway import (
    AutoscalePolicy,
    FairScheduler,
    JobCancelled,
    JobGateway,
    QueueEntry,
    TenantPolicy,
    TicketStore,
)
from repro.cluster.service import ClusterService
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails

FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _list_collect():
    return ResultDetails(name="list", init=lambda: [],
                         collect=lambda a, x: a + [x], finalise=sorted)


def _spec(work, n_items, *, nclusters=1, workers=2):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_list_collect(),
    )


def _service(**kw):
    kw.setdefault("nodes", 1)
    kw.setdefault("workers", 2)
    kw.setdefault("launcher", InProcessLauncher())
    kw.update(FAST)
    return ClusterService(**kw)


# Module-level work functions: stable cloudpickle digests across submits
# (warm code-cache hits) and across gateway restarts (recovered tickets
# resubmit the identical spec blob).
def _double(x):
    return x * 2


def _slow_double(x):
    time.sleep(0.02)
    return x * 2


def _entry(ticket, tenant, *, priority=0, submitted_at=0.0, timeout=None):
    return QueueEntry(ticket=ticket, tenant=tenant, priority=priority,
                      submitted_at=submitted_at, timeout=timeout)


# ---------------------------------------------------------------------------
# FairScheduler units (pure, injected clocks)
# ---------------------------------------------------------------------------


def test_drr_weights_give_proportional_admissions():
    """A weight-2 tenant is admitted twice per weight-1 admission while
    both have queued work."""
    sched = FairScheduler({"big": TenantPolicy(weight=2.0),
                           "small": TenantPolicy(weight=1.0)})
    for i in range(12):
        sched.push(_entry(f"b{i}", "big"))
        sched.push(_entry(f"s{i}", "small"))
    order = [sched.pop_next(now=100.0).tenant for _ in range(9)]
    assert order.count("big") == 6
    assert order.count("small") == 3


def test_equal_weights_alternate():
    sched = FairScheduler()
    for i in range(4):
        sched.push(_entry(f"a{i}", "a"))
        sched.push(_entry(f"b{i}", "b"))
    order = [sched.pop_next(now=1.0).tenant for _ in range(6)]
    # Ties break to the least-recently-served: strict alternation.
    assert order[:2] in (["a", "b"], ["b", "a"])
    assert all(order[i] != order[i + 1] for i in range(5))


def test_priority_orders_within_tenant_only():
    """Submit priority ranks tickets inside one tenant; across tenants the
    weights decide, so tenant b still gets served between a's tickets."""
    sched = FairScheduler()
    sched.push(_entry("a-low", "a", priority=0))
    sched.push(_entry("a-high", "a", priority=5))
    sched.push(_entry("b-low", "b", priority=0))
    picks = [sched.pop_next(now=1.0).ticket for _ in range(3)]
    # Within tenant a the high-priority ticket leads; b is interleaved,
    # not starved behind both of a's.
    assert picks.index("a-high") < picks.index("a-low")
    assert picks.index("b-low") < 2


def test_aging_lifts_stale_tickets_past_fresh_high_priority():
    sched = FairScheduler(aging_s=10.0)
    sched.push(_entry("old", "t", priority=0, submitted_at=0.0))
    sched.push(_entry("new", "t", priority=3, submitted_at=100.0))
    # At t=100 the old ticket has aged +10 effective priority: it wins.
    assert sched.pop_next(now=100.0).ticket == "old"
    # Without the age advantage the fresher high-priority one would have:
    sched2 = FairScheduler(aging_s=10.0)
    sched2.push(_entry("old", "t", priority=0, submitted_at=99.0))
    sched2.push(_entry("new", "t", priority=3, submitted_at=100.0))
    assert sched2.pop_next(now=100.0).ticket == "new"


def test_fifo_mode_is_strict_priority_across_tenants():
    sched = FairScheduler(mode="fifo")
    sched.push(_entry("a1", "a", priority=0, submitted_at=1.0))
    sched.push(_entry("b1", "b", priority=5, submitted_at=2.0))
    sched.push(_entry("b2", "b", priority=5, submitted_at=3.0))
    picks = [sched.pop_next(now=4.0).ticket for _ in range(3)]
    assert picks == ["b1", "b2", "a1"]  # the starvation baseline


def test_max_active_jobs_cap_blocks_tenant():
    sched = FairScheduler({"capped": TenantPolicy(max_active_jobs=1)})
    sched.push(_entry("c1", "capped"))
    sched.push(_entry("u1", "uncapped"))
    # capped already has 1 admitted job: only the other tenant is eligible.
    assert sched.pop_next({"capped": 1}, now=1.0).ticket == "u1"
    assert sched.pop_next({"capped": 1}, now=1.0) is None
    assert sched.pop_next({}, now=1.0).ticket == "c1"


def test_remove_and_drop_expired():
    sched = FairScheduler()
    sched.push(_entry("keep", "t", submitted_at=0.0))
    sched.push(_entry("gone", "t", submitted_at=0.0, timeout=5.0))
    sched.push(_entry("fresh", "t", submitted_at=8.0, timeout=5.0))
    assert sched.remove("nope") is None
    expired = sched.drop_expired(now=6.0)
    assert [e.ticket for e in expired] == ["gone"]
    assert sched.remove("keep").ticket == "keep"
    assert sched.depth() == 1 and sched.oldest_wait(now=10.0) == 2.0


def test_scheduler_validation():
    with pytest.raises(ValueError):
        FairScheduler(mode="lifo")
    with pytest.raises(ValueError):
        FairScheduler({"t": TenantPolicy(weight=0.0)})
    with pytest.raises(ValueError):
        TenantPolicy(max_inflight=0).validate()


# ---------------------------------------------------------------------------
# TicketStore units (real files: durability is the point)
# ---------------------------------------------------------------------------


def test_store_lifecycle_and_reopen(tmp_path):
    db = str(tmp_path / "q.db")
    store = TicketStore(db)
    store.add("t1", {"payload": 1}, tenant="a", priority=2, retries=1,
              timeout=9.0)
    store.mark_running("t1")
    store.finish("t1", result=[1, 2, 3], summary={"cluster_boot_ms": 0.0})
    store.add("t2", {"payload": 2}, tenant="b", priority=0, retries=0,
              timeout=None)
    store.close()
    # A fresh process over the same file sees everything.
    store2 = TicketStore(db)
    row = store2.get("t1")
    assert row.state == "done"
    assert row.load_result() == [1, 2, 3]
    assert row.summary == {"cluster_boot_ms": 0.0}
    assert row.load_spec() == {"payload": 1}
    assert store2.counts() == {"done": 1, "queued": 1}
    store2.close()


def test_store_recover_requeues_running_rows(tmp_path):
    store = TicketStore(str(tmp_path / "q.db"))
    store.add("ran", {}, tenant="a", priority=0, retries=0, timeout=None,
              now=1.0)
    store.add("sat", {}, tenant="a", priority=0, retries=0, timeout=None,
              now=2.0)
    store.add("fin", {}, tenant="a", priority=0, retries=0, timeout=None)
    store.mark_running("ran")
    store.mark_running("fin")
    store.finish("fin", result="x")
    rows = store.recover()
    # The crashed-mid-run row is queued again (oldest first); done stays.
    assert [r.ticket for r in rows] == ["ran", "sat"]
    assert store.get("ran").state == "queued"
    assert store.get("ran").started_at is None
    assert store.get("fin").state == "done"
    store.close()


def test_store_cancel_only_from_queued(tmp_path):
    store = TicketStore(str(tmp_path / "q.db"))
    store.add("q", {}, tenant="a", priority=0, retries=0, timeout=None)
    store.add("r", {}, tenant="a", priority=0, retries=0, timeout=None)
    store.mark_running("r")
    assert store.cancel("q", "client asked") is True
    assert store.cancel("r", "client asked") is False
    assert store.get("q").state == "cancelled"
    assert store.get("q").error == "client asked"
    assert store.get("r").state == "running"
    store.close()


# ---------------------------------------------------------------------------
# Gateway end-to-end (real pool over InProcessLauncher)
# ---------------------------------------------------------------------------


def test_enqueue_attach_result_roundtrip(tmp_path):
    with _service() as svc:
        with JobGateway(svc, str(tmp_path / "q.db")) as gw:
            t1 = gw.enqueue(_spec(_double, 20), tenant="alice")
            t2 = gw.enqueue(_spec(_double, 10), tenant="bob")
            h1, h2 = gw.attach(t1), gw.attach(t2)
            assert h1.result(timeout=60) == [2 * i for i in range(20)]
            assert h2.result(timeout=60) == [2 * i for i in range(10)]
            assert h1.status() == "done" and h1.done()
            stats = h1.stats()
            assert stats["tenant"] == "alice"
            assert stats["items_collected"] == 20
            with pytest.raises(KeyError):
                gw.attach("tnope")
        counts = svc.telemetry.snapshot()["cluster"]
        assert counts["tickets_enqueued"] == 2
        assert counts["tickets_done"] == 2


def test_bad_ticket_fails_alone_and_pump_survives(tmp_path):
    """One malformed spec fails only its own ticket: the pump thread
    survives admission errors (it used to re-raise and die, stranding
    every tenant's tickets as queued forever), so a good ticket enqueued
    after the bad one is still admitted and completes."""
    with _service() as svc:
        with JobGateway(svc, str(tmp_path / "q.db")) as gw:
            bad = gw.enqueue(object(), tenant="mallory")  # not a spec
            good = gw.enqueue(_spec(_double, 10), tenant="alice")
            hb, hg = gw.attach(bad), gw.attach(good)
            assert hg.result(timeout=60) == [2 * i for i in range(10)]
            assert hb.wait(timeout=30)
            assert hb.status() == "failed"
            with pytest.raises(RuntimeError, match="AttributeError"):
                hb.result(timeout=5)
        counts = svc.telemetry.snapshot()["cluster"]
        assert counts["tickets_failed"] == 1
        assert counts["tickets_done"] == 1


def test_ticket_survives_gateway_crash_and_restart(tmp_path):
    """The durability pillar: enqueue, crash the gateway before admission,
    restart over the same database, attach, get the result — and the
    warm pool means the recovered job reports cluster_boot_ms == 0."""
    db = str(tmp_path / "q.db")
    with _service() as svc:
        # Warm the pool so boot is charged before the gateway exists.
        svc.submit(_spec(_double, 4), timeout=60).result()
        # A zero-slot tenant policy keeps the ticket queued: the crash
        # happens before the job ever reaches the pool.
        gw1 = JobGateway(svc, db,
                         default_policy=TenantPolicy(max_active_jobs=0))
        ticket = gw1.enqueue(_spec(_double, 30), tenant="alice")
        time.sleep(0.2)
        assert gw1.attach(ticket).status() == "queued"
        gw1.kill()  # the simulated crash: no reaping, no state rewrite
        gw2 = JobGateway(svc, db)
        try:
            handle = gw2.attach(ticket)
            assert handle.result(timeout=60) == [2 * i for i in range(30)]
            stats = handle.stats()
            assert stats["state"] == "done"
            assert stats["cluster_boot_ms"] == 0.0
        finally:
            gw2.close()


def test_running_ticket_requeued_after_crash(tmp_path):
    """A ticket caught mid-run by the crash is recovered: the next gateway
    requeues it from the row alone (lazy spec unpickle) and it completes."""
    db = str(tmp_path / "q.db")
    with _service() as svc:
        gw1 = JobGateway(svc, db)
        ticket = gw1.enqueue(_spec(_slow_double, 40), tenant="alice")
        handle = gw1.attach(ticket)
        deadline = time.monotonic() + 30
        while handle.status() != "running":
            assert time.monotonic() < deadline, "never admitted"
            time.sleep(0.02)
        gw1.kill()
        peek = TicketStore(db)
        assert peek.get(ticket).state == "running"
        peek.close()
        gw2 = JobGateway(svc, db)
        try:
            assert gw2.attach(ticket).result(timeout=120) == \
                [2 * i for i in range(40)]
        finally:
            gw2.close()


def test_queued_timeout_reports_cancelled(tmp_path):
    """submit(timeout=) on a job still queued at its deadline: it leaves
    the queue and reports cancelled — it can never hold a slot forever."""
    with _service() as svc:
        gw = JobGateway(svc, str(tmp_path / "q.db"),
                        default_policy=TenantPolicy(max_active_jobs=0))
        try:
            ticket = gw.enqueue(_spec(_double, 5), timeout=0.3)
            handle = gw.attach(ticket)
            assert handle.wait(timeout=30)
            assert handle.status() == "cancelled"
            with pytest.raises(JobCancelled, match="timed out"):
                handle.result(timeout=5)
            assert gw.queued_count() == 0
        finally:
            gw.close()


def test_cancel_queued_ticket(tmp_path):
    with _service() as svc:
        gw = JobGateway(svc, str(tmp_path / "q.db"),
                        default_policy=TenantPolicy(max_active_jobs=0))
        try:
            ticket = gw.enqueue(_spec(_double, 5))
            assert gw.cancel(ticket) is True
            assert gw.attach(ticket).status() == "cancelled"
            with pytest.raises(JobCancelled):
                gw.attach(ticket).result(timeout=5)
            assert gw.cancel(ticket) is False  # already gone
        finally:
            gw.close()


def test_fair_admission_interleaves_tenants(tmp_path):
    """With one admission slot, fair mode alternates tenants even though
    the wide tenant enqueued first at a higher priority; fifo mode admits
    strictly by priority — the narrow tenant waits behind every wide job."""

    def admitted_tenants(mode):
        with _service() as svc:
            gw = JobGateway(svc, str(tmp_path / f"{mode}.db"),
                            mode=mode, max_active_jobs=1)
            try:
                handles = []
                for i in range(2):
                    handles.append(gw.attach(gw.enqueue(
                        _spec(_slow_double, 8), tenant="wide", priority=5)))
                for i in range(2):
                    handles.append(gw.attach(gw.enqueue(
                        _spec(_double, 2), tenant="narrow", priority=0)))
                for h in handles:
                    assert h.result(timeout=120) is not None
                events = svc.telemetry.events_since(0, limit=1000)
                return [e["tenant"] for e in events
                        if e["kind"] == "ticket_admitted"]
            finally:
                gw.close()

    fair = admitted_tenants("fair")
    assert fair[0] == "wide"  # enqueued first into an empty gateway
    assert "narrow" in fair[1:3], f"narrow starved under fair: {fair}"
    fifo = admitted_tenants("fifo")
    assert fifo[:2] == ["wide", "wide"], f"fifo baseline changed: {fifo}"


def test_tenant_max_inflight_caps_dispatch(tmp_path):
    """The per-tenant credit cap rides into host_loader._answer: with
    max_inflight=2 no WORK_BATCH may carry more than 2 items, even though
    the pool's credit window would otherwise batch more."""
    with _service(workers=4) as svc:
        gw = JobGateway(svc, str(tmp_path / "q.db"),
                        tenants={"capped": TenantPolicy(max_inflight=2)})
        try:
            handle = gw.attach(gw.enqueue(_spec(_double, 30, workers=4),
                                          tenant="capped"))
            assert handle.result(timeout=60) == [2 * i for i in range(30)]
            assert svc.host_loader.stats.max_batch <= 2
        finally:
            gw.close()


def test_autoscaler_grows_on_backlog_and_shrinks_idle(tmp_path):
    """Queued demand grows the pool through the late-join path; a fully
    idle gateway retires the extra node through graceful retirement."""
    policy = AutoscalePolicy(min_nodes=1, max_nodes=2, scale_up_wait_s=0.1,
                             backlog_per_node=2.0, idle_shrink_s=0.5,
                             cooldown_s=0.3, interval_s=0.05)
    with _service(nodes=1) as svc:
        # max_active_jobs=2 keeps the third ticket visibly *queued* while
        # the first two run — the backlog the scale-up conditions read.
        gw = JobGateway(svc, str(tmp_path / "q.db"), autoscale=policy,
                        max_active_jobs=2)
        try:
            handles = [gw.attach(gw.enqueue(_spec(_slow_double, 20)))
                       for _ in range(3)]
            for h in handles:
                assert h.result(timeout=120) == [2 * i for i in range(20)]
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                counters = svc.telemetry.snapshot()["cluster"]
                if (counters.get("scale_up_events", 0) >= 1
                        and counters.get("scale_down_events", 0) >= 1
                        and len(svc.pool_alive()) == 1):
                    break
                time.sleep(0.1)
            counters = svc.telemetry.snapshot()["cluster"]
            assert counters.get("scale_up_events", 0) >= 1
            assert counters.get("scale_down_events", 0) >= 1
            assert len(svc.pool_alive()) == 1  # back at min_nodes
            # The pool still works after the full grow/shrink cycle.
            assert gw.attach(gw.enqueue(_spec(_double, 6))).result(
                timeout=60) == [2 * i for i in range(6)]
        finally:
            gw.close()


def test_gateway_telemetry_sampler_and_prometheus(tmp_path):
    with _service() as svc:
        gw = JobGateway(svc, str(tmp_path / "q.db"),
                        tenants={"alice": TenantPolicy(weight=2.0,
                                                       max_inflight=4)})
        try:
            gw.attach(gw.enqueue(_spec(_double, 8),
                                 tenant="alice")).result(timeout=60)
            snap = svc.telemetry.snapshot()
            assert snap["gateway"]["mode"] == "fair"
            assert snap["gateway"]["tickets"] == {"done": 1}
            prom = svc.telemetry.prometheus()
            assert 'repro_gateway_tickets{state="done"} 1' in prom
        finally:
            gw.close()


def test_gateway_rejects_bad_arguments(tmp_path):
    with _service() as svc:
        with pytest.raises(ValueError):
            JobGateway(svc, str(tmp_path / "a.db"), max_active_jobs=0)
        gw = JobGateway(svc, str(tmp_path / "q.db"))
        try:
            with pytest.raises(ValueError):
                gw.enqueue(_spec(_double, 2), retries=-1)
        finally:
            gw.close()
        with pytest.raises(RuntimeError):
            gw.enqueue(_spec(_double, 2))  # closed gateway
