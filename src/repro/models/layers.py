"""Shared layers: norms, gated MLPs, embeddings, chunked cross-entropy."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, fan_in_normal


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def rms_norm_spec(d: int, layers: int | None = None) -> ParamSpec:
    if layers is None:
        return ParamSpec((d,), ("d_model",), init="zeros")
    return ParamSpec((layers, d), ("layers", "d_model"), init="zeros")


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate, w_up, w_down, compute_dtype=jnp.bfloat16):
    """x: [..., D]; w_gate/w_up: [D, F]; w_down: [F, D]."""
    xc = x.astype(compute_dtype)
    g = jnp.einsum("...d,df->...f", xc, w_gate.astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", xc, w_up.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(compute_dtype))


def gelu_mlp(x: jax.Array, w_up, w_down, compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", xc, w_up.astype(compute_dtype)))
    return jnp.einsum("...f,fd->...d", h, w_down.astype(compute_dtype))


def mlp_specs(d: int, f: int, layers: int) -> dict:
    return {
        "w_gate": ParamSpec(
            (layers, d, f), ("layers", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, f)),
        ),
        "w_up": ParamSpec(
            (layers, d, f), ("layers", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, f)),
        ),
        "w_down": ParamSpec(
            (layers, f, d), ("layers", "d_ff", "d_model_fsdp"),
            stddev=fan_in_normal((f, d)),
        ),
    }


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_tokens(embedding: jax.Array, tokens: jax.Array, compute_dtype):
    return jnp.take(embedding, tokens, axis=0).astype(compute_dtype)


def lm_logits(x: jax.Array, head: jax.Array, compute_dtype, softcap: float = 0.0):
    logits = jnp.einsum("...d,dv->...v", x.astype(compute_dtype), head.astype(compute_dtype))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits.astype(jnp.float32) / softcap)
    return logits


# ---------------------------------------------------------------------------
# Chunked cross-entropy
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    x: jax.Array,
    head: jax.Array,
    targets: jax.Array,
    *,
    vocab_size: int,
    seq_chunk: int = 512,
    softcap: float = 0.0,
    compute_dtype=jnp.bfloat16,
    unroll: bool = False,
) -> jax.Array:
    """Mean next-token CE without materialising [B, S, V] fp32 logits.

    ``x``: [B, S, D] final hidden states; ``head``: [D, V_padded];
    ``targets``: [B, S] int32.  Scans over sequence chunks: each step
    materialises only [B, chunk, V_padded] logits.  Padded vocab entries are
    masked with -inf so they never contribute to the partition function.
    """
    B, S, D = x.shape
    Vp = head.shape[1]
    seq_chunk = min(seq_chunk, S)
    if S % seq_chunk != 0:
        raise ValueError(f"S={S} not divisible by seq_chunk={seq_chunk}")
    n = S // seq_chunk
    xs = jnp.moveaxis(x.reshape(B, n, seq_chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, seq_chunk), 1, 0)
    pad_mask = (jnp.arange(Vp) >= vocab_size)[None, None, :]

    def body(acc, inp):
        xc, tc = inp
        logits = lm_logits(xc, head, compute_dtype, softcap).astype(jnp.float32)
        logits = jnp.where(pad_mask, NEG_INF_F32, logits)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - tgt), None

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for i in range(n):
            total, _ = body(total, (xs[i], ts[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
    return total / (B * S)


NEG_INF_F32 = -1e30
