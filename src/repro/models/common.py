"""Parameter plumbing shared by every model family.

Models declare their parameters as trees of :class:`ParamSpec` — shape,
*logical axes* (consumed by ``core.channels.ShardingRules``) and an
initializer.  From one spec tree we derive:

* ``init_params``  — materialised arrays (smoke tests / real training);
* ``param_structs`` — ``ShapeDtypeStruct`` stand-ins with shardings attached
  (multi-pod dry-run: no allocation);
* parameter counting for the analytic FLOPs module.

This mirrors how the paper's builder generates node processes from the spec:
the single declaration is the source of truth and everything physical
(placement, init, memory) is derived.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channels import ShardingRules


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | rglru_lambda
    stddev: float = 0.02

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"ParamSpec rank mismatch: {self.shape} vs {self.logical_axes}"
            )


def fan_in_normal(shape: tuple[int, ...], fan_axis: int = -2) -> float:
    """1/sqrt(fan_in) stddev for weight matrices."""
    if len(shape) < 2:
        return 0.02
    return 1.0 / math.sqrt(shape[fan_axis])


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "rglru_lambda":
        # Griffin: Lambda parametrised so that a = exp(-c * softplus(L) * r)
        # starts with forget rates spread in (0.9, 0.999).
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        c = 8.0
        # a^(1/c)? recover L s.t. softplus(L) = -log(a)/c... keep the Griffin
        # parametrisation: L = softplus^{-1}(-log(a) / c * ... ) simplified:
        val = jnp.log(jnp.expm1(-jnp.log(u) * (1.0 / c) * 100.0) + 1e-8)
        return val.astype(dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.stddev).astype(
            dtype
        )
    raise ValueError(f"unknown init {spec.init!r}")


def _iter_leaves(tree: Any, prefix: str = ""):
    if isinstance(tree, ParamSpec):
        yield prefix, tree
        return
    if isinstance(tree, Mapping):
        for k in sorted(tree):
            yield from _iter_leaves(tree[k], f"{prefix}/{k}")
        return
    raise TypeError(f"unexpected node in param spec tree at {prefix}: {type(tree)}")


def init_params(
    spec_tree: Any,
    rng: jax.Array,
    dtype=jnp.float32,
    rules: ShardingRules | None = None,
) -> Any:
    """Materialise a parameter tree (per-leaf keys derived from path names)."""

    def build(tree: Any, prefix: str = "") -> Any:
        if isinstance(tree, ParamSpec):
            key = jax.random.fold_in(rng, _path_hash(prefix))
            arr = _init_leaf(tree, key, dtype)
            if rules is not None:
                arr = jax.device_put(arr, rules.sharding(tree.shape, tree.logical_axes))
            return arr
        return {k: build(v, f"{prefix}/{k}") for k, v in tree.items()}

    return build(spec_tree)


def param_structs(
    spec_tree: Any, rules: ShardingRules, dtype=jnp.float32
) -> Any:
    """ShapeDtypeStruct tree with shardings — the dry-run parameter inputs."""

    def build(tree: Any) -> Any:
        if isinstance(tree, ParamSpec):
            return jax.ShapeDtypeStruct(
                tree.shape,
                dtype,
                sharding=rules.sharding(tree.shape, tree.logical_axes),
            )
        return {k: build(v) for k, v in tree.items()}

    return build(spec_tree)


def param_shardings(spec_tree: Any, rules: ShardingRules) -> Any:
    def build(tree: Any) -> Any:
        if isinstance(tree, ParamSpec):
            return rules.sharding(tree.shape, tree.logical_axes)
        return {k: build(v) for k, v in tree.items()}

    return build(spec_tree)


def count_params(spec_tree: Any) -> int:
    return sum(math.prod(s.shape) for _p, s in _iter_leaves(spec_tree))


def param_bytes(spec_tree: Any, bytes_per_param: int = 4) -> int:
    return count_params(spec_tree) * bytes_per_param


def _path_hash(path: str) -> int:
    h = 2166136261
    for ch in path.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def describe_params(spec_tree: Any, max_rows: int = 60) -> str:
    rows = list(_iter_leaves(spec_tree))
    total = count_params(spec_tree)
    lines = [f"{'param':<52}{'shape':<26}{'count':>14}"]
    for path, spec in rows[:max_rows]:
        lines.append(
            f"{path:<52}{str(spec.shape):<26}{math.prod(spec.shape):>14,}"
        )
    if len(rows) > max_rows:
        lines.append(f"... {len(rows) - max_rows} more")
    lines.append(f"{'TOTAL':<52}{'':<26}{total:>14,}")
    return "\n".join(lines)
