"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps through the full stack (data pipeline -> builder-derived shardings ->
fault-tolerant executor -> checkpointing), with a crash injected mid-run to
demonstrate restore.

The default is sized for this 1-core CPU container (a ~10M model, 60 steps);
pass --full for the ~100M / 300-step variant (same code path, just slower).

Run:  PYTHONPATH=src python examples/train_lm.py [--full]
"""

import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.executor import Trainer, TrainerConfig
from repro.runtime.failures import FailureEvent, FailurePlan


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
        head_dim=64, attn_q_chunk=256, loss_seq_chunk=256,
    )


def model_10m() -> ModelConfig:
    return ModelConfig(
        name="lm-10m", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=8192,
        head_dim=32, attn_q_chunk=128, loss_seq_chunk=128,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_10m()
    steps = args.steps or (300 if args.full else 60)
    shape = ShapeConfig("train", seq_len=512 if args.full else 256,
                        global_batch=8 if args.full else 4, kind="train")

    from repro.models.flops import param_counts
    total, _ = param_counts(cfg)
    print(f"model: {cfg.name} ({total / 1e6:.1f}M non-embedding params), "
          f"{steps} steps of {shape.global_batch}x{shape.seq_len}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg, shape,
            TrainerConfig(num_steps=steps, checkpoint_every=max(steps // 5, 1),
                          checkpoint_dir=ckpt_dir,
                          warmup_steps=max(steps // 10, 1), peak_lr=1e-3),
            opt_cfg=AdamWConfig(),
            failure_plan=FailurePlan(
                [FailureEvent(step=steps // 2, kind="crash")]),
        )
        out = trainer.run()
        losses = [m["ce_loss"] for m in trainer.metrics_history]
        print(f"\nfinished at step {out['final_step']} "
              f"(restarts: {out['restarts']})")
        k = max(len(losses) // 10, 1)
        first = sum(losses[:k]) / k
        last = sum(losses[-k:]) / k
        print(f"ce_loss: first-{k} avg {first:.4f} -> last-{k} avg {last:.4f}")
        assert last < first, "loss should decrease on the synthetic stream"
        print(out["timing"])


if __name__ == "__main__":
    main()
