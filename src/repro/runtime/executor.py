"""The training executor: fault-tolerant step loop over a built deployment.

Control flow (all of it exercised by tests, with failures injected):

    load  : build mesh+rules -> init/restore state -> compile step  (timed)
    run   : per-step: data -> train_step -> metrics                  (timed)
            async checkpoint every K steps
            failure check: crash      -> restore from last checkpoint
                           node_loss  -> elastic re-mesh + restore
                           straggler  -> detect (monitor) -> re-mesh w/o node
    finish: final checkpoint; per-node load/run timing report (paper req. 7)

The paper's demand-driven work distribution appears here twice: the data
pipeline's emit stage is the Emit process, and straggler/failure re-dispatch
is the client-server protocol degenerated to static SPMD between incidents
(DESIGN.md section 2).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager, config_hash
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.builder import ClusterBuilder
from repro.core.channels import ShardingRules
from repro.core.timing import TimingCollector
from repro.data.pipeline import DataPipeline, source_for
from repro.models.common import init_params, param_shardings
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.elastic import ElasticController
from repro.runtime.failures import (
    FailurePlan,
    SimulatedNodeFailure,
    StragglerMonitor,
)

log = logging.getLogger("repro.executor")


@dataclass
class TrainerConfig:
    num_steps: int = 20
    checkpoint_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    seed: int = 0
    tp: int = 1
    resume: bool = True
    max_restarts: int = 4


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        shape: ShapeConfig,
        trainer_cfg: TrainerConfig,
        opt_cfg: adamw.AdamWConfig | None = None,
        rules: ShardingRules | None = None,
        mesh=None,
        failure_plan: FailurePlan | None = None,
        elastic: ElasticController | None = None,
    ):
        self.model_cfg = model_cfg
        self.shape = shape
        self.cfg = trainer_cfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.rules = rules
        self.mesh = mesh
        self.failure_plan = failure_plan or FailurePlan()
        self.elastic = elastic
        self.timing = TimingCollector()
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(
            trainer_cfg.checkpoint_dir, keep=trainer_cfg.keep_checkpoints
        )
        self.metrics_history: list[dict] = []
        self.restarts = 0
        self.excluded_nodes: set[int] = set()
        self._build()

    # -- load phase -----------------------------------------------------------

    def _build(self) -> None:
        with self.timing.phase("host", "load"):
            builder = ClusterBuilder(mesh=self.mesh, rules=self.rules,
                                     timing=self.timing)
            self.train_step = jax.jit(
                steps_mod.make_train_step(
                    self.model_cfg, self.opt_cfg, tp=self.cfg.tp,
                    rules=self.rules, peak_lr=self.cfg.peak_lr,
                    warmup_steps=self.cfg.warmup_steps,
                    total_steps=self.cfg.num_steps,
                ),
                donate_argnums=(0, 1),
            )
            self.pipeline = DataPipeline(
                source_for(self.model_cfg, self.shape, seed=self.cfg.seed),
                self.rules,
            )
            self.step0, self.params, self.opt_state = self._init_or_restore()

    def _state_shardings(self):
        if self.rules is None:
            return None
        specs = steps_mod.model_param_specs(self.model_cfg, self.cfg.tp)
        p_sh = param_shardings(specs, self.rules)
        return {
            "params": p_sh,
            "opt": {"m": p_sh, "v": p_sh, "count": None},
        }

    def _init_or_restore(self):
        meta = {"config_hash": config_hash(self.model_cfg)}
        if self.cfg.resume and self.ckpt.latest_step() is not None:
            sh = self._state_shardings()
            step, state, _m = self.ckpt.restore(
                shardings=sh, expect_meta=meta
            )
            log.info("restored checkpoint at step %d", step)
            return step, state["params"], state["opt"]
        specs = steps_mod.model_param_specs(self.model_cfg, self.cfg.tp)
        params = init_params(
            specs, jax.random.PRNGKey(self.cfg.seed),
            jnp.dtype(self.model_cfg.param_dtype), rules=self.rules,
        )
        opt_state = adamw.init_state(params, self.opt_cfg)
        return 0, params, opt_state

    def _save(self, step: int, block: bool = False) -> None:
        state = {"params": self.params, "opt": self.opt_state}
        meta = {"config_hash": config_hash(self.model_cfg)}
        if block:
            self.ckpt.save(step, state, meta)
        else:
            self.ckpt.save_async(step, state, meta)

    # -- failure handling -------------------------------------------------------

    def _handle_failure(self, exc: SimulatedNodeFailure) -> None:
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted") from exc
        log.warning("handling %s (restart %d)", exc, self.restarts)
        self.ckpt.wait()
        if exc.kind in ("node_loss", "straggler") and self.elastic is not None:
            self.excluded_nodes.add(exc.node)
            nodes = self.elastic.largest_batch_divisor_nodes(
                self.shape.global_batch, self.excluded_nodes
            )
            self.mesh, self.rules = self.elastic.build(nodes)
            log.warning("elastic re-mesh onto nodes %s -> mesh %s",
                        nodes, dict(self.mesh.shape))
        # Crash or re-mesh: rebuild compiled artifacts + restore state.
        self._build()

    # -- run phase ---------------------------------------------------------------

    def run(self) -> dict:
        step = self.step0
        end = self.cfg.num_steps
        while step < end:
            try:
                ev = self.failure_plan.check(step)
                if ev is not None and ev.kind in ("crash", "node_loss"):
                    raise SimulatedNodeFailure(step, ev.kind, ev.node)
                t0 = time.perf_counter()
                batch = self.pipeline.get(step)
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch, jnp.int32(step)
                )
                if ev is not None and ev.kind == "straggler":
                    time.sleep(ev.slowdown * max(self.monitor.median(), 1e-3))
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.timing.add("host", "run", dt * 1e3)
                straggling = self.monitor.record(dt)
                if straggling and self.elastic is not None and ev is not None:
                    raise SimulatedNodeFailure(step, "straggler", ev.node)
                self.metrics_history.append(
                    {k: float(v) for k, v in metrics.items()} | {"step": step}
                )
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self._save(step)
            except SimulatedNodeFailure as exc:
                self._handle_failure(exc)
                step = self.step0
        self.ckpt.wait()
        self._save(end, block=True)
        return {
            "final_step": end,
            "restarts": self.restarts,
            "last_metrics": self.metrics_history[-1] if self.metrics_history else {},
            "timing": self.timing.report(),
        }
