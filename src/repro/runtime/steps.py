"""Step-function factories: the "cluster stage" bodies the builder deploys.

Everything the dry-run, the trainer, the serving engine and the benchmarks
lower comes from here, so every consumer sees the same semantics:

* ``make_train_step``   — fwd + bwd + AdamW, donated state (train_4k);
* ``make_prefill_step`` — full-sequence forward to last-token logits
  (prefill_32k);
* ``make_decode_step``  — one token against the KV/state cache
  (decode_32k / long_500k);
* ``*_structs``         — matching ShapeDtypeStruct inputs with shardings
  derived by the builder rules (the dry-run's no-allocation inputs).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.channels import ShardingRules
from repro.data.pipeline import BATCH_AXES
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.common import param_shardings, param_structs
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine

ENC_LEN_CAP = 4096  # encoder frames for decode shapes (source is bounded)


def model_param_specs(cfg: ModelConfig, tp: int = 1):
    if cfg.encoder_layers:
        return encdec_mod.encdec_param_specs(cfg, tp)
    return lm_mod.lm_param_specs(cfg, tp)


def loss_fn_for(cfg: ModelConfig, tp: int, rules: ShardingRules | None):
    if cfg.encoder_layers:
        return lambda p, b: encdec_mod.encdec_loss(cfg, p, b, tp=tp, rules=rules)
    return lambda p, b: lm_mod.lm_loss(cfg, p, b, tp=tp, rules=rules)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    tp: int = 1,
    rules: ShardingRules | None = None,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10000,
) -> Callable:
    loss_fn = loss_fn_for(cfg, tp, rules)

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        lr = warmup_cosine(step, peak_lr=peak_lr, warmup_steps=warmup_steps,
                           total_steps=total_steps)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr
        )
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def train_state_structs(cfg: ModelConfig, rules: ShardingRules, tp: int,
                        opt_cfg: adamw.AdamWConfig):
    """(param structs, opt-state structs) for dry-run lowering."""
    specs = model_param_specs(cfg, tp)
    p_structs = param_structs(specs, rules, dtype=jnp.dtype(cfg.param_dtype))
    sdt = jnp.dtype(opt_cfg.state_dtype)
    moments = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, sdt, sharding=s.sharding),
        p_structs,
    )
    opt_structs = {
        "m": moments,
        "v": moments,
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return p_structs, opt_structs


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules):
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=rules.sharding((B, S), BATCH_AXES["tokens"])
        ),
        "targets": jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=rules.sharding((B, S), BATCH_AXES["targets"])
        ),
    }
    if cfg.encoder_layers:
        shp = (B, S, cfg.d_model)
        out["frames"] = jax.ShapeDtypeStruct(
            shp, jnp.bfloat16, sharding=rules.sharding(shp, BATCH_AXES["frames"])
        )
        del out["tokens"]
        out["tokens"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=rules.sharding((B, S), BATCH_AXES["tokens"])
        )
    elif cfg.frontend:
        shp = (B, cfg.frontend_len, cfg.d_model)
        out["extra_embeds"] = jax.ShapeDtypeStruct(
            shp, jnp.bfloat16,
            sharding=rules.sharding(shp, BATCH_AXES["extra_embeds"]),
        )
    return out


# ---------------------------------------------------------------------------
# Prefill (full-sequence forward, last-token logits)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, *, tp: int = 1,
                      rules: ShardingRules | None = None) -> Callable:
    if cfg.encoder_layers:
        def prefill_step(params, batch):
            enc_out = encdec_mod.encode(cfg, params, batch["frames"],
                                        tp=tp, rules=rules)
            x = encdec_mod.decode_train(cfg, params, batch["tokens"], enc_out,
                                        tp=tp, rules=rules)
            cdt = jnp.dtype(cfg.compute_dtype)
            return jnp.einsum("bd,dv->bv", x[:, -1].astype(cdt),
                              params["lm_head"].astype(cdt))
    else:
        def prefill_step(params, batch):
            x, _aux = lm_mod.forward_hidden(
                cfg, params, batch["tokens"], tp=tp, rules=rules,
                extra_embeds=batch.get("extra_embeds"),
            )
            return lm_mod.logits_from_hidden(cfg, params, x[:, -1:])[:, 0]

    return prefill_step


def prefill_batch_structs(cfg: ModelConfig, shape: ShapeConfig,
                          rules: ShardingRules):
    structs = batch_structs(cfg, shape, rules)
    structs.pop("targets", None)
    return structs


# ---------------------------------------------------------------------------
# Decode (one token vs cache)
# ---------------------------------------------------------------------------


def make_decode_step(cfg: ModelConfig, *, tp: int = 1,
                     rules: ShardingRules | None = None) -> Callable:
    if cfg.encoder_layers:
        def decode_step(params, cache, tokens, cache_len):
            return encdec_mod.encdec_decode_step(
                cfg, params, cache, tokens, cache_len, tp=tp, rules=rules
            )
    else:
        def decode_step(params, cache, tokens, cache_len):
            return lm_mod.decode_step(
                cfg, params, cache, tokens, cache_len, tp=tp, rules=rules
            )

    return decode_step


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules,
                  tp: int):
    """ShapeDtypeStructs for the decode cache (no allocation)."""
    B = shape.global_batch
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.encoder_layers:
        hp = lm_mod.head_plan(cfg, tp)
        nd, Kp, hd = cfg.num_layers, hp["Kp"], cfg.head_dim
        enc_len = min(shape.seq_len, ENC_LEN_CAP)
        shapes = {
            "k": ((nd, B, shape.seq_len, Kp, hd),
                  ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
            "v": ((nd, B, shape.seq_len, Kp, hd),
                  ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
            "xk": ((nd, B, enc_len, Kp, hd),
                   ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
            "xv": ((nd, B, enc_len, Kp, hd),
                   ("layers", "batch", "kv_seq", "kv_heads", "head_dim")),
        }
        return {
            k: jax.ShapeDtypeStruct(shp, dt, sharding=rules.sharding(shp, ax))
            for k, (shp, ax) in shapes.items()
        }
    spec = lm_mod.cache_spec(cfg, B, shape.seq_len, tp, dtype=dt)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s[0], s[1], sharding=rules.sharding(s[0], s[2])
        ),
        spec,
        is_leaf=lm_mod._is_spec_leaf,
    )


def decode_input_structs(cfg: ModelConfig, shape: ShapeConfig,
                         rules: ShardingRules, tp: int):
    B = shape.global_batch
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=rules.sharding((B, 1), ("batch", "seq"))
    )
    cache = cache_structs(cfg, shape, rules, tp)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, cache_len
