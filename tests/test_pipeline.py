"""The generalised spec API: PipelineSpec, the fluent builder, multi-stage
execution on both backends, per-stage deployment plans and the
readonly-delivery parity switch.

The one-stage special case is covered by the pre-existing ClusterSpec tests
(unmodified); this module exercises what the generalisation adds.
"""

import numpy as np
import pytest

from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec, Pipeline, PipelineSpec, Stage
from repro.core.processes import EmitDetails, ResultDetails
from repro.core.verify import verify_pipeline, verify_spec
from repro.runtime.failures import WorkFunctionError

# Fast liveness settings for cluster-backend tests (as in test_cluster).
FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _sum_collect():
    return ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)


def _two_stage(n_items=30, square_nodes=2, square_workers=2):
    return (Pipeline(host="127.0.0.1")
            .emit(_range_emit(n_items))
            .stage(lambda x: x * x, nodes=square_nodes,
                   workers=square_workers, name="square")
            .stage(lambda x: x + 1, nodes=1, workers=2, name="inc")
            .collect(_sum_collect())
            .build())


# ---------------------------------------------------------------------------
# construction + structure
# ---------------------------------------------------------------------------


def test_fluent_builder_produces_validated_pipeline():
    spec = _two_stage()
    assert spec.nstages == 2
    assert spec.total_nodes == 3
    assert [st.name for st in spec.stages] == ["square", "inc"]
    assert spec.node_assignments() == [
        ("node0", 0), ("node1", 0), ("node2", 1)
    ]
    # respawn replacements map through their base id; unknowns -> stage 0
    assert spec.stage_of("node2r1") == 1
    assert spec.stage_of("ws07-1234") == 0


def test_fluent_builder_rejects_misuse():
    with pytest.raises(ValueError, match="emit"):
        Pipeline(host="h").stage(lambda x: x)
    with pytest.raises(ValueError, match="missing"):
        Pipeline(host="h").emit(_range_emit(1)).build()
    p = Pipeline(host="h").emit(_range_emit(1)).stage(lambda x: x, name="a")
    with pytest.raises(ValueError, match="duplicate stage name"):
        p.stage(lambda x: x, name="a")
    p.collect(_sum_collect())
    with pytest.raises(ValueError, match="precede collect"):
        p.stage(lambda x: x)


def test_cluster_spec_is_the_one_stage_special_case():
    spec = ClusterSpec.simple(
        host="10.0.0.1", nclusters=3, workers_per_node=2,
        emit_details=_range_emit(5), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    pipe = spec.as_pipeline()
    assert pipe.nstages == 1
    assert pipe.nclusters == 3 and pipe.workers_per_node == 2
    # the very records, not copies: the wrapper is thin
    assert pipe.stages[0].node_net is spec.node_net
    assert pipe.stages[0].afo is spec.host_net.afo
    assert pipe.host_net.emit is spec.host_net.emit
    # and it collapses back
    back = pipe.as_cluster_spec()
    assert back.nclusters == 3 and back.host == "10.0.0.1"
    back.validate()


def test_multi_stage_pipeline_rejects_single_stage_accessors():
    spec = _two_stage()
    with pytest.raises(ValueError, match="one-stage"):
        spec.nclusters
    with pytest.raises(ValueError, match="one-stage"):
        spec.workers_per_node


# ---------------------------------------------------------------------------
# execution — threads backend
# ---------------------------------------------------------------------------


def test_two_stage_pipeline_runs_on_threads():
    n = 40
    builder = ClusterBuilder()
    app = builder.build_application(_two_stage(n))
    assert app.run() == sum(i * i + 1 for i in range(n))
    items = {t.node_id: t.items for t in builder.timing.nodes
             if t.node_id.startswith("node")}
    # stage square (node0, node1) shares the emit stream; stage inc (node2)
    # processes every forwarded result.
    assert items["node0"] + items["node1"] == n
    assert items["node2"] == n


def test_three_stage_pipeline_runs_on_threads():
    n = 24
    spec = PipelineSpec.simple(
        host="h",
        emit_details=_range_emit(n),
        stages=[
            Stage("a", lambda x: x + 1, nclusters=2, workers_per_node=1),
            Stage("b", lambda x: x * 2, nclusters=1, workers_per_node=2),
            Stage("c", lambda x: x - 3, nclusters=1, workers_per_node=1),
        ],
        result_details=_sum_collect(),
    )
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum((i + 1) * 2 - 3 for i in range(n))


def test_threads_work_function_error_fails_fast():
    def bad(x):
        if x == 3:
            raise ValueError("item 3 is cursed")
        return x

    spec = ClusterSpec.simple(
        host="h", nclusters=2, workers_per_node=1,
        emit_details=_range_emit(10), work_function=bad,
        result_details=_sum_collect(),
    )
    app = ClusterBuilder().build_application(spec)
    with pytest.raises(WorkFunctionError, match="item 3 is cursed"):
        app.run()


# ---------------------------------------------------------------------------
# execution — cluster backend (real subprocesses)
# ---------------------------------------------------------------------------


def test_two_stage_pipeline_matches_on_cluster_backend():
    """Acceptance: the same two-stage spec, zero changes, over real
    node-loader subprocesses — matching result, per-stage routing stats,
    exactly-once, clean shutdown."""
    n = 30
    expected = sum(i * i + 1 for i in range(n))
    threaded = ClusterBuilder().build_application(_two_stage(n)).run()
    assert threaded == expected

    builder = ClusterBuilder()
    app = builder.build_application(
        _two_stage(n), backend="cluster", job_timeout=120.0, **FAST
    )
    assert app.run() == expected

    stats = app.host_loader.stats
    assert stats.items_total == n  # final-stage results collected once each
    assert stats.forwarded == n  # every stage-0 result re-entered as work
    assert stats.duplicates_dropped == 0 and stats.deaths_detected == 0
    assert len(app.processes) == 3
    assert app.orphaned() == []
    # stage inc's node processed the full stream
    items = {t.node_id: t.items for t in builder.timing.nodes
             if t.node_id.startswith("node")}
    assert items["node0"] + items["node1"] == n
    assert items["node2"] == n


# ---------------------------------------------------------------------------
# verification of the chained network
# ---------------------------------------------------------------------------


def test_verify_spec_on_two_stage_pipeline():
    report = verify_spec(_two_stage())
    assert report.ok, report.summary()
    assert report.stage_shapes is not None and len(report.stage_shapes) == 2
    assert "pipeline" in report.summary()


def test_verify_pipeline_chained_assertions():
    for shapes in ([(2, 1), (1, 1)], [(1, 1), (2, 1)], [(2, 1), (2, 1)]):
        report = verify_pipeline(shapes, num_objects=3)
        assert report.ok, report.summary()
    # single-entry list is the paper's network verbatim
    assert verify_pipeline([(2, 1)], num_objects=5).num_states > 1000


# ---------------------------------------------------------------------------
# deployment plan (per-stage, real addresses)
# ---------------------------------------------------------------------------


def test_deployment_plan_groups_nodes_per_stage():
    plan = ClusterBuilder().deployment_plan(_two_stage())
    assert [sp.name for sp in plan.stages] == ["square", "inc"]
    assert [len(sp.nodes) for sp in plan.stages] == [2, 1]
    assert plan.nodes[2].stage == "inc"
    assert "stage=inc" in plan.describe()
    assert any("per-stage credit accounting" in s for s in plan.load_order())


def test_deployment_plan_derives_real_addresses():
    spec = ClusterSpec.simple(
        host="192.168.1.176", nclusters=3, workers_per_node=1,
        emit_details=_range_emit(3), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    builder = ClusterBuilder()
    # hosts= assigns machines round-robin, exactly as SSHLauncher will
    plan = builder.deployment_plan(spec, hosts=["ws01", "ws02"])
    assert [n.address.split(":")[0] for n in plan.nodes] == [
        "ws01", "ws02", "ws01"
    ]
    # a launcher exposing .hosts works the same way
    class FakeLauncher:
        hosts = ["wsA"]
    plan = builder.deployment_plan(spec, launcher=FakeLauncher())
    assert all(n.address.startswith("wsA:") for n in plan.nodes)
    # local deployments dial the bind address (wildcard -> loopback)
    plan = builder.deployment_plan(spec, bind_host="0.0.0.0")
    assert all(n.address.startswith("127.0.0.1:") for n in plan.nodes)
    # no deployment info at all: documentation placeholders (unchanged)
    plan = builder.deployment_plan(spec)
    assert plan.nodes[0].address.startswith("192.168.1.100:")


def test_cluster_backend_plan_reflects_deployment():
    app = ClusterBuilder().build_application(
        _two_stage(4), backend="cluster"
    )
    # never started: just inspect the derived plan
    assert all(n.address.startswith("127.0.0.1:") for n in app.plan.nodes)


# ---------------------------------------------------------------------------
# readonly delivery (threads/cluster semantic parity)
# ---------------------------------------------------------------------------


def _array_emit(n):
    return EmitDetails(
        name="arrays",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: ((None, s) if s[0] >= s[1]
                          else (np.full(4, float(s[0])), (s[0] + 1, s[1]))),
    )


def _float_sum():
    return ResultDetails(name="sum", init=lambda: 0.0,
                         collect=lambda a, x: a + x)


def test_readonly_delivery_hands_out_immutable_views():
    def probe(x):
        assert isinstance(x, np.ndarray)
        return 0.0 if x.flags.writeable else 1.0

    def make():
        return ClusterSpec.simple(
            host="127.0.0.1", nclusters=1, workers_per_node=2,
            emit_details=_array_emit(6), work_function=probe,
            result_details=_float_sum(),
        )

    # default threads backend: the original, writable array (documented)
    assert ClusterBuilder().build_application(make()).run() == 0.0
    # readonly_delivery: every delivery is an immutable view
    assert ClusterBuilder().build_application(
        make(), readonly_delivery=True
    ).run() == 6.0


def test_readonly_delivery_catches_cluster_mutation_bugs_single_host():
    """The regression the option exists for: a work function that mutates
    its input in place passes on the default threads backend but fails on
    the cluster's zero-copy wire — readonly_delivery=True reproduces the
    cluster failure on one host, same exception type."""

    def mutating(x):
        x[0] = -1.0  # in-place write
        return float(x.sum())

    def make():
        return ClusterSpec.simple(
            host="127.0.0.1", nclusters=1, workers_per_node=1,
            emit_details=_array_emit(4), work_function=mutating,
            result_details=_float_sum(),
        )

    # silently "works" on the default threads backend...
    ClusterBuilder().build_application(make()).run()
    # ...fails under readonly_delivery, like the cluster backend would
    with pytest.raises(WorkFunctionError):
        ClusterBuilder().build_application(
            make(), readonly_delivery=True
        ).run()
