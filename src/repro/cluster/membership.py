"""Node registry + heartbeat tracking for the Host-Node-Loader.

The paper's HNL learns the cluster's membership from the registration
messages arriving on the load network (port 2000 / channel 1) and assumes
workstations stay up; we extend that with the standard heartbeat liveness
protocol so a dead Node-Loader subprocess is *detected* (via
:class:`repro.runtime.failures.HeartbeatMonitor` thresholds) and its
in-flight work re-dispatched — the same detect→recover control path the SPMD
executor exercises with injected ``node_loss`` events, now driven by a real
process death.

Pure bookkeeping: no sockets here.  The host loader feeds events in
(``register``/``beat``/``mark_*``) and polls :meth:`Membership.reap` from
its dispatcher loop.
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any

from repro.runtime.failures import FailureEvent, HeartbeatMonitor

# Node lifecycle:
#   LAUNCHING -(REGISTER)-> REGISTERED -(LOAD)-> LOADED -(UT ack)-> DONE
#       |                        \-----------(missed beats)-------> DEAD
#       \-(respawned elsewhere)-> REPLACED -(its launch registers late)
#                                     \------(REGISTER)-----------> REGISTERED
# LAUNCHING records exist only when the deployment layer announces expected
# launches up front (``expect``); direct ``register`` calls still create
# records from scratch (an unannounced/elastic node).
LAUNCHING = "launching"
REGISTERED = "registered"
LOADED = "loaded"
DONE = "done"
DEAD = "dead"
REPLACED = "replaced"


@dataclass
class NodeRecord:
    node_id: str
    index: int  # dense index, used as FailureEvent.node
    address: str  # observed peer ip:port
    cores: int = 1
    pid: int = 0
    state: str = REGISTERED
    attempts: int = 1  # launch attempts (respawns bump the replacement's)
    launched_at: float = 0.0  # when the launch was announced (expect)
    registered_at: float = 0.0
    last_beat: float = 0.0
    beats: int = 0
    items_done: int = 0
    # Outstanding demand the host could not satisfy yet (credit-based
    # pipelining): credits the node sent that are parked until new items
    # appear (re-dispatch) or the job terminates (answered with UT).
    credits: int = 0
    # Multi-job service state.  ``jobs_loaded`` holds the job ids whose LOAD
    # this node has acked — the host only dispatches job-J work to a node
    # once J is in here (no work-before-code races).  ``code_digests`` is
    # the host-side mirror of the node's warm code-cache LRU (digest ->
    # None, insertion-ordered, same capacity and touch order as the node's),
    # so the host knows which stage functions it can skip re-shipping.
    jobs_loaded: set = field(default_factory=set)
    code_digests: collections.OrderedDict = field(
        default_factory=collections.OrderedDict
    )
    timing: dict[str, Any] = field(default_factory=dict)
    conn: Any = None  # FrameConnection; opaque to this module
    # Observability: when the current state was entered, and the full
    # (state, monotonic time) history — the events feed and dashboard show
    # *when* a node registered/died/was replaced, not just that it did.
    state_changed_at: float = 0.0
    transitions: list = field(default_factory=list)
    # The FailureEvent recorded when this node was declared dead (None
    # while alive) — the heal path reads its detection metadata.
    last_failure: Any = None
    # Graceful retirement (pool shrink): set when the host decided to UT
    # this node mid-run.  A retiring node is fenced from new work
    # (``_answer`` skips it) but stays ``alive`` until its UT ack lands —
    # its in-flight items are requeued there, not reaped as a death.
    retiring: bool = False
    # Listening port of the node's peer data-plane server (0 = none
    # reported; the node is unreachable for peer routing / block trading
    # and routing tables simply omit it).
    peer_port: int = 0

    @property
    def alive(self) -> bool:
        return self.state in (REGISTERED, LOADED)


class Membership:
    """The HNL's view of the cluster, with heartbeat-based death detection."""

    def __init__(self, monitor: HeartbeatMonitor | None = None):
        self.monitor = monitor or HeartbeatMonitor()
        self.nodes: dict[str, NodeRecord] = {}
        self.failures: list[FailureEvent] = []
        # Observability hook: called as on_transition(rec, old_state) after
        # every state change.  The host loader wires this to the telemetry
        # bus; pure-bookkeeping users leave it None.
        self.on_transition: Any = None

    def _transition(self, rec: NodeRecord, state: str,
                    now: float | None = None) -> None:
        """Single choke point for state changes: stamps the time, records
        the history, and fires ``on_transition``."""
        now = time.monotonic() if now is None else now
        old = rec.state
        rec.state = state
        rec.state_changed_at = now
        rec.transitions.append((state, now))
        if self.on_transition is not None:
            self.on_transition(rec, old)

    def expect(self, node_id: str, now: float | None = None) -> NodeRecord:
        """Announce a launch: a record in LAUNCHING until REGISTER arrives."""
        if node_id in self.nodes:
            raise ValueError(f"duplicate launch announcement for {node_id!r}")
        now = time.monotonic() if now is None else now
        rec = NodeRecord(
            node_id=node_id,
            index=len(self.nodes),
            address="",
            state=LAUNCHING,
            launched_at=now,
            state_changed_at=now,
        )
        rec.transitions.append((LAUNCHING, now))
        self.nodes[node_id] = rec
        return rec

    def retract(self, node_id: str, now: float | None = None) -> bool:
        """Withdraw a launch announcement whose launch never produced a
        process (``launcher.launch`` raised): the record leaves LAUNCHING
        — straight to DEAD, with no FailureEvent since there was never a
        node to lose — so it stops counting as capacity on its way and
        stops keeping stages eligible.  Refused (False) once the node
        registered or otherwise left LAUNCHING."""
        rec = self.nodes.get(node_id)
        if rec is None or rec.state != LAUNCHING:
            return False
        self._transition(rec, DEAD, now)
        rec.credits = 0
        return True

    def register(self, node_id: str, address: str, *, cores: int = 1,
                 pid: int = 0, conn: Any = None, peer_port: int = 0,
                 now: float | None = None) -> NodeRecord:
        now = time.monotonic() if now is None else now
        rec = self.nodes.get(node_id)
        if rec is not None:
            # An announced launch showing up — or a replaced launch arriving
            # late, which is still a usable worker (exactly-once collection
            # is guaranteed by result-id dedup, so admit it).
            if rec.state not in (LAUNCHING, REPLACED):
                raise ValueError(f"duplicate registration for {node_id!r}")
            rec.address = address
            rec.cores = cores
            rec.pid = pid
            rec.conn = conn
            rec.peer_port = peer_port
            rec.registered_at = rec.last_beat = now
            self._transition(rec, REGISTERED, now)
            return rec
        rec = NodeRecord(
            node_id=node_id,
            index=len(self.nodes),
            address=address,
            cores=cores,
            pid=pid,
            launched_at=now,
            registered_at=now,
            last_beat=now,
            conn=conn,
            peer_port=peer_port,
            state=LAUNCHING,
        )
        self.nodes[node_id] = rec
        self._transition(rec, REGISTERED, now)
        return rec

    def replace(self, node_id: str) -> NodeRecord:
        """A silent launch was respawned elsewhere: retire the old attempt."""
        rec = self.nodes[node_id]
        if rec.state != LAUNCHING:
            raise ValueError(
                f"cannot replace {node_id!r} in state {rec.state!r}"
            )
        self._transition(rec, REPLACED)
        return rec

    def beat(self, node_id: str, now: float | None = None) -> None:
        rec = self.nodes.get(node_id)
        if rec is None or not rec.alive:
            return  # late beat from an already-reaped node: ignore
        rec.last_beat = time.monotonic() if now is None else now
        rec.beats += 1

    def mark_loaded(self, node_id: str) -> None:
        self._transition(self.nodes[node_id], LOADED)

    def mark_done(self, node_id: str, timing: dict[str, Any] | None = None) -> None:
        rec = self.nodes[node_id]
        self._transition(rec, DONE)
        if timing:
            rec.timing = dict(timing)

    def mark_dead(self, node_id: str, *, at_item: int = 0,
                  now: float | None = None) -> FailureEvent | None:
        rec = self.nodes.get(node_id)
        if rec is None or rec.state == DEAD:
            return None
        now = time.monotonic() if now is None else now
        self._transition(rec, DEAD, now)
        rec.credits = 0  # a dead node's parked demand can never be answered
        # Detection latency: silence observed before we declared death —
        # bounded below by the monitor deadline when beats ever arrived.
        latency = max(0.0, now - rec.last_beat) if rec.last_beat else 0.0
        ev = FailureEvent(step=at_item, kind="node_loss", node=rec.index,
                          node_id=node_id, detect_latency_s=latency)
        self.failures.append(ev)
        rec.last_failure = ev
        return ev

    # -- liveness -----------------------------------------------------------

    def reap(self, now: float | None = None, *, at_item: int = 0
             ) -> list[NodeRecord]:
        """Declare nodes whose heartbeats exceeded the threshold dead."""
        now = time.monotonic() if now is None else now
        newly_dead = []
        for rec in self.nodes.values():
            if rec.alive and self.monitor.is_dead(rec.last_beat, now):
                self.mark_dead(rec.node_id, at_item=at_item, now=now)
                newly_dead.append(rec)
        return newly_dead

    # -- queries ------------------------------------------------------------

    def alive_nodes(self) -> list[NodeRecord]:
        return [r for r in self.nodes.values() if r.alive]

    def launching_nodes(self) -> list[NodeRecord]:
        return [r for r in self.nodes.values() if r.state == LAUNCHING]

    def arrived_count(self) -> int:
        """Launches that turned into real cluster members (any state past
        LAUNCHING, except abandoned REPLACED attempts)."""
        return sum(1 for r in self.nodes.values()
                   if r.state not in (LAUNCHING, REPLACED))

    def finished(self) -> bool:
        """True when no node is still expected to produce anything.

        LAUNCHING records (a degraded start's missing stragglers, still
        eligible to late-join) and REPLACED ones don't block termination —
        only members that actually joined the application network do.
        """
        return all(r.state not in (REGISTERED, LOADED)
                   for r in self.nodes.values())

    def describe(self, now: float | None = None) -> str:
        now = time.monotonic() if now is None else now
        lines = [f"{'node':<10}{'state':<12}{'addr':<22}{'beats':>6}"
                 f"{'items':>7}{'in-state':>10}"]
        for r in sorted(self.nodes.values(), key=lambda r: r.index):
            in_state = now - r.state_changed_at if r.state_changed_at else 0.0
            lines.append(
                f"{r.node_id:<10}{r.state:<12}{r.address:<22}"
                f"{r.beats:>6d}{r.items_done:>7d}{in_state:>9.1f}s"
            )
        return "\n".join(lines)
