"""Deterministic synthetic data pipeline (the Emit substrate).

The paper's Emit process generates work objects from a sequential data class
(``Mdata.createInstance``); here the emit stage of a training deployment is a
*sharded batch pipeline*.  The synthetic stream is:

* **deterministic** — ``tokens[step, b, s] = philox(seed, step, b, s) % vocab``
  so every restart / re-mesh / elastic resume reproduces the exact stream
  (the checkpoint records only ``step``);
* **host-sharded** — each host materialises only its addressable shard and
  the global array is assembled with ``jax.make_array_from_callback`` (on a
  single-host CPU container this degenerates to a device_put, but the code
  path is the multi-host one);
* **structured** — next-token targets; optional frontend stub embeddings for
  the VLM/audio archs.

A real corpus plugs in by implementing :class:`BatchSource`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.channels import ShardingRules
from repro.core.processes import EmitDetails


class BatchSource(Protocol):
    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Return the *global* (unsharded) numpy batch for ``step``."""


@dataclass
class SyntheticLM(BatchSource):
    """Philox-counter LM stream: reproducible, seekable, infinite."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_len: int = 0
    d_model: int = 0  # for frontend stub embeddings
    encdec: bool = False

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.Philox(key=self.seed + (step << 20))
        gen = np.random.Generator(rng)
        B, S = self.global_batch, self.seq_len
        tokens = gen.integers(0, self.vocab_size, size=(B, S + 1), dtype=np.int32)
        out = {"tokens": tokens[:, :S], "targets": tokens[:, 1:]}
        if self.encdec:
            out["frames"] = gen.standard_normal((B, S, self.d_model)).astype(
                np.float32
            )
        elif self.frontend_len:
            out["extra_embeds"] = gen.standard_normal(
                (B, self.frontend_len, self.d_model)
            ).astype(np.float32)
        return out


def source_for(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        frontend_len=cfg.frontend_len if cfg.frontend == "vit" else 0,
        d_model=cfg.d_model,
        encdec=bool(cfg.encoder_layers),
    )


BATCH_AXES: dict[str, tuple] = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "extra_embeds": ("batch", "seq", "d_model"),
    "frames": ("batch", "seq", "d_model"),
}


def shard_batch(batch: dict[str, np.ndarray], rules: ShardingRules) -> dict:
    """Assemble global device arrays from (host-local) numpy shards."""
    out = {}
    for name, arr in batch.items():
        sharding = rules.sharding(arr.shape, BATCH_AXES[name])
        out[name] = jax.make_array_from_callback(
            arr.shape, sharding, lambda idx, a=arr: a[idx]
        )
    return out


class DataPipeline:
    """step -> sharded device batch, with one-batch prefetch."""

    def __init__(self, source: BatchSource, rules: ShardingRules | None):
        self.source = source
        self.rules = rules
        self._prefetched: tuple[int, Any] | None = None

    def get(self, step: int) -> dict:
        if self._prefetched is not None and self._prefetched[0] == step:
            batch = self._prefetched[1]
            self._prefetched = None
            return batch
        return self._materialise(step)

    def prefetch(self, step: int) -> None:
        if self._prefetched is None or self._prefetched[0] != step:
            self._prefetched = (step, self._materialise(step))

    def _materialise(self, step: int) -> dict:
        np_batch = self.source.batch(step)
        if self.rules is None:
            return {k: jnp.asarray(v) for k, v in np_batch.items()}
        return shard_batch(np_batch, self.rules)


def emit_details_for(source: BatchSource, num_steps: int) -> EmitDetails:
    """Adapter: the data pipeline as the DSL's Emit stage (``Mdata`` role)."""

    def create(state):
        step = state
        if step >= num_steps:
            return None, state
        return (step, source.batch(step)), step + 1

    return EmitDetails(name=type(source).__name__, create=create,
                       init=lambda: 0, init_data=())
