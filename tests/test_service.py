"""The persistent warm cluster service (repro.cluster.service).

One pool, many jobs: concurrent submissions interleaving on the same
nodes with exactly-once preserved per job (including through a mid-run
node death), warm resubmission skipping both boot and code shipping,
FIFO-with-priority admission, failure isolation between jobs, and the
``backend="service"`` builder path.  Everything runs on 127.0.0.1 with an
InProcessLauncher (real sockets, real frames, no subprocess cost), so
tier-1 stays hermetic.
"""

import time

import pytest

from repro.cluster.deploy.inprocess import InProcessLauncher
from repro.cluster.service import ClusterService
from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails

# Fast liveness settings (death detected within ~0.4s).
FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _list_collect():
    return ResultDetails(name="list", init=lambda: [],
                         collect=lambda a, x: a + [x], finalise=sorted)


def _spec(work, n_items, *, nclusters=2, workers=2):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_list_collect(),
    )


def _service(**kw):
    kw.setdefault("nodes", 2)
    kw.setdefault("workers", 2)
    kw.setdefault("launcher", InProcessLauncher())
    kw.update(FAST)
    return ClusterService(**kw)


# Module-level work functions: the same object on every submit, so their
# cloudpickle digests match and resubmits hit the nodes' code caches.
def _double(x):
    return x * 2


def _triple(x):
    return x * 3


# ---------------------------------------------------------------------------
# one pool, many jobs
# ---------------------------------------------------------------------------


def test_back_to_back_jobs_one_pool():
    """Sequential submits reuse the booted pool: only the first submission
    is charged boot time, and both produce exact results."""
    with _service() as svc:
        h1 = svc.submit(_spec(_double, 30), timeout=60)
        assert h1.result() == [2 * i for i in range(30)]
        h2 = svc.submit(_spec(_triple, 30), timeout=60)
        assert h2.result() == [3 * i for i in range(30)]
        assert h1.cluster_boot_ms > 0.0
        assert h2.cluster_boot_ms == 0.0
    assert svc.orphaned() == []


def test_concurrent_jobs_interleave_exactly_once():
    """Two jobs submitted together share the node pool; each collects its
    own items exactly once (no cross-job leakage, no loss, no dupes)."""
    with _service() as svc:
        h1 = svc.submit(_spec(_double, 40), timeout=60)
        h2 = svc.submit(_spec(_triple, 40), timeout=60)
        r1, r2 = h1.result(), h2.result()
        assert r1 == [2 * i for i in range(40)]
        assert r2 == [3 * i for i in range(40)]
        assert h1.stats()["items_collected"] == 40
        assert h2.stats()["items_collected"] == 40
    assert svc.orphaned() == []


def test_node_death_mid_run_both_jobs_complete():
    """A node dying with in-flight items of *both* jobs: the host reaps it,
    requeues per job, and the surviving node finishes both exactly-once."""

    def slow_double(x):
        time.sleep(0.005)
        return x * 2

    def slow_triple(x):
        time.sleep(0.005)
        return x * 3

    n = 60
    with _service() as svc:
        h1 = svc.submit(_spec(slow_double, n), timeout=120)
        h2 = svc.submit(_spec(slow_triple, n), timeout=120)
        hl = svc.host_loader
        deadline = time.monotonic() + 30
        while hl.stats.items_total < 10:  # both jobs under way
            assert time.monotonic() < deadline
            time.sleep(0.005)
        svc.kill_node("node1")
        assert h1.result() == [2 * i for i in range(n)]
        assert h2.result() == [3 * i for i in range(n)]
        assert hl.stats.deaths_detected == 1
        assert hl.stats.redispatched > 0
    assert svc.orphaned() == []


def test_priority_preempts_fifo():
    """A high-priority job submitted behind a long low-priority one is
    answered first at every demand: it finishes while the long job is
    still running."""

    def slow(x):
        time.sleep(0.01)
        return x

    with _service(nodes=1, workers=1) as svc:
        h_low = svc.submit(_spec(slow, 100, nclusters=1, workers=1),
                           priority=0, timeout=120)
        h_high = svc.submit(_spec(_double, 5, nclusters=1, workers=1),
                            priority=5, timeout=120)
        assert h_high.result() == [2 * i for i in range(5)]
        assert not h_low.done()  # the long job is still going
        assert h_low.result() == list(range(100))
    assert svc.orphaned() == []


# ---------------------------------------------------------------------------
# warm resubmission
# ---------------------------------------------------------------------------


def test_warm_resubmit_skips_boot_and_code():
    """Resubmitting a pipeline whose stage function the nodes already hold:
    no boot, no code shipped — the nodes rebind from their digest cache."""
    with _service() as svc:
        h1 = svc.submit(_spec(_double, 20), timeout=60)
        h1.result()
        s1 = h1.stats()
        assert s1["code_shipped"] > 0 and s1["code_cached"] == 0

        h2 = svc.submit(_spec(_double, 20), timeout=60)
        assert h2.result() == h1.result()
        s2 = h2.stats()
        assert s2["cluster_boot_ms"] == 0.0
        assert s2["code_shipped"] == 0  # every node served it from cache
        assert s2["code_cached"] == s1["code_shipped"]
        assert h2.submit_to_first_result_ms is not None


def test_failed_job_does_not_poison_the_pool():
    """A work-function error fails *that* job only; the pool stays warm and
    the next submission runs normally."""

    def cursed(x):
        if x == 7:
            raise ValueError("item 7 is cursed")
        return x

    with _service() as svc:
        h_bad = svc.submit(_spec(cursed, 20), timeout=60)
        with pytest.raises(Exception, match="item 7 is cursed"):
            h_bad.result()
        h_ok = svc.submit(_spec(_double, 20), timeout=60)
        assert h_ok.result() == [2 * i for i in range(20)]
    assert svc.orphaned() == []


def test_submit_after_close_rejected():
    svc = _service()
    svc.start()
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(_spec(_double, 5))


# ---------------------------------------------------------------------------
# builder integration (backend="service")
# ---------------------------------------------------------------------------


def test_builder_service_backend_ephemeral_pool():
    """backend="service" with no service= boots an ephemeral pool sized
    from the spec and tears it down after — the one-shot contract."""
    app = ClusterBuilder().build_application(
        _spec(_double, 25), backend="service",
        launcher=InProcessLauncher(), **FAST,
    )
    assert app.run() == [2 * i for i in range(25)]
    assert app.orphaned() == []


def test_builder_service_backend_shared_warm_pool():
    """Two applications over one caller-owned service: the second build of
    the same spec is a warm resubmit (no boot, no code shipped)."""
    with _service() as svc:
        b = ClusterBuilder()
        app1 = b.build_application(_spec(_triple, 15), backend="service",
                                   service=svc)
        app2 = b.build_application(_spec(_triple, 15), backend="service",
                                   service=svc)
        assert app1.run() == [3 * i for i in range(15)]
        assert app2.run() == app1.result
        assert app2.handle.cluster_boot_ms == 0.0
        assert app2.handle.stats()["code_shipped"] == 0
        # the shared pool survives its applications
        assert svc.run(_spec(_double, 5)) == [0, 2, 4, 6, 8]
    assert svc.orphaned() == []


# ---------------------------------------------------------------------------
# elasticity (grow / graceful shrink)
# ---------------------------------------------------------------------------


def _wait_pool(svc, n, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if len(svc.pool_alive()) == n:
            return
        time.sleep(0.05)
    raise AssertionError(f"pool never reached {n}: {svc.pool_alive()}")


def test_grow_adds_nodes_via_late_join():
    """grow() launches fresh node-loaders into the running pool: they
    register mid-run, receive the pool config, and serve work."""
    with _service(nodes=1) as svc:
        assert svc.run(_spec(_double, 10)) == [2 * i for i in range(10)]
        new_ids = svc.grow(1)
        assert new_ids == ["node1"]
        _wait_pool(svc, 2)
        h = svc.submit(_spec(_double, 40), timeout=60)
        assert h.result() == [2 * i for i in range(40)]
        # Both the original and the late-joined node did work eventually
        # (the pool is 2-wide; at minimum the grown node is a live member).
        assert svc.pool_alive() == ["node0", "node1"]
        assert svc.telemetry.snapshot()["cluster"]["scale_up_events"] == 1
    assert svc.orphaned() == []


def test_shrink_retires_node_gracefully():
    """shrink() fences the victim and UTs it: the pool contracts without a
    death event, and jobs keep producing exact results before and after."""
    with _service(nodes=2) as svc:
        assert svc.run(_spec(_double, 10)) == [2 * i for i in range(10)]
        retired = svc.shrink()
        assert retired == "node1"
        _wait_pool(svc, 1)
        assert svc.run(_spec(_double, 20)) == [2 * i for i in range(20)]
        snap = svc.telemetry.snapshot()["cluster"]
        assert snap["scale_down_events"] == 1
        assert svc.host_loader.membership.failures == []  # no death, a retire
        # The last live node is never retirable.
        assert svc.shrink() is None
    assert svc.orphaned() == []


def test_grow_then_shrink_round_trip():
    with _service(nodes=1) as svc:
        svc.start()
        svc.grow(1)
        _wait_pool(svc, 2)
        assert svc.shrink() == "node1"
        _wait_pool(svc, 1)
        assert svc.run(_spec(_triple, 12)) == [3 * i for i in range(12)]
    assert svc.orphaned() == []


def test_grow_launch_failure_retracts_announcement():
    """A launch that raises must not leave a phantom LAUNCHING record:
    the announcement is retracted, pool_span() stops counting it as
    capacity on its way (a phantom would suppress autoscale scale-ups
    forever), and no scale_up event is recorded."""
    with _service(nodes=1) as svc:
        assert svc.run(_spec(_double, 6)) == [2 * i for i in range(6)]

        def boom(node_id, **kw):
            raise RuntimeError("launcher out of capacity")

        svc.launcher.launch = boom
        with pytest.raises(RuntimeError, match="out of capacity"):
            svc.grow(1)
        # Wait for the dispatcher to process both the announcement and
        # its retraction (pool_span alone could read (1, 0) before the
        # expect event was even applied).
        deadline = time.monotonic() + 10
        while True:
            rec = svc.host_loader.membership.nodes.get("node1")
            if rec is not None and rec.state == "dead":
                break
            assert time.monotonic() < deadline, "retraction never applied"
            time.sleep(0.02)
        assert svc.pool_span() == (1, 0)
        snap = svc.telemetry.snapshot()["cluster"]
        assert snap.get("scale_up_events", 0) == 0
    assert svc.orphaned() == []


# ---------------------------------------------------------------------------
# per-stage data-plane knobs on the shared pool
# ---------------------------------------------------------------------------


def test_pool_job_honours_stage_prefetch_cap():
    """A service-pool job's per-stage prefetch= bounds how many of its
    items one node may hold: with prefetch=0 no WORK_BATCH can exceed the
    pool's worker count, where an uncapped job batches the full credit
    window."""
    from repro.core.dsl import PipelineSpec, Stage

    def capped_spec(n):
        return PipelineSpec.simple(
            host="127.0.0.1", emit_details=_range_emit(n),
            stages=[Stage(name="double", fn=_double, nclusters=1,
                          workers_per_node=2, prefetch=0, flush_ms=1.0)],
            result_details=_list_collect(),
        )

    with _service(nodes=1, workers=2) as svc:
        h = svc.submit(capped_spec(40), timeout=60)
        assert h.result() == [2 * i for i in range(40)]
        assert svc.host_loader.stats.max_batch <= 2  # pool_workers + 0
    assert svc.orphaned() == []
