"""DSL parsing, spec validation, builder wiring and the local runtime."""

import pytest

from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec, Pipeline, PipelineSpec, parse_cgpp
from repro.core.processes import EmitDetails, ResultDetails


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _sum_collect():
    return ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)


def test_simple_spec_runs_to_completion():
    spec = ClusterSpec.simple(
        host="10.0.0.1", nclusters=2, workers_per_node=3,
        emit_details=_range_emit(50),
        work_function=lambda x: x * x,
        result_details=_sum_collect(),
    )
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum(i * i for i in range(50))


def test_demand_driven_distribution_is_load_balanced():
    """The onrl/nrfa protocol hands work to whichever node is idle; with
    uniform work every node must process a nontrivial share."""
    spec = ClusterSpec.simple(
        host="10.0.0.1", nclusters=3, workers_per_node=2,
        emit_details=_range_emit(300),
        work_function=lambda x: x + 1,
        result_details=_sum_collect(),
    )
    builder = ClusterBuilder()
    app = builder.build_application(spec)
    app.run()
    items = {t.node_id: t.items for t in builder.timing.nodes
             if t.node_id.startswith("node")}
    assert sum(items.values()) == 300
    assert all(v > 0 for v in items.values()), items


def test_cgpp_parser_roundtrip():
    text = """
cores = 2
clusters = 3
//@emit 192.168.1.176
details = DataDetails(name='r', init=lambda n: (0, n), init_data=(10,),
                      create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0]+1, s[1])))
emit = Emit(e_details=details)
onrl = OneNodeRequestedList()
//@cluster clusters
nrfa = NodeRequestingFanAny(destinations=cores)
group = AnyGroupAny(workers=cores, function=lambda x: 2 * x)
afoc = AnyFanOne(sources=cores)
//@collect
rd = ResultDetails(name='sum', init=lambda: 0, collect=lambda a, x: a + x)
afo = AnyFanOne(sources=clusters)
collector = Collect(r_details=rd)
"""
    spec = parse_cgpp(text)
    assert spec.host == "192.168.1.176"
    assert spec.nclusters == 3
    assert spec.workers_per_node == 2
    assert spec.constants["cores"] == 2
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum(2 * i for i in range(10))


def test_cgpp_parser_rejects_malformed():
    with pytest.raises(SyntaxError):
        parse_cgpp("x = 1\n//@cluster 2\n//@emit 1.2.3.4\n//@collect\n")
    with pytest.raises(SyntaxError):
        parse_cgpp("x = 1\n")


def test_cgpp_malformed_annotations_name_the_offending_line():
    # //@emit without a host-ip (line 2)
    with pytest.raises(SyntaxError, match=r"line 2: malformed annotation.*//@emit"):
        parse_cgpp("x = 1\n//@emit\n//@cluster 2\n//@collect\n")
    # //@cluster without a count (line 3)
    with pytest.raises(SyntaxError, match=r"line 3: malformed annotation.*//@cluster"):
        parse_cgpp("x = 1\n//@emit 1.2.3.4\n//@cluster\n//@collect\n")
    # unknown annotation form
    with pytest.raises(SyntaxError, match=r"line 1: malformed annotation.*//@emitter"):
        parse_cgpp("//@emitter 1.2.3.4\n//@cluster 2\n//@collect\n")


def test_cgpp_out_of_order_annotations_name_the_offending_line():
    # //@cluster before //@emit: the parser points at the cluster line
    with pytest.raises(SyntaxError, match=r"line 2: .*//@cluster.*must follow"):
        parse_cgpp("x = 1\n//@cluster 2\n//@emit 1.2.3.4\n//@collect\n")
    # //@collect before //@cluster
    with pytest.raises(SyntaxError, match=r"line 3: .*//@collect.*must follow"):
        parse_cgpp("x = 1\n//@emit 1.2.3.4\n//@collect\n//@cluster 2\n")


def test_cgpp_duplicate_sections_name_the_offending_line():
    with pytest.raises(SyntaxError, match=r"line 3: .*duplicate //@emit"):
        parse_cgpp("//@emit 1.2.3.4\nx = 1\n//@emit 5.6.7.8\n//@cluster 2\n//@collect\n")
    with pytest.raises(SyntaxError, match=r"line 4: .*duplicate //@cluster"):
        parse_cgpp("//@emit 1.2.3.4\nx = 1\n//@cluster 2\n//@cluster 3\n//@collect\n")
    with pytest.raises(SyntaxError, match=r"line 5: .*duplicate //@collect"):
        parse_cgpp("//@emit 1.2.3.4\n//@cluster 2\nx = 1\n//@collect\n//@collect\n")


def test_cgpp_missing_collect_section():
    with pytest.raises(SyntaxError, match="missing //@collect"):
        parse_cgpp("//@emit 1.2.3.4\n//@cluster 2\nx = 1\n")
    with pytest.raises(SyntaxError, match="missing //@emit"):
        parse_cgpp("x = 1\ny = 2\n")


# ---------------------------------------------------------------------------
# the //@stage grammar (PipelineSpec front end)
# ---------------------------------------------------------------------------

_EMIT_SECTION = """
//@emit 10.0.0.1
d = DataDetails(name='r', init=lambda n: (0, n), init_data=(12,),
                create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0]+1, s[1])))
emit = Emit(e_details=d)
"""

_COLLECT_SECTION = """
//@collect
rd = ResultDetails(name='sum', init=lambda: 0, collect=lambda a, x: a + x)
collector = Collect(r_details=rd)
"""


def test_stage_grammar_parses_and_runs_a_pipeline():
    text = (
        "clusters = 2\n" + _EMIT_SECTION
        + "//@stage square clusters\n"
        + "group = AnyGroupAny(workers=2, function=lambda x: x * x)\n"
        + "//@stage inc 1\n"
        + "group = AnyGroupAny(workers=1, function=lambda x: x + 1)\n"
        + _COLLECT_SECTION
    )
    spec = parse_cgpp(text)
    assert isinstance(spec, PipelineSpec)
    assert [(s.name, s.nclusters, s.workers_per_node) for s in spec.stages] \
        == [("square", 2, 2), ("inc", 1, 1)]
    assert spec.host == "10.0.0.1"
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum(i * i + 1 for i in range(12))


def test_legacy_cluster_section_equals_one_stage_pipeline():
    """//@cluster N is exactly a single anonymous stage: the parsed
    ClusterSpec's pipeline view matches the //@stage parse structurally and
    produces the same result."""
    work = "lambda x: 3 * x"
    legacy = parse_cgpp(
        "cores = 2\n" + _EMIT_SECTION
        + "onrl = OneNodeRequestedList()\n"
        + "//@cluster 2\n"
        + "nrfa = NodeRequestingFanAny(destinations=cores)\n"
        + f"group = AnyGroupAny(workers=cores, function={work})\n"
        + "afoc = AnyFanOne(sources=cores)\n"
        + _COLLECT_SECTION
        + "afo = AnyFanOne(sources=2)\n"
    )
    staged = parse_cgpp(
        "cores = 2\n" + _EMIT_SECTION
        + "//@stage cluster 2\n"
        + f"group = AnyGroupAny(workers=cores, function={work})\n"
        + _COLLECT_SECTION
    )
    assert isinstance(legacy, ClusterSpec) and isinstance(staged, PipelineSpec)
    lp = legacy.as_pipeline()
    assert lp.nstages == staged.nstages == 1
    assert lp.stages[0].name == staged.stages[0].name == "cluster"
    assert lp.stages[0].nclusters == staged.stages[0].nclusters
    assert (lp.stages[0].workers_per_node
            == staged.stages[0].workers_per_node)
    r1 = ClusterBuilder().build_application(legacy).run()
    r2 = ClusterBuilder().build_application(staged).run()
    assert r1 == r2 == sum(3 * i for i in range(12))


def test_stage_annotation_error_paths_name_the_offending_line():
    # //@stage without a node count -> malformed annotation
    with pytest.raises(SyntaxError,
                       match=r"malformed annotation.*//@stage square"):
        parse_cgpp("//@emit 1.2.3.4\n//@stage square\n//@collect\n")
    # duplicate stage names
    with pytest.raises(SyntaxError, match=r"line 3: .*duplicate //@stage 'a'"):
        parse_cgpp("//@emit 1.2.3.4\n//@stage a 1\n//@stage a 2\n//@collect\n")
    # //@stage before //@emit
    with pytest.raises(SyntaxError, match=r"line 1: .*must follow the emit"):
        parse_cgpp("//@stage a 1\n//@emit 1.2.3.4\n//@collect\n")
    # //@stage after //@collect
    with pytest.raises(SyntaxError, match=r"line 4: .*must precede"):
        parse_cgpp("//@emit 1.2.3.4\n//@stage a 1\n//@collect\n//@stage b 1\n")
    # mixing the grammars, either order
    with pytest.raises(SyntaxError, match=r"line 3: .*cannot mix"):
        parse_cgpp("//@emit 1.2.3.4\n//@cluster 2\n//@stage a 1\n//@collect\n")
    with pytest.raises(SyntaxError, match=r"line 3: .*cannot mix"):
        parse_cgpp("//@emit 1.2.3.4\n//@stage a 1\n//@cluster 2\n//@collect\n")
    # an unevaluable node count names its stage line
    with pytest.raises(SyntaxError, match=r"line 3: //@stage a: cannot"):
        parse_cgpp(
            "//@emit 1.2.3.4\n"
            "emit = Emit(e_details=DataDetails(name='e', create=lambda s: (None, s)))\n"
            "//@stage a nope\n//@collect\n"
        )


def test_stage_sections_must_define_their_records():
    base = "//@emit 1.2.3.4\nemit = Emit(e_details=DataDetails(name='e', create=lambda s: (None, s)))\n"
    tail = "//@collect\ncollector = Collect(r_details=ResultDetails(name='c', collect=lambda a, x: a))\n"
    with pytest.raises(SyntaxError, match=r"stage 'a' must define exactly one AnyGroupAny"):
        parse_cgpp(base + "//@stage a 1\nx = 1\n" + tail)
    with pytest.raises(SyntaxError, match=r"collect section must define exactly one Collect"):
        parse_cgpp(
            base + "//@stage a 1\ngroup = AnyGroupAny(workers=1, function=lambda x: x)\n"
            + "//@collect\nx = 1\n"
        )


def test_stage_sections_accept_prebuilt_namespace_records():
    """A record supplied via namespace= belongs to the section that binds
    it — not to whichever section executed first (regression)."""
    from repro.core.processes import AnyGroupAny, Collect, Emit

    emit_rec = Emit(e_details=EmitDetails(
        name="r", init=lambda n: (0, n), init_data=(6,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    ))
    group_rec = AnyGroupAny(workers=1, function=lambda x: x * 10)
    coll_rec = Collect(r_details=ResultDetails(
        name="sum", init=lambda: 0, collect=lambda a, x: a + x))
    spec = parse_cgpp(
        "//@emit 1.2.3.4\n"
        "emit = EMIT_REC\n"
        "//@stage tens 1\n"
        "group = GROUP_REC\n"
        "//@collect\n"
        "collector = COLL_REC\n",
        namespace={"EMIT_REC": emit_rec, "GROUP_REC": group_rec,
                   "COLL_REC": coll_rec},
    )
    assert spec.stages[0].node_net.group is group_rec
    assert spec.emit is emit_rec and spec.collector is coll_rec
    assert ClusterBuilder().build_application(spec).run() \
        == sum(10 * i for i in range(6))


def test_pipeline_roundtrips_between_fluent_api_and_cgpp():
    """The fluent API and the //@stage grammar are two spellings of the same
    PipelineSpec: identical structure when fed identical callables."""
    square = lambda x: x * x  # noqa: E731
    inc = lambda x: x + 1  # noqa: E731
    emit = EmitDetails(
        name="r", init=lambda n: (0, n), init_data=(9,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )
    coll = ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)

    fluent = (Pipeline(host="10.0.0.1")
              .emit(emit)
              .stage(square, nodes=2, workers=2, name="square")
              .stage(inc, nodes=1, workers=1, name="inc")
              .collect(coll)
              .build())
    parsed = parse_cgpp(
        "//@emit 10.0.0.1\n"
        "emit = Emit(e_details=EMIT)\n"
        "//@stage square 2\n"
        "group = AnyGroupAny(workers=2, function=SQUARE)\n"
        "//@stage inc 1\n"
        "group = AnyGroupAny(workers=1, function=INC)\n"
        "//@collect\n"
        "collector = Collect(r_details=COLL)\n",
        namespace={"EMIT": emit, "SQUARE": square, "INC": inc, "COLL": coll},
    )
    assert fluent.host == parsed.host
    assert [(s.name, s.nclusters, s.workers_per_node) for s in fluent.stages] \
        == [(s.name, s.nclusters, s.workers_per_node) for s in parsed.stages]
    assert [s.function for s in fluent.stages] \
        == [s.function for s in parsed.stages]
    assert fluent.emit.e_details is emit and parsed.emit.e_details is emit
    assert fluent.collector.r_details is coll
    assert parsed.collector.r_details is coll
    # identical results, too
    expected = sum(i * i + 1 for i in range(9))
    assert ClusterBuilder().build_application(fluent).run() == expected
    assert ClusterBuilder().build_application(parsed).run() == expected


def test_spec_validation_catches_mismatched_fanin():
    spec = ClusterSpec.simple(
        host="h", nclusters=2, workers_per_node=2,
        emit_details=_range_emit(5), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    spec.host_net.afo.sources = 3  # corrupt
    with pytest.raises(ValueError, match="AnyFanOne"):
        spec.validate()


def test_deployment_plan_structure():
    spec = ClusterSpec.simple(
        host="192.168.1.176", nclusters=4, workers_per_node=6,
        emit_details=_range_emit(5), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    plan = ClusterBuilder().deployment_plan(spec)
    assert plan.host_load_address == "192.168.1.176:2000/1"
    assert len(plan.nodes) == 4
    order = plan.load_order()
    # input ends before output ends; loading before the app network
    assert any("input channel" in s for s in order[:1])
    assert "timing" in order[-1] or "load_ms" in order[-1]


def test_load_time_fraction_small():
    """Paper section 8.2: load < 1% of runtime for real workloads; with a
    compute-heavy work function ours should be well under 20% even at toy
    scale."""
    import numpy as np

    def work(x):
        return float(np.sum(np.arange(20000) * (x + 1) % 7))

    spec = ClusterSpec.simple(
        host="h", nclusters=2, workers_per_node=2,
        emit_details=_range_emit(120), work_function=work,
        result_details=_sum_collect(),
    )
    builder = ClusterBuilder()
    app = builder.build_application(spec)
    app.run()
    assert builder.timing.load_fraction() < 0.5
