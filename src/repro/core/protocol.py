"""CSP process model of the ClusterBuilder application network (Listing 3).

This is a direct transliteration of the paper's CSPm specification into a
labelled-transition-system (LTS) form that ``core.verify`` can exhaustively
check, generalised two ways beyond the paper: from ``W = 1`` worker per node
to ``W >= 1`` (the deployed network of Figure 2 has ``cores`` workers behind
every ``nrfa``), and from one cluster stage to an ordered *pipeline* of
stages (``PipelineSpec``) — each stage's reducer feeds the next stage's
server exactly as Emit feeds the first, so every hop repeats the same
client-server pattern.

Processes and channels (paper Figure 3, channels now stage-indexed):

    Emit --a.0--> Server_0 --c.0.i--> Client_0i --d.0.i--> Worker_0iw
                     ^-----b.0.i---------|
    Worker_0iw --e.0.i--> Reducer_0 --a.1--> Server_1 --...--> Reducer_{S-1}
    Reducer_{S-1} --f--> Collect --finished--> env

All channels are synchronous, unbuffered and unidirectional (CSP semantics:
a communication happens only when writer and reader are simultaneously
ready).  The hidden channels are everything except ``finished`` when
checking refinement against ``TestSystem = finished -> TestSystem`` —
exactly the setup of Listing 3 lines 50-58, with ``a..f`` now the union over
stages.

NOTE — paper erratum: Listing 3 line 28 reads ``Server_End(y) = b?y.S ->
c!y.UT -> if y == N then SKIP else Server_End(y+1)``.  Taken literally, with
clients indexed ``0..N-1`` the recursion reaches ``Server_End(N)`` and blocks
on the non-existent channel ``b.N`` — a deadlock FDR would flag.  We
implement the evidently-intended ``if y == N-1 then SKIP`` and the verifier
(tests) demonstrates that the literal version deadlocks while the corrected
one passes all assertions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Iterable

# The Universal Terminator object (paper's ``UT``).
UT = "UT"

# Process-state sentinel equivalent to CSP SKIP (successful termination).
SKIP = ("SKIP",)

Event = tuple  # (channel_key, value)
State = Hashable


@dataclass(frozen=True)
class Output:
    chan: Hashable
    value: Any
    next_state: State


@dataclass(frozen=True)
class Input:
    chan: Hashable
    # accept(value) -> next_state, or None to refuse the value.
    accept: Callable[[Any], State | None]


class Process:
    """A process = initial state + ready-output/ready-input functions."""

    name: str = "proc"

    def initial(self) -> State:
        raise NotImplementedError

    def outputs(self, state: State) -> list[Output]:
        return []

    def inputs(self, state: State) -> list[Input]:
        return []

    def is_terminated(self, state: State) -> bool:
        return state == SKIP


# ---------------------------------------------------------------------------
# The six process kinds of Listing 3.
# ---------------------------------------------------------------------------


class EmitProc(Process):
    """Emit(o) = a!o -> if o == UT then SKIP else Emit(create(o))  {3:22}."""

    def __init__(self, num_objects: int):
        self.name = "emit"
        self.num_objects = num_objects

    def initial(self) -> State:
        return ("emit", 0)

    def outputs(self, state: State) -> list[Output]:
        if state == SKIP:
            return []
        _, k = state
        if k < self.num_objects:
            return [Output(("a", 0), k, ("emit", k + 1))]
        return [Output(("a", 0), UT, SKIP)]


class ServerProc(Process):
    """The ``onrl`` server {3:24-29} (with the line-28 erratum corrected).

    ``stage`` indexes which pipeline hop this server distributes for: it
    reads ``a.stage`` (the emit stream for stage 0, the previous stage's
    reducer output otherwise) and serves its own clients on
    ``b.stage.i``/``c.stage.i``.  ``literal_paper_model=True`` reproduces
    Listing 3 exactly (including the off-by-one) so the verifier can exhibit
    the deadlock.
    """

    def __init__(self, nclusters: int, stage: int = 0,
                 literal_paper_model: bool = False,
                 peer_input: bool = False):
        self.name = f"server{stage}"
        self.n = nclusters
        self.s = stage
        self.literal = literal_paper_model
        # A peer-routed hop renames the input stream ("a", s) -> ("p", s):
        # the *location* of the channel moved off the host, its protocol
        # (a well-behaved emit stream ending in one UT) did not — which is
        # exactly why the Listing-3 assertions transfer unchanged.
        self.in_chan: Hashable = ("p", stage) if peer_input else ("a", stage)

    def initial(self) -> State:
        return ("idle",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("idle",):
            # Server() = a?o -> ...
            def accept(o: Any) -> State:
                return ("end", 0) if o == UT else ("have", o)

            return [Input(self.in_chan, accept)]
        if state[0] == "have":
            # Server_Choice(o) = [] x : {0..N-1} @ Service(x, o); Service
            # begins b?i.S.
            o = state[1]
            return [
                Input(("b", self.s, i), lambda _s, i=i, o=o: ("serve", i, o))
                for i in range(self.n)
            ]
        if state[0] == "end":
            # Server_End(y) = b?y.S -> c!y.UT -> ...
            y = state[1]
            if y < self.n:
                return [Input(("b", self.s, y),
                              lambda _s, y=y: ("end_serve", y))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "serve":
            _, i, o = state
            return [Output(("c", self.s, i), o, ("idle",))]
        if state and state[0] == "end_serve":
            y = state[1]
            if self.literal:
                # Literal Listing 3: `if y == N then SKIP else Server_End(y+1)`
                nxt = SKIP if y == self.n else ("end", y + 1)
            else:
                nxt = SKIP if y == self.n - 1 else ("end", y + 1)
            return [Output(("c", self.s, y), UT, nxt)]
        return []


class ClientProc(Process):
    """The ``nrfa`` client of node ``i`` {3:30-31}, generalised to W workers.

    Client(i) = b!i.S -> c?i.o -> if o == UT then (d!i.UT * W -> SKIP)
                                  else (d!i.o -> Client(i))

    The one-place-buffer invariant is structural: the client re-enters the
    requesting state only *after* the d.i communication completes, so the
    server can never be blocked by a node with an idle worker (paper §5).
    """

    def __init__(self, i: int, workers: int, stage: int = 0):
        self.name = f"client{stage}.{i}"
        self.i = i
        self.s = stage
        self.workers = workers

    def initial(self) -> State:
        return ("req",)

    def outputs(self, state: State) -> list[Output]:
        if state == ("req",):
            return [Output(("b", self.s, self.i), "S", ("wait",))]
        if state and state[0] == "deliver":
            o = state[1]
            if o == UT:
                # First of W terminators — one per worker behind this client.
                nxt = SKIP if self.workers == 1 else ("term", 1)
                return [Output(("d", self.s, self.i), UT, nxt)]
            return [Output(("d", self.s, self.i), o, ("req",))]
        if state and state[0] == "term":
            w = state[1]
            nxt = SKIP if w + 1 == self.workers else ("term", w + 1)
            return [Output(("d", self.s, self.i), UT, nxt)]
        return []

    def inputs(self, state: State) -> list[Input]:
        if state == ("wait",):
            return [Input(("c", self.s, self.i), lambda o: ("deliver", o))]
        return []


class WorkerProc(Process):
    """Worker {3:35-36}: d?i.o -> (e!i.o ->) with UT termination."""

    def __init__(self, i: int, w: int, stage: int = 0):
        self.name = f"worker{stage}.{i}.{w}"
        self.i = i
        self.s = stage

    def initial(self) -> State:
        return ("work",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("work",):
            return [Input(("d", self.s, self.i), lambda o: ("fwd", o))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "fwd":
            o = state[1]
            nxt = SKIP if o == UT else ("work",)
            return [Output(("e", self.s, self.i), o, nxt)]
        return []


class ReducerProc(Process):
    """Reducer {3:39-45}, generalised: forwards non-UT objects from any e.i,
    counts ``N*W`` UTs (one per worker), then emits a single terminal UT.

    The final stage's reducer writes ``f`` (into Collect, as in the paper);
    an intermediate stage's reducer writes ``a.(s+1)`` — it *is* the next
    stage's Emit, which is the whole compositional argument: each hop sees
    upstream only as a well-behaved emit stream.
    """

    def __init__(self, nclusters: int, workers: int, stage: int = 0,
                 last: bool = True, peer_output: bool = False):
        self.name = f"reducer{stage}"
        self.n = nclusters
        self.s = stage
        if last:
            self.out_chan: Hashable = ("f",)
        elif peer_output:
            self.out_chan = ("p", stage + 1)
        else:
            self.out_chan = ("a", stage + 1)
        self.remaining = nclusters * workers

    def initial(self) -> State:
        return ("read", self.remaining)

    def inputs(self, state: State) -> list[Input]:
        if state and state[0] == "read":
            k = state[1]

            def accept(o: Any, k: int = k) -> State:
                if o == UT:
                    return ("fwd_ut",) if k == 1 else ("read", k - 1)
                return ("fwd", o, k)

            return [Input(("e", self.s, i), accept) for i in range(self.n)]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state and state[0] == "fwd":
            _, o, k = state
            return [Output(self.out_chan, o, ("read", k))]
        if state == ("fwd_ut",):
            return [Output(self.out_chan, UT, SKIP)]
        return []


class CollectProc(Process):
    """Collect {3:46-48}: reads f until UT, then loops on finished!True."""

    def __init__(self) -> None:
        self.name = "collect"

    def initial(self) -> State:
        return ("run",)

    def inputs(self, state: State) -> list[Input]:
        if state == ("run",):
            return [Input(("f",), lambda o: ("done",) if o == UT else ("run",))]
        return []

    def outputs(self, state: State) -> list[Output]:
        if state == ("done",):
            return [Output(("finished",), True, ("done",))]
        return []

    def is_terminated(self, state: State) -> bool:
        return state == ("done",)


# ---------------------------------------------------------------------------
# Network assembly.
# ---------------------------------------------------------------------------


def normalize_routes(routes: "dict | Iterable[int] | None",
                     nstages: int) -> frozenset:
    """Validate peer-route declarations; return the set of source stages.

    Accepts a set/list of source stage indices (each meaning "the hop
    ``s -> s+1`` is peer-routed") or a ``{src: dst}`` dict — the explicit
    form exists so an ill-formed topology can be *stated* and rejected:
    a route whose destination is not downstream of its source would let
    items re-enter a stage they already left, so the per-stage UT
    accounting (each reducer counts exactly ``N*W`` terminators) could
    wait forever on a cycle the emit stream never closes.  That is
    refused here, before any state-space work.
    """
    if not routes:
        return frozenset()
    if isinstance(routes, dict):
        pairs = [(int(s), int(d)) for s, d in routes.items()]
    else:
        pairs = [(int(s), int(s) + 1) for s in routes]
    srcs = set()
    for src, dst in pairs:
        if not 0 <= src < nstages - 1:
            raise ValueError(
                f"peer route source stage {src} out of range for "
                f"{nstages} stages (a route leaves stages 0..{nstages - 2})"
            )
        if dst <= src:
            raise ValueError(
                f"cyclic peer route: stage {src} -> stage {dst} sends data "
                "backwards (or to itself), so stage UT accounting would "
                "deadlock — peer routes must target the next stage"
            )
        if dst != src + 1:
            raise ValueError(
                f"unsupported peer route: stage {src} -> stage {dst} skips "
                f"stage {src + 1}; peer routes cover the adjacent hop only"
            )
        srcs.add(src)
    return frozenset(srcs)


@dataclass
class ProtocolNetwork:
    """The composed System of Listing 3 lines 50-51."""

    processes: list[Process]
    visible_channels: frozenset = frozenset({("finished",)})

    @staticmethod
    def build(
        nclusters: int,
        workers_per_node: int = 1,
        num_objects: int = 5,
        literal_paper_model: bool = False,
    ) -> "ProtocolNetwork":
        return ProtocolNetwork.build_pipeline(
            [(nclusters, workers_per_node)],
            num_objects,
            literal_paper_model=literal_paper_model,
        )

    @staticmethod
    def build_pipeline(
        stage_shapes: list[tuple[int, int]],
        num_objects: int = 5,
        literal_paper_model: bool = False,
        routes: "dict | Iterable[int] | None" = None,
    ) -> "ProtocolNetwork":
        """The chained System: one (server, clients, workers, reducer) group
        per ``(nclusters, workers_per_node)`` stage shape, reducer *s* wired
        to server *s+1*; a single-entry list is Listing 3 verbatim.

        ``routes`` marks peer-routed hops (see :func:`normalize_routes`):
        for each source stage ``s`` in it the hop channel ``("a", s+1)``
        is renamed ``("p", s+1)`` — the stream's endpoints moved from the
        host to the nodes, its protocol did not, so the composition is
        re-verified over the renamed channels with zero new process kinds.
        """
        if not stage_shapes:
            raise ValueError("pipeline needs at least one stage shape")
        peer_srcs = normalize_routes(routes, len(stage_shapes))
        procs: list[Process] = [EmitProc(num_objects)]
        last = len(stage_shapes) - 1
        for s, (n, w) in enumerate(stage_shapes):
            procs.append(
                ServerProc(n, stage=s, literal_paper_model=literal_paper_model,
                           peer_input=(s - 1) in peer_srcs)
            )
            for i in range(n):
                procs.append(ClientProc(i, w, stage=s))
            for i in range(n):
                for wi in range(w):
                    procs.append(WorkerProc(i, wi, stage=s))
            procs.append(ReducerProc(n, w, stage=s, last=(s == last),
                                     peer_output=s in peer_srcs))
        procs.append(CollectProc())
        return ProtocolNetwork(processes=procs)

    def initial(self) -> tuple:
        return tuple(p.initial() for p in self.processes)

    def successors(self, state: tuple) -> Iterable[tuple[Event, tuple]]:
        """All enabled synchronisations from a global state.

        A transition exists for every (writer, reader) pair that is ready on
        the same channel and whose reader accepts the offered value.
        """
        procs = self.processes
        # Gather ready outputs and inputs per channel.
        outs: dict[Hashable, list[tuple[int, Output]]] = {}
        ins: dict[Hashable, list[tuple[int, Input]]] = {}
        for pi, proc in enumerate(procs):
            for out in proc.outputs(state[pi]):
                outs.setdefault(out.chan, []).append((pi, out))
            for inp in proc.inputs(state[pi]):
                ins.setdefault(inp.chan, []).append((pi, inp))
        for chan, writers in outs.items():
            if chan in self.visible_channels:
                # Environment always willing to observe visible events.
                for pi, out in writers:
                    ns = list(state)
                    ns[pi] = out.next_state
                    yield (chan, out.value), tuple(ns)
                continue
            for pi, out in writers:
                for qi, inp in ins.get(chan, []):
                    if pi == qi:
                        continue
                    nxt = inp.accept(out.value)
                    if nxt is None:
                        continue
                    ns = list(state)
                    ns[pi] = out.next_state
                    ns[qi] = nxt
                    yield (chan, out.value), tuple(ns)

    def is_hidden(self, event: Event) -> bool:
        return event[0] not in self.visible_channels

    def all_terminated(self, state: tuple) -> bool:
        return all(p.is_terminated(s) for p, s in zip(self.processes, state))
