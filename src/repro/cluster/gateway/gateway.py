"""JobGateway: the durable, multi-tenant front door of a warm pool.

``ClusterService.submit`` is a live-process API: the JobHandle is the only
record a job exists, so the client must stay connected and the scheduling
is strict priority+FIFO.  The gateway puts three things in front of it
(see ARCHITECTURE.md "Job gateway & fair scheduling"):

* **durability** — ``enqueue()`` writes the spec to a SQLite task table
  (:mod:`.store`) and returns a ticket id; the client may disconnect, the
  gateway may restart over the same database, and ``attach(ticket)`` still
  resolves to the result (rows caught mid-run by a crash are requeued);
* **weighted-fair admission** — queued tickets enter the pool via
  deficit-round-robin over tenants with aging (:mod:`.scheduler`); submit
  priority only orders tickets *within* a tenant, and each tenant's
  ``max_inflight`` credit cap rides the submission into
  ``host_loader._answer`` so a wide job cannot monopolise node credits;
* **autoscaling** — pass ``autoscale=AutoscalePolicy(...)`` and a control
  loop (:mod:`.autoscale`) grows/shrinks the pool with queue depth.

The pump — one daemon thread — is the only writer of scheduler state: it
reaps finished pool jobs into the store, drops queued tickets whose
submit timeout expired (they report ``cancelled``, never holding a slot
forever), and admits the next DRR pick whenever an admission slot frees.
"""

from __future__ import annotations

import threading
import time
import traceback
import uuid
from typing import Any

from repro.cluster.gateway.autoscale import AutoscalePolicy, Autoscaler
from repro.cluster.gateway.scheduler import (
    FairScheduler,
    QueueEntry,
    TenantPolicy,
)
from repro.cluster.gateway.store import (
    CANCELLED,
    DONE,
    FAILED,
    QUEUED,
    RUNNING,
    TicketStore,
)

__all__ = ["JobGateway", "TicketHandle", "JobCancelled"]

TERMINAL_STATES = (DONE, FAILED, CANCELLED)


class JobCancelled(RuntimeError):
    """Raised by ``TicketHandle.result()`` for a cancelled ticket (explicit
    ``cancel()`` or a submit timeout that expired while still queued)."""


class _Active:
    """One admitted ticket: its live pool-job handle plus identity."""

    __slots__ = ("ticket", "tenant", "handle")

    def __init__(self, ticket: str, tenant: str, handle: Any):
        self.ticket = ticket
        self.tenant = tenant
        self.handle = handle


class TicketHandle:
    """A ticket's future, valid across gateway restarts.

    Unlike a ``JobHandle`` this is just a view over the task table (plus
    the live pool handle while the job runs), so any process that can open
    the gateway's database can wait on any ticket.
    """

    def __init__(self, gateway: "JobGateway", ticket: str):
        self._gateway = gateway
        self.ticket = ticket

    def status(self) -> str:
        """``queued`` | ``running`` | ``done`` | ``failed`` | ``cancelled``."""
        row = self._gateway._row(self.ticket)
        return row.state

    def done(self) -> bool:
        return self.status() in TERMINAL_STATES

    def wait(self, timeout: float | None = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            # The live handle's event is the fast path; the store poll
            # covers queued tickets and post-restart attachment.
            active = self._gateway._active_of(self.ticket)
            if active is not None:
                step = 0.25 if deadline is None else min(
                    0.25, max(0.0, deadline - time.monotonic()))
                active.handle.wait(step)
            if self.status() in TERMINAL_STATES:
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            if active is None:
                time.sleep(self._gateway.poll_interval)

    def result(self, timeout: float | None = None) -> Any:
        if not self.wait(timeout):
            raise TimeoutError(
                f"ticket {self.ticket} not finished within {timeout}s")
        row = self._gateway._row(self.ticket)
        if row.state == CANCELLED:
            raise JobCancelled(row.error or f"ticket {self.ticket} cancelled")
        if row.state == FAILED:
            raise RuntimeError(row.error or f"ticket {self.ticket} failed")
        return row.load_result()

    def stats(self) -> dict[str, Any]:
        """Ticket metadata merged with the job's figures: live from the
        pool handle while running, from the persisted summary after —
        ``cluster_boot_ms`` survives reattachment either way."""
        row = self._gateway._row(self.ticket)
        out: dict[str, Any] = {
            "ticket": row.ticket,
            "tenant": row.tenant,
            "state": row.state,
            "priority": row.priority,
            "submitted_at": row.submitted_at,
            "started_at": row.started_at,
            "finished_at": row.finished_at,
        }
        active = self._gateway._active_of(self.ticket)
        if active is not None:
            out.update(active.handle.stats())
        elif row.summary:
            out.update(row.summary)
        return out


class JobGateway:
    """The durable multi-tenant submit queue over one ``ClusterService``.

    ``tenants`` maps tenant name -> :class:`TenantPolicy` (weights, caps);
    unknown tenants get ``default_policy``.  ``mode="fifo"`` disables the
    DRR machinery (strict priority+FIFO admission, no credit caps) — the
    measured baseline, not a recommended configuration.

    ``max_active_jobs`` bounds concurrently admitted pool jobs overall —
    the admission slots DRR arbitrates.  The gateway never owns the
    service: ``close()`` stops metering but leaves the pool warm.
    """

    def __init__(
        self,
        service,
        db_path: str,
        *,
        tenants: dict[str, TenantPolicy] | None = None,
        default_policy: TenantPolicy | None = None,
        mode: str = "fair",
        max_active_jobs: int = 8,
        aging_s: float = 30.0,
        autoscale: AutoscalePolicy | None = None,
        poll_interval: float = 0.05,
    ):
        if max_active_jobs < 1:
            raise ValueError("max_active_jobs must be >= 1")
        self.service = service
        self.telemetry = service.telemetry
        self.mode = mode
        self.max_active_jobs = max_active_jobs
        self.poll_interval = poll_interval
        self.store = TicketStore(db_path)
        self.scheduler = FairScheduler(tenants, default=default_policy,
                                       mode=mode, aging_s=aging_s)
        self._lock = threading.Lock()
        self._active: dict[str, _Active] = {}
        self._wake = threading.Event()
        self._stop = threading.Event()
        # Crash recovery: rows left ``running`` by a dead gateway lost
        # their pool job with it — requeue them with the queued rows.
        for row in self.store.recover():
            self.scheduler.push(QueueEntry(
                ticket=row.ticket, tenant=row.tenant, priority=row.priority,
                submitted_at=row.submitted_at, timeout=row.timeout,
                retries=row.retries, spec=None,  # lazily unpickled on admit
            ))
        self.telemetry.set_sampler("gateway", self._sample)
        self._pump = threading.Thread(target=self._pump_loop,
                                      name="gateway-pump", daemon=True)
        self._pump.start()
        self.autoscaler: Autoscaler | None = None
        if autoscale is not None:
            self.autoscaler = Autoscaler(self, autoscale)
            self.autoscaler.start()

    # -- client API ----------------------------------------------------------

    def enqueue(self, spec, *, tenant: str = "default", priority: int = 0,
                retries: int = 0, timeout: float | None = None) -> str:
        """Persist one submission; returns its ticket id immediately.

        The ticket survives client disconnect and gateway restart;
        ``timeout`` is end-to-end from enqueue (a ticket still queued at
        its deadline is cancelled, one admitted gets the remainder as its
        job timeout).
        """
        if self._stop.is_set():
            raise RuntimeError("gateway is closed")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        ticket = f"t{uuid.uuid4().hex[:12]}"
        row = self.store.add(ticket, spec, tenant=tenant, priority=priority,
                             retries=retries, timeout=timeout)
        with self._lock:
            self.scheduler.push(QueueEntry(
                ticket=ticket, tenant=tenant, priority=priority,
                submitted_at=row.submitted_at, timeout=timeout,
                retries=retries, spec=spec,
            ))
        self.telemetry.inc("tickets_enqueued")
        self.telemetry.emit("ticket_enqueued", ticket=ticket, tenant=tenant,
                            priority=priority)
        self._wake.set()
        return ticket

    def attach(self, ticket: str) -> TicketHandle:
        """Reconnect to a ticket (this gateway's or any prior one's over
        the same database)."""
        self._row(ticket)  # raise early on unknown ids
        return TicketHandle(self, ticket)

    def cancel(self, ticket: str) -> bool:
        """Remove a still-queued ticket.  True when it was cancelled;
        False when it already started (or finished) — running work is
        never preempted here."""
        with self._lock:
            entry = self.scheduler.remove(ticket)
        if entry is None:
            return False
        self.store.cancel(ticket, "cancelled by client")
        self.telemetry.inc("tickets_cancelled")
        self.telemetry.emit("ticket_cancelled", ticket=ticket,
                            tenant=entry.tenant, reason="client")
        return True

    # -- introspection (autoscaler + telemetry) ------------------------------

    def queued_count(self) -> int:
        with self._lock:
            return self.scheduler.depth()

    def active_count(self) -> int:
        with self._lock:
            return len(self._active)

    def oldest_queued_wait(self) -> float:
        with self._lock:
            return self.scheduler.oldest_wait()

    def _row(self, ticket: str):
        row = self.store.get(ticket)
        if row is None:
            raise KeyError(f"unknown ticket {ticket!r}")
        return row

    def _active_of(self, ticket: str) -> _Active | None:
        with self._lock:
            return self._active.get(ticket)

    def _sample(self) -> dict:
        with self._lock:
            depth = self.scheduler.depth_by_tenant()
            active = list(self._active.values())
            oldest = self.scheduler.oldest_wait()
        by_tenant: dict[str, dict] = {}
        for t, n in depth.items():
            by_tenant.setdefault(t, {"queued": 0, "active": 0})["queued"] = n
        for a in active:
            by_tenant.setdefault(a.tenant,
                                 {"queued": 0, "active": 0})["active"] += 1
        for t, fields in by_tenant.items():
            pol = self.scheduler.policy(t)
            fields["weight"] = pol.weight
            if pol.max_inflight is not None:
                fields["max_inflight"] = pol.max_inflight
        return {
            "mode": self.mode,
            "queued": sum(depth.values()),
            "active": len(active),
            "oldest_wait_s": round(oldest, 6),
            "tickets": self.store.counts(),
            "tenants": by_tenant,
        }

    # -- the pump ------------------------------------------------------------

    def _pump_loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(self.poll_interval)
            self._wake.clear()
            try:
                self._reap()
                self._expire()
                self._admit()
            except Exception:
                if self._stop.is_set():
                    return  # store/service closed under the pump: done
                # A dead pump strands every tenant — nothing is ever
                # reaped, expired or admitted again and waiters block
                # forever — so surface the error on the bus and keep
                # pumping.
                self.telemetry.inc("gateway_pump_errors")
                self.telemetry.emit("gateway_pump_error",
                                    error=traceback.format_exc(limit=8))

    def _reap(self) -> None:
        with self._lock:
            # Claim atomically: close(wait=True) reaps on the caller
            # thread while the pump runs its own _reap, and each finished
            # ticket must be recorded (store row, counters, bus event)
            # exactly once — whoever pops the ticket processes it.
            finished = [self._active.pop(a.ticket)
                        for a in list(self._active.values())
                        if a.handle.done()]
        for a in finished:
            try:
                self._record_finished(a)
            except Exception as exc:
                # An unpicklable result (or a store hiccup) must not
                # strand the row as ``running`` or kill the pump: record
                # the ticket failed instead.
                self.store.finish(a.ticket,
                                  error=f"{type(exc).__name__}: {exc}")
                self.telemetry.inc("tickets_failed")
                self.telemetry.emit("ticket_failed", ticket=a.ticket,
                                    tenant=a.tenant, error=str(exc))

    def _record_finished(self, a: _Active) -> None:
        handle = a.handle
        stats = handle.stats()
        summary = {
            "items_collected": stats.get("items_collected"),
            "cluster_boot_ms": stats.get("cluster_boot_ms"),
            "submit_to_first_result_ms":
                stats.get("submit_to_first_result_ms"),
            "code_shipped": stats.get("code_shipped"),
            "retries": stats.get("retries"),
        }
        if handle.error is None:
            self.store.finish(a.ticket, result=handle._job.result,
                              summary=summary)
            self.telemetry.inc("tickets_done")
            self.telemetry.emit("ticket_done", ticket=a.ticket,
                                tenant=a.tenant,
                                items=stats.get("items_collected"))
        else:
            self.store.finish(a.ticket, error=str(handle.error),
                              summary=summary)
            self.telemetry.inc("tickets_failed")
            self.telemetry.emit("ticket_failed", ticket=a.ticket,
                                tenant=a.tenant,
                                error=str(handle.error))

    def _expire(self) -> None:
        with self._lock:
            expired = self.scheduler.drop_expired()
        for entry in expired:
            self.store.cancel(
                entry.ticket,
                f"timed out after {entry.timeout}s while still queued")
            self.telemetry.inc("tickets_cancelled")
            self.telemetry.emit("ticket_cancelled", ticket=entry.ticket,
                                tenant=entry.tenant, reason="queued_timeout")

    def _admit(self) -> None:
        while True:
            with self._lock:
                if len(self._active) >= self.max_active_jobs:
                    return
                counts: dict[str, int] = {}
                for a in self._active.values():
                    counts[a.tenant] = counts.get(a.tenant, 0) + 1
                entry = self.scheduler.pop_next(counts)
            if entry is None:
                return
            try:
                self._admit_one(entry)
            except Exception as exc:
                # The entry is already out of the scheduler, so one bad
                # ticket (unpicklable spec, spec validation refusing it,
                # a submit error) fails alone — the pump survives and
                # every other tenant keeps flowing.
                self.store.finish(entry.ticket,
                                  error=f"{type(exc).__name__}: {exc}")
                self.telemetry.inc("tickets_failed")
                self.telemetry.emit("ticket_failed", ticket=entry.ticket,
                                    tenant=entry.tenant, error=str(exc))

    def _admit_one(self, entry: QueueEntry) -> None:
        row = self._row(entry.ticket)
        spec = entry.spec if entry.spec is not None else row.load_spec()
        job_timeout = None
        if entry.timeout is not None:
            job_timeout = entry.deadline() - time.time()
            if job_timeout <= 0:
                self.store.cancel(
                    entry.ticket,
                    f"timed out after {entry.timeout}s while queued")
                self.telemetry.inc("tickets_cancelled")
                self.telemetry.emit("ticket_cancelled",
                                    ticket=entry.ticket,
                                    tenant=entry.tenant,
                                    reason="queued_timeout")
                return
        pol = self.scheduler.policy(entry.tenant)
        if self.mode == "fair":
            # Cross-tenant ordering is the DRR's job (already applied)
            # — inside the pool every tenant's jobs run at one
            # priority, with the tenant's credit cap metering items.
            handle = self.service.submit(
                spec, priority=0, timeout=job_timeout,
                retries=entry.retries, tenant=entry.tenant,
                max_inflight=pol.max_inflight,
            )
        else:
            handle = self.service.submit(
                spec, priority=entry.priority, timeout=job_timeout,
                retries=entry.retries, tenant=entry.tenant,
            )
        self.store.mark_running(entry.ticket)
        with self._lock:
            self._active[entry.ticket] = _Active(entry.ticket,
                                                 entry.tenant, handle)
        self.telemetry.inc("tickets_admitted")
        self.telemetry.emit("ticket_admitted", ticket=entry.ticket,
                            tenant=entry.tenant,
                            job=handle.job_id)

    # -- lifecycle -----------------------------------------------------------

    def close(self, *, wait: bool = True,
              timeout: float | None = 60.0) -> None:
        """Stop metering.  ``wait=True`` (default) first lets admitted
        jobs finish and records their results; queued tickets stay queued
        in the store either way — a later gateway over the same database
        resumes them.  The pool itself is left running (caller-owned)."""
        if wait:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while True:
                with self._lock:
                    active = list(self._active.values())
                if not active:
                    break
                if deadline is not None and time.monotonic() >= deadline:
                    break
                active[0].handle.wait(0.25)
                self._reap()
        self.kill()

    def kill(self) -> None:
        """Abrupt stop — the crash the durability tests simulate: no
        reaping, no state transitions; ``running`` rows are left as-is for
        the next gateway's ``recover()`` to requeue."""
        self._stop.set()
        if self.autoscaler is not None:
            self.autoscaler.stop()
        self._wake.set()
        self._pump.join(timeout=5.0)
        self.store.close()

    def __enter__(self) -> "JobGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
