import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

The two lines above MUST stay first: jax locks the device count at first
initialisation, and the dry-run needs 512 placeholder host devices to build
the production meshes.  (Smoke tests and benches must NOT import this
module — they see 1 device.)

Per cell this proves, with zero allocation (ShapeDtypeStruct inputs):

* the builder-derived shardings compose (no mismatched collectives),
* the program partitions onto 16x16 and 2x16x16 meshes,
* ``memory_analysis()`` -> per-device bytes (does it fit 16 GiB HBM v5e?),
* ``cost_analysis()``   -> per-device FLOPs/bytes (roofline numerators),
* the collective schedule (parsed from partitioned HLO).

Results are cached as JSON under ``results/dryrun/`` for EXPERIMENTS.md.
Usage::

    python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import all_cells, get_config, get_shape
from repro.core.builder import ClusterBuilder
from repro.core.channels import rules_for_shape_kind
from repro.core.hlo import parse_collectives
from repro.launch.mesh import HBM_BYTES, make_production_mesh, model_axis_size
from repro.models.flops import step_flops
from repro.optim.adamw import AdamWConfig
from repro.runtime import steps as steps_mod


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(step_fn, example_args) for one cell — shared with roofline probes."""
    rules = rules_for_shape_kind(mesh, shape.kind)
    tp = model_axis_size(mesh)
    opt_cfg = AdamWConfig()
    if shape.kind == "train":
        fn = steps_mod.make_train_step(cfg, opt_cfg, tp=tp, rules=rules)
        p, o = steps_mod.train_state_structs(cfg, rules, tp, opt_cfg)
        b = steps_mod.batch_structs(cfg, shape, rules)
        args = (p, o, b, jax.ShapeDtypeStruct((), jnp.int32))
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg, tp=tp, rules=rules)
        p, _ = steps_mod.train_state_structs(cfg, rules, tp, opt_cfg)
        b = steps_mod.prefill_batch_structs(cfg, shape, rules)
        args = (p, b)
        donate = ()
    else:  # decode / long
        fn = steps_mod.make_decode_step(cfg, tp=tp, rules=rules)
        p, _ = steps_mod.train_state_structs(cfg, rules, tp, opt_cfg)
        cache, tokens, cache_len = steps_mod.decode_input_structs(
            cfg, shape, rules, tp
        )
        args = (p, cache, tokens, cache_len)
        donate = (1,)
    return fn, args, donate, rules, tp


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    fn, args, donate, rules, tp = build_cell(cfg, shape, mesh)
    builder = ClusterBuilder(mesh=mesh, rules=rules)
    art = builder.build_step(
        fn, args, name=f"{arch}/{shape_name}", donate_argnums=donate
    )
    load_s = time.perf_counter() - t0

    ma = art.memory()
    cost = art.cost()
    colls = art.collectives()
    chips = mesh.devices.size
    fl = step_flops(cfg, shape, tp=tp)
    per_dev_bytes = (
        ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
        - ma.alias_size_in_bytes
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "ok": True,
        "load_compile_s": round(load_s, 2),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "live_bytes_per_device": per_dev_bytes,
            "fits_hbm": bool(per_dev_bytes <= HBM_BYTES),
            "hbm_fraction": round(per_dev_bytes / HBM_BYTES, 4),
        },
        # NOTE: scan bodies counted once (see launch.roofline for totals).
        "cost_analysis": cost,
        "collectives": {
            "by_kind": {
                k: {"count": n, "link_MiB_per_device": round(b / 2**20, 3)}
                for k, (n, b) in colls.by_kind().items()
            },
            "total_ops": len(colls.ops),
            "total_link_MiB_per_device": round(colls.total_link_bytes / 2**20, 3),
        },
        "model_flops_global": fl.model_flops,
        "params_total": fl.params_total,
        "params_active": fl.params_active,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [
            (cfg.name, shape.name, mp)
            for cfg, shape, runnable in all_cells()
            if runnable
            for mp in (False, True)
        ]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape_name, mp in cells:
        tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            result = dryrun_cell(arch, shape_name, mp)
            mem = result["memory"]
            print(
                f"  ok in {result['load_compile_s']}s: "
                f"{mem['live_bytes_per_device'] / 2**30:.2f} GiB/device "
                f"(HBM {100 * mem['hbm_fraction']:.1f}%), "
                f"{result['collectives']['total_ops']} collectives, "
                f"flops/dev {result['cost_analysis']['flops_per_device']:.3e}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 - recorded per cell
            failures += 1
            result = {
                "arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if mp else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"  FAILED: {result['error']}", flush=True)
        with open(path, "w") as fh:
            json.dump(result, fh, indent=2)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("all requested dry-run cells compiled")


if __name__ == "__main__":
    main()
