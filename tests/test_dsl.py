"""DSL parsing, spec validation, builder wiring and the local runtime."""

import pytest

from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec, parse_cgpp
from repro.core.processes import EmitDetails, ResultDetails


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _sum_collect():
    return ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)


def test_simple_spec_runs_to_completion():
    spec = ClusterSpec.simple(
        host="10.0.0.1", nclusters=2, workers_per_node=3,
        emit_details=_range_emit(50),
        work_function=lambda x: x * x,
        result_details=_sum_collect(),
    )
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum(i * i for i in range(50))


def test_demand_driven_distribution_is_load_balanced():
    """The onrl/nrfa protocol hands work to whichever node is idle; with
    uniform work every node must process a nontrivial share."""
    spec = ClusterSpec.simple(
        host="10.0.0.1", nclusters=3, workers_per_node=2,
        emit_details=_range_emit(300),
        work_function=lambda x: x + 1,
        result_details=_sum_collect(),
    )
    builder = ClusterBuilder()
    app = builder.build_application(spec)
    app.run()
    items = {t.node_id: t.items for t in builder.timing.nodes
             if t.node_id.startswith("node")}
    assert sum(items.values()) == 300
    assert all(v > 0 for v in items.values()), items


def test_cgpp_parser_roundtrip():
    text = """
cores = 2
clusters = 3
//@emit 192.168.1.176
details = DataDetails(name='r', init=lambda n: (0, n), init_data=(10,),
                      create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0]+1, s[1])))
emit = Emit(e_details=details)
onrl = OneNodeRequestedList()
//@cluster clusters
nrfa = NodeRequestingFanAny(destinations=cores)
group = AnyGroupAny(workers=cores, function=lambda x: 2 * x)
afoc = AnyFanOne(sources=cores)
//@collect
rd = ResultDetails(name='sum', init=lambda: 0, collect=lambda a, x: a + x)
afo = AnyFanOne(sources=clusters)
collector = Collect(r_details=rd)
"""
    spec = parse_cgpp(text)
    assert spec.host == "192.168.1.176"
    assert spec.nclusters == 3
    assert spec.workers_per_node == 2
    assert spec.constants["cores"] == 2
    app = ClusterBuilder().build_application(spec)
    assert app.run() == sum(2 * i for i in range(10))


def test_cgpp_parser_rejects_malformed():
    with pytest.raises(SyntaxError):
        parse_cgpp("x = 1\n//@cluster 2\n//@emit 1.2.3.4\n//@collect\n")
    with pytest.raises(SyntaxError):
        parse_cgpp("x = 1\n")


def test_cgpp_malformed_annotations_name_the_offending_line():
    # //@emit without a host-ip (line 2)
    with pytest.raises(SyntaxError, match=r"line 2: malformed annotation.*//@emit"):
        parse_cgpp("x = 1\n//@emit\n//@cluster 2\n//@collect\n")
    # //@cluster without a count (line 3)
    with pytest.raises(SyntaxError, match=r"line 3: malformed annotation.*//@cluster"):
        parse_cgpp("x = 1\n//@emit 1.2.3.4\n//@cluster\n//@collect\n")
    # unknown annotation form
    with pytest.raises(SyntaxError, match=r"line 1: malformed annotation.*//@emitter"):
        parse_cgpp("//@emitter 1.2.3.4\n//@cluster 2\n//@collect\n")


def test_cgpp_out_of_order_annotations_name_the_offending_line():
    # //@cluster before //@emit: the parser points at the cluster line
    with pytest.raises(SyntaxError, match=r"line 2: .*//@cluster.*must follow"):
        parse_cgpp("x = 1\n//@cluster 2\n//@emit 1.2.3.4\n//@collect\n")
    # //@collect before //@cluster
    with pytest.raises(SyntaxError, match=r"line 3: .*//@collect.*must follow"):
        parse_cgpp("x = 1\n//@emit 1.2.3.4\n//@collect\n//@cluster 2\n")


def test_cgpp_duplicate_sections_name_the_offending_line():
    with pytest.raises(SyntaxError, match=r"line 3: .*duplicate //@emit"):
        parse_cgpp("//@emit 1.2.3.4\nx = 1\n//@emit 5.6.7.8\n//@cluster 2\n//@collect\n")
    with pytest.raises(SyntaxError, match=r"line 4: .*duplicate //@cluster"):
        parse_cgpp("//@emit 1.2.3.4\nx = 1\n//@cluster 2\n//@cluster 3\n//@collect\n")
    with pytest.raises(SyntaxError, match=r"line 5: .*duplicate //@collect"):
        parse_cgpp("//@emit 1.2.3.4\n//@cluster 2\nx = 1\n//@collect\n//@collect\n")


def test_cgpp_missing_collect_section():
    with pytest.raises(SyntaxError, match="missing //@collect"):
        parse_cgpp("//@emit 1.2.3.4\n//@cluster 2\nx = 1\n")
    with pytest.raises(SyntaxError, match="missing //@emit"):
        parse_cgpp("x = 1\ny = 2\n")


def test_spec_validation_catches_mismatched_fanin():
    spec = ClusterSpec.simple(
        host="h", nclusters=2, workers_per_node=2,
        emit_details=_range_emit(5), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    spec.host_net.afo.sources = 3  # corrupt
    with pytest.raises(ValueError, match="AnyFanOne"):
        spec.validate()


def test_deployment_plan_structure():
    spec = ClusterSpec.simple(
        host="192.168.1.176", nclusters=4, workers_per_node=6,
        emit_details=_range_emit(5), work_function=lambda x: x,
        result_details=_sum_collect(),
    )
    plan = ClusterBuilder().deployment_plan(spec)
    assert plan.host_load_address == "192.168.1.176:2000/1"
    assert len(plan.nodes) == 4
    order = plan.load_order()
    # input ends before output ends; loading before the app network
    assert any("input channel" in s for s in order[:1])
    assert "timing" in order[-1] or "load_ms" in order[-1]


def test_load_time_fraction_small():
    """Paper section 8.2: load < 1% of runtime for real workloads; with a
    compute-heavy work function ours should be well under 20% even at toy
    scale."""
    import numpy as np

    def work(x):
        return float(np.sum(np.arange(20000) * (x + 1) % 7))

    spec = ClusterSpec.simple(
        host="h", nclusters=2, workers_per_node=2,
        emit_details=_range_emit(120), work_function=work,
        result_details=_sum_collect(),
    )
    builder = ClusterBuilder()
    app = builder.build_application(spec)
    app.run()
    assert builder.timing.load_fraction() < 0.5
