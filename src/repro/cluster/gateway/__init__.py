"""repro.cluster.gateway — the multi-tenant front door of a warm pool.

The paper's premise — idle workstations absorbing an organisation's big
jobs — implies many independent users sharing one cluster.  This package
is that sharing layer, sitting in front of
:class:`~repro.cluster.service.ClusterService` (the Public Cluster line of
work, arXiv:0708.0605/0708.0603, is the shape; hyper-shell's
database-backed task table is the durability exemplar):

* :mod:`~repro.cluster.gateway.store` — the SQLite ticket table: every
  submission is a row first, so tickets survive client disconnects and
  gateway restarts;
* :mod:`~repro.cluster.gateway.scheduler` — weighted-fair admission:
  deficit round robin over tenants (priority only orders *within* a
  tenant) with starvation-proof aging, plus per-tenant caps;
* :mod:`~repro.cluster.gateway.autoscale` — the queue-driven control loop
  growing/shrinking the pool through late join and graceful retirement;
* :mod:`~repro.cluster.gateway.gateway` — :class:`JobGateway`, tying the
  three together: ``enqueue() -> ticket``, ``attach(ticket)``,
  ``cancel(ticket)``.

See ARCHITECTURE.md "Job gateway & fair scheduling".
"""

from repro.cluster.gateway.autoscale import (  # noqa: F401
    AutoscalePolicy,
    Autoscaler,
)
from repro.cluster.gateway.gateway import (  # noqa: F401
    JobCancelled,
    JobGateway,
    TicketHandle,
)
from repro.cluster.gateway.scheduler import (  # noqa: F401
    FairScheduler,
    QueueEntry,
    TenantPolicy,
)
from repro.cluster.gateway.store import TicketRow, TicketStore  # noqa: F401

__all__ = [
    "AutoscalePolicy",
    "Autoscaler",
    "FairScheduler",
    "JobCancelled",
    "JobGateway",
    "QueueEntry",
    "TenantPolicy",
    "TicketHandle",
    "TicketRow",
    "TicketStore",
]
