"""The Node-Loader (NL): the identical executable every worker machine runs.

Paper §4: the user starts *one* NodeLoader per node — it knows only the
host's load address ("ip:2000/1"); everything else (code, topology, worker
count) arrives over the load network.  Mirroring that:

    python -m repro.cluster.node_loader --host 127.0.0.1 --port <p>

Lifecycle (timed per requirement 7, split three ways):

1. *boot*: connect + REGISTER (node id, cores, pid) on the load channel
   while a background thread pre-imports heavy dependencies named on the
   command line (``--preload jax.numpy``) — the environment cost of the
   workstation, accounted separately from code distribution.  The dial
   retries with exponential backoff inside ``--connect-timeout``: a
   remotely launched node may come up before the host is listening;
2. *load*: receive LOAD — the deployment payload (work function shipped by
   value over the code-loading channel; optional AOT-serialized executables
   land in :data:`ARTIFACTS`).  Deserialization is deferred until the
   preloader finishes so shipped-code imports hit a warm module cache
   instead of serializing on the import lock inside the load window;
3. *run*: the node-local Figure-2 fragment, pipelined.  The nrfa client
   keeps a *window* of ``workers + prefetch`` items resident: one initial
   WORK_REQUEST carries ``credits=window``, the host answers with a
   WORK_BATCH, and every RESULT_BATCH the flusher sends piggybacks
   ``credits=len(results)`` — each completed item frees a window slot, so
   demand travels with delivery and workers never idle on a round-trip.
   Results coalesce in a small buffer flushed on a threshold or a few-ms
   interval instead of one frame + one syscall per item;
4. on UT: flood workers with UT, join them, return
   (boot_ms, load_ms, run_ms, items) to the host in a final UT frame,
   exit 0.

This module must import without jax — a node-loader on a fresh workstation
is a bare bootstrap; the shipped code pulls in its own dependencies when
deserialized (or earlier, via ``--preload``).
"""

from __future__ import annotations

import argparse
import importlib
import os
import queue
import socket
import threading
import time
import traceback
from typing import Any, Sequence

from repro.cluster.netchannels import ChannelClosed, ChannelMux
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    DEFAULT_HEARTBEAT_S,
    LOAD_WIRE_CHANNEL,
    UT,
    Frame,
    FrameConnection,
    FrameType,
)

# AOT-serialized executables shipped in the LOAD payload, keyed by name.
# Work functions may read these (e.g. deserialize_and_load a compiled step).
ARTIFACTS: dict[str, bytes] = {}


def connect_with_retry(host: str, port: int,
                       timeout: float = 30.0) -> socket.socket:
    """Dial the host, retrying with exponential backoff until ``timeout``.

    On a real network the start order is uncontrolled: an ssh-launched
    node-loader routinely comes up before the host binds its load port (or
    while the host is still syncing code to other machines).  Dying on the
    first ECONNREFUSED would turn every such race into a lost workstation;
    instead the node keeps dialling — 0.2s, 0.4s, ... capped at 2s between
    attempts — and only gives up once the whole window is spent.
    """
    deadline = time.monotonic() + timeout
    delay = 0.2
    while True:
        remaining = deadline - time.monotonic()
        try:
            return socket.create_connection(
                (host, port), timeout=max(0.2, min(5.0, remaining))
            )
        except OSError as exc:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ConnectionError(
                    f"could not reach host-node-loader at {host}:{port} "
                    f"within {timeout}s: {exc}"
                ) from exc
            time.sleep(min(delay, remaining))
            delay = min(delay * 2, 2.0)


def run_node(
    host: str,
    port: int,
    *,
    node_id: str | None = None,
    connect_timeout: float = 30.0,
    preload: Sequence[str] = (),
) -> dict[str, Any]:
    """Run one Node-Loader to completion; returns its timing record."""
    node_id = node_id or f"{socket.gethostname()}-{os.getpid()}"
    t_boot0 = time.perf_counter()

    # Heavy dependencies import concurrently with registration: the cost of
    # booting the environment lands in boot_ms, not in the code-distribution
    # (load) window the paper accounts in §8.2.
    def preloader() -> None:
        for name in preload:
            try:
                importlib.import_module(name)
            except Exception:  # the shipped code will surface a real error
                pass

    preload_thread = threading.Thread(target=preloader, name="nl-preload",
                                      daemon=True)
    preload_thread.start()

    sock = connect_with_retry(host, port, timeout=connect_timeout)
    sock.settimeout(None)
    conn = FrameConnection(sock)
    mux = ChannelMux(conn)
    # Inboxes exist before we announce ourselves (§4 ordering: input ends
    # before output ends).  The reader *thread* starts only after the
    # preloader joins — decoding LOAD pulls in the shipped code's imports,
    # and those must not contend with the preloader inside the load window;
    # meanwhile inbound frames simply wait in the kernel socket buffer.
    load_ch = mux.open(LOAD_WIRE_CHANNEL, FrameType.LOAD, maxsize=4)
    app_ch = mux.open(APP_WIRE_CHANNEL, FrameType.WORK_BATCH, maxsize=64)

    conn.send(Frame(
        FrameType.REGISTER,
        {"node_id": node_id, "cores": os.cpu_count() or 1, "pid": os.getpid()},
        LOAD_WIRE_CHANNEL,
    ))

    # The beacon starts right after REGISTER: the boot/load phases may take
    # seconds (jax import), and the host must not mistake them for death.
    # The interval is refined once the plan says what the host expects.
    stop_beat = threading.Event()
    beat_interval = [DEFAULT_HEARTBEAT_S]

    def heartbeat() -> None:
        while not stop_beat.wait(beat_interval[0]):
            try:
                conn.send(Frame(
                    FrameType.HEARTBEAT, {"node_id": node_id},
                    LOAD_WIRE_CHANNEL,
                ))
            except OSError:
                return

    beat_thread = threading.Thread(target=heartbeat, name="nl-heartbeat",
                                   daemon=True)
    beat_thread.start()

    preload_thread.join()
    boot_ms = (time.perf_counter() - t_boot0) * 1e3
    t_load0 = time.perf_counter()
    mux.start()

    try:
        plan = load_ch.get(timeout=connect_timeout)
    except queue.Empty:
        stop_beat.set()
        conn.close()
        raise ConnectionError(
            f"no LOAD received from the host within {connect_timeout}s "
            "(are all expected node-loaders up?)"
        ) from None
    if plan is UT:  # host aborted during bootstrap
        stop_beat.set()
        conn.close()
        return {"node_id": node_id, "boot_ms": round(boot_ms, 3),
                "load_ms": 0.0, "run_ms": 0.0, "items": 0}
    fn = plan["function"]
    workers = int(plan["workers"])
    slowdown = float(plan.get("slowdown", 0.0))
    beat_interval[0] = float(
        plan.get("heartbeat_interval", DEFAULT_HEARTBEAT_S)
    )
    prefetch = plan.get("prefetch")
    # None = one extra per worker; 0 is honoured (strict one-item-per-worker
    # window, the pure demand-driven pre-pipelining behaviour).
    prefetch = workers if prefetch is None else max(0, int(prefetch))
    window = workers + prefetch
    flush_items = max(1, int(plan.get("flush_items", 8)))
    flush_interval = float(plan.get("flush_interval", 0.005))
    ARTIFACTS.clear()
    ARTIFACTS.update(plan.get("artifacts") or {})
    load_ms = (time.perf_counter() - t_load0) * 1e3

    # -- the node-local Figure-2 fragment, pipelined -------------------------
    # Buffering is bounded by the credit window, not by queue capacity: the
    # host never holds more than `window` items against this node.
    work_q: queue.Queue = queue.Queue()
    items_done = 0
    items_lock = threading.Lock()

    out_lock = threading.Lock()
    out_buf: list[dict] = []
    flush_now = threading.Event()
    stop_flush = threading.Event()

    def complete(result: dict, urgent: bool = False) -> None:
        with out_lock:
            out_buf.append(result)
            n = len(out_buf)
        if urgent or n >= flush_items:
            flush_now.set()

    def flush() -> None:
        with out_lock:
            if not out_buf:
                return
            batch, out_buf[:] = list(out_buf), []
        payload = {"node_id": node_id, "results": batch,
                   # Each finished item frees one window slot: demand
                   # piggybacks on delivery (no separate request frame).
                   "credits": len(batch)}
        try:
            conn.send(Frame(FrameType.RESULT_BATCH, payload, APP_WIRE_CHANNEL))
        except OSError:
            pass  # host gone: the nrfa loop shuts the node down
        except Exception as exc:
            # A result refused to serialize: report instead of stalling the
            # job with a silently dead flusher (the host fails fast).
            try:
                conn.send(Frame(
                    FrameType.RESULT_BATCH,
                    {"node_id": node_id, "credits": len(batch),
                     "results": [{
                         "id": batch[0]["id"],
                         "error": f"{type(exc).__name__}: {exc}",
                         "traceback": traceback.format_exc(),
                     }]},
                    APP_WIRE_CHANNEL,
                ))
            except OSError:
                pass

    def flusher() -> None:
        while not stop_flush.is_set():
            flush_now.wait(flush_interval)
            flush_now.clear()
            flush()
        flush()  # drain the tail after the workers joined

    def worker() -> None:
        nonlocal items_done
        while True:
            item = work_q.get()
            if item is UT:
                return
            try:
                value = fn(item["obj"])
                if slowdown > 0.0:
                    time.sleep(slowdown)  # injected straggler (§6.1 testing)
                complete({"id": item["id"], "value": value})
            except BaseException as exc:
                # Report instead of dying silently: a dead worker thread
                # would stall the node (heartbeats keep flowing, so the
                # host would never re-dispatch).  The host fails the job.
                complete({"id": item["id"],
                          "error": f"{type(exc).__name__}: {exc}",
                          "traceback": traceback.format_exc()},
                         urgent=True)
                continue
            with items_lock:
                items_done += 1

    worker_threads = [
        threading.Thread(target=worker, name=f"nl-worker{i}", daemon=True)
        for i in range(workers)
    ]
    for t in worker_threads:
        t.start()
    flush_thread = threading.Thread(target=flusher, name="nl-flusher",
                                    daemon=True)
    flush_thread.start()

    t_run0 = time.perf_counter()
    try:
        # The windowed nrfa client: one up-front demand for the whole
        # window, then WORK_BATCH frames fill it and RESULT_BATCH credits
        # (sent by the flusher) keep it full.
        conn.send(Frame(
            FrameType.WORK_REQUEST,
            {"node_id": node_id, "credits": window},
            APP_WIRE_CHANNEL,
        ))
        while True:
            msg = app_ch.get()
            if msg is UT:
                for _ in range(workers):
                    work_q.put(UT)
                break
            items = (msg["items"]
                     if isinstance(msg, dict) and "items" in msg
                     else [msg])  # legacy single-WORK frame
            for item in items:
                work_q.put(item)
    except (ChannelClosed, OSError):
        # Host vanished (mid-recv or mid-request-send): there is nobody to
        # deliver to; shut down quietly.
        for _ in range(workers):
            work_q.put(UT)
    for t in worker_threads:
        t.join()
    stop_flush.set()
    flush_now.set()
    flush_thread.join()
    run_ms = (time.perf_counter() - t_run0) * 1e3
    stop_beat.set()

    record = {
        "node_id": node_id,
        "boot_ms": round(boot_ms, 3),
        "load_ms": round(load_ms, 3),
        "run_ms": round(run_ms, 3),
        "items": items_done,
    }
    try:
        conn.send(Frame(FrameType.UT, record, LOAD_WIRE_CHANNEL))
    except OSError:
        pass
    conn.close()
    return record


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        description="ClusterBuilder Node-Loader (paper §4)"
    )
    parser.add_argument("--host", required=True,
                        help="Host-Node-Loader address")
    parser.add_argument("--port", type=int, required=True,
                        help="load network port (the paper's 2000)")
    parser.add_argument("--node-id", default=None)
    parser.add_argument(
        "--connect-timeout", type=float, default=30.0,
        help="seconds to keep retrying the initial host dial (with "
             "exponential backoff) before giving up",
    )
    parser.add_argument(
        "--preload", default="",
        help="comma-separated modules to import during boot, overlapping "
             "registration (e.g. 'jax.numpy')",
    )
    args = parser.parse_args(argv)
    preload = tuple(m for m in args.preload.split(",") if m)
    try:
        record = run_node(
            args.host, args.port,
            node_id=args.node_id,
            connect_timeout=args.connect_timeout,
            preload=preload,
        )
    except (ConnectionError, socket.timeout, OSError) as exc:
        print(
            f"node-loader: cannot reach host-node-loader at "
            f"{args.host}:{args.port}: {exc}",
            flush=True,
        )
        return 1
    print(f"node-loader done: {record}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
