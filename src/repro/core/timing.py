"""Load-time vs run-time accounting (paper requirement 7).

ClusterBuilder collects, per node, the time spent *loading* the application
(code distribution, channel construction, synchronisation barriers) separately
from the time spent *running* it.  On termination every node returns its
timings to the host, which combines them with its own and prints the table
(paper §4, §8.2: load time was linear in the node count, 132.5 +/- 2.5 ms per
node, and under 1% of total run time).

This module is runtime-agnostic: the local threaded runtime, the SPMD
executor and the dry-run all record into the same structure.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field


@dataclass
class NodeTiming:
    """Timing record for a single (logical) node."""

    node_id: str
    load_ms: float = 0.0
    run_ms: float = 0.0
    items: int = 0

    def as_dict(self) -> dict:
        return {
            "node_id": self.node_id,
            "load_ms": round(self.load_ms, 3),
            "run_ms": round(self.run_ms, 3),
            "items": self.items,
        }


class TimingCollector:
    """Thread-safe collector of per-node load/run timings.

    Usage::

        tc = TimingCollector()
        with tc.phase("node0", "load"):
            ...  # channel construction, code transfer
        with tc.phase("node0", "run"):
            ...  # application processing
        print(tc.report())
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._nodes: dict[str, NodeTiming] = {}

    def node(self, node_id: str) -> NodeTiming:
        with self._lock:
            if node_id not in self._nodes:
                self._nodes[node_id] = NodeTiming(node_id=node_id)
            return self._nodes[node_id]

    def phase(self, node_id: str, kind: str) -> "_PhaseTimer":
        if kind not in ("load", "run"):
            raise ValueError(f"phase kind must be 'load' or 'run', got {kind!r}")
        return _PhaseTimer(self, node_id, kind)

    def add(self, node_id: str, kind: str, ms: float) -> None:
        rec = self.node(node_id)
        with self._lock:
            if kind == "load":
                rec.load_ms += ms
            else:
                rec.run_ms += ms

    def count_item(self, node_id: str, n: int = 1) -> None:
        rec = self.node(node_id)
        with self._lock:
            rec.items += n

    # -- reporting ---------------------------------------------------------

    @property
    def nodes(self) -> list[NodeTiming]:
        with self._lock:
            return sorted(self._nodes.values(), key=lambda r: r.node_id)

    def total_load_ms(self) -> float:
        return sum(n.load_ms for n in self.nodes)

    def total_run_ms(self) -> float:
        return max((n.run_ms for n in self.nodes), default=0.0)

    def load_fraction(self) -> float:
        """Load time as a fraction of total wall time (paper reports <1%)."""
        run = self.total_run_ms()
        load = self.total_load_ms()
        denom = run + load
        return load / denom if denom > 0 else 0.0

    def report(self) -> str:
        lines = [f"{'node':<16}{'load_ms':>12}{'run_ms':>14}{'items':>8}"]
        for rec in self.nodes:
            lines.append(
                f"{rec.node_id:<16}{rec.load_ms:>12.3f}{rec.run_ms:>14.3f}"
                f"{rec.items:>8d}"
            )
        lines.append(
            f"load fraction of total: {100.0 * self.load_fraction():.3f}%"
        )
        return "\n".join(lines)

    def as_json(self) -> str:
        return json.dumps([n.as_dict() for n in self.nodes], indent=2)


class _PhaseTimer:
    def __init__(self, collector: TimingCollector, node_id: str, kind: str):
        self._collector = collector
        self._node_id = node_id
        self._kind = kind
        self._t0 = 0.0

    def __enter__(self) -> "_PhaseTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        dt_ms = (time.perf_counter() - self._t0) * 1e3
        self._collector.add(self._node_id, self._kind, dt_ms)
