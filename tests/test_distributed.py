"""Distribution tests that need >1 device: run in a subprocess with
forced host platform device count (tests themselves keep the 1-device
default, matching the dryrun-only rule for XLA_FLAGS)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str = "", devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_smoke_arch_compiles_on_multi_device_mesh():
    """A reduced arch lowers+compiles on a (2 data x 4 model) mesh, with the
    sharded-train-step semantics equal to single-device execution."""
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeConfig
        from repro.core.channels import training_rules
        from repro.launch.mesh import compat_make_mesh, use_mesh
        from repro.runtime import steps as steps_mod
        from repro.models.common import init_params, param_shardings
        from repro.optim import adamw
        from repro.data.pipeline import source_for, shard_batch

        cfg = dataclasses.replace(get_config('yi-9b').smoke(),
                                  d_model=64, num_heads=4, num_kv_heads=4,
                                  vocab_size=256, compute_dtype='float32')
        shape = ShapeConfig('t', seq_len=32, global_batch=8, kind='train')
        mesh = compat_make_mesh((2, 4), ('data', 'model'))
        rules = training_rules(mesh)
        opt_cfg = adamw.AdamWConfig()
        tp = 4

        specs = steps_mod.model_param_specs(cfg, tp)
        params = init_params(specs, jax.random.PRNGKey(0), jnp.float32,
                             rules=rules)
        opt_state = adamw.init_state(params, opt_cfg)
        step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, tp=tp,
                                                 rules=rules))
        src = source_for(cfg, shape)
        batch = shard_batch(src.batch(0), rules)
        with use_mesh(mesh):
            p1, o1, m1 = step(params, opt_state, batch, jnp.int32(0))
        print('sharded_loss', float(m1['loss']))

        # single-device (tp=1 config) reference: same loss up to padding
        specs1 = steps_mod.model_param_specs(cfg, 1)
        # note: tp=4 pads nothing here (all dims divide), so reuse params
        step1 = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, tp=1,
                                                  rules=None))
        import numpy as np
        batch1 = {k: jnp.asarray(np.asarray(v)) for k, v in batch.items()}
        p2, o2, m2 = step1(params, opt_state, batch1, jnp.int32(0))
        print('local_loss', float(m2['loss']))
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_remesh_resume():
    """Train on 8 devices (4 nodes x 2), lose a node, re-mesh onto 2 nodes,
    restore the checkpoint against the new shardings and keep training."""
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses, tempfile
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeConfig
        from repro.runtime.executor import Trainer, TrainerConfig
        from repro.runtime.elastic import ElasticController
        from repro.runtime.failures import FailurePlan, FailureEvent
        from repro.optim.adamw import AdamWConfig

        cfg = dataclasses.replace(get_config('yi-9b').smoke(),
                                  compute_dtype='float32')
        shape = ShapeConfig('t', seq_len=32, global_batch=8, kind='train')
        elastic = ElasticController(model_axis=2, devices_per_node=1,
                                    shape_kind='train')
        mesh, rules = elastic.build(elastic.available_nodes())
        assert dict(mesh.shape) == {'data': 4, 'model': 2}
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, shape,
                         TrainerConfig(num_steps=10, checkpoint_every=2,
                                       checkpoint_dir=d, warmup_steps=1,
                                       tp=2),
                         opt_cfg=AdamWConfig(),
                         rules=rules, mesh=mesh,
                         failure_plan=FailurePlan([
                             FailureEvent(step=5, kind='node_loss', node=3)]),
                         elastic=elastic)
            out = tr.run()
            assert out['restarts'] == 1
            # mesh shrank: node 3 excluded -> 3 nodes, batch 8 % 3 != 0 -> 2
            assert dict(tr.mesh.shape)['data'] in (2, 3)
            assert out['final_step'] == 10
        print('OK', dict(tr.mesh.shape))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_executable_serialization_roundtrip():
    """AOT compile once, serialize, deserialize-and-load (the paper's
    code-loading channel analogue) and execute.  devices=4: the deserialised
    executable binds to the process's full device set, so the mesh must
    cover it (on a real pod every chip participates)."""
    out = run_sub(devices=4, code="""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.builder import ClusterBuilder
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 2), ('data', 'model'))
        x = jax.device_put(jnp.arange(16.0).reshape(4, 4),
                           NamedSharding(mesh, P('data', None)))
        builder = ClusterBuilder(mesh=mesh)
        art = builder.build_step(lambda a: (a * 2).sum(), [x], name='double')
        payload = art.serialize()
        assert isinstance(payload, bytes) and len(payload) > 100
        import jax.tree_util as jtu
        from jax.experimental.serialize_executable import deserialize_and_load, serialize
        p2, in_tree, out_tree = serialize(art.compiled)
        loaded = deserialize_and_load(p2, in_tree, out_tree)
        result = loaded(x)
        assert float(jax.tree.leaves(result)[0]) == float(jnp.arange(16.0).sum() * 2)
        print('OK')
    """)
    assert "OK" in out
