"""repro.cluster.telemetry — live observability for the cluster.

Three pieces, all stdlib-only (this package must import without jax, like
the rest of the node-loader bootstrap path):

* :mod:`~repro.cluster.telemetry.registry` — the thread-safe event bus +
  metrics registry every host-side component publishes into, plus the
  JSONL trace writer for offline replay;
* :mod:`~repro.cluster.telemetry.http` — the ``GET /metrics`` / ``/jobs``
  / ``/nodes`` / ``/events`` status endpoint (JSON + Prometheus text);
* :mod:`~repro.cluster.telemetry.dashboard` — the self-contained HTML
  dashboard served at ``GET /``.

See ARCHITECTURE.md "Observability" for how the host loader, membership
layer, node heartbeats, and service scheduler feed it.
"""

from repro.cluster.telemetry.http import TelemetryServer  # noqa: F401
from repro.cluster.telemetry.registry import (  # noqa: F401
    Histogram,
    Telemetry,
    TraceWriter,
    read_trace,
    total_counts,
)

__all__ = ["Histogram", "Telemetry", "TelemetryServer", "TraceWriter",
           "read_trace", "total_counts"]
