"""The host-side event bus + metrics registry.

Everything the cluster already knows while a run is live — per-job farm
gauges, per-node wire counters, membership transitions, node-reported
boot/load phases and warm-cache hits — was invisible outside the process.
This module is the one place those signals meet:

* :class:`Telemetry` is a thread-safe **event bus** (a bounded ring of
  timestamped, sequence-numbered lifecycle events) plus a **metrics
  registry** (per-job gauges, per-node fields, cluster-level counters).
  The dispatcher, membership layer, and service scheduler *push* into it
  at state changes; fast-moving values the producers already maintain
  (wire byte counters, parked credits) are *pulled* at snapshot time
  through registered sampler callbacks, so the hot paths pay nothing for
  them.
* :class:`TraceWriter` appends every bus event as one JSON line, so a
  benchmark or post-mortem can replay the full membership/job lifecycle
  offline (:func:`read_trace`).

The registry is deliberately dependency-free (stdlib only) and knows
nothing about sockets or jobs — producers decide what a gauge means; the
registry stores, snapshots, and exports it (JSON via :meth:`snapshot`,
Prometheus text exposition via :meth:`prometheus`).
"""

from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Callable, Iterable

__all__ = ["Histogram", "Telemetry", "TraceWriter", "read_trace"]

# Default capacity of the event ring: enough for the full lifecycle of a
# long service run (events are per state change, not per item), bounded so
# an immortal pool can never grow host memory.
EVENT_RING_SLOTS = 1024

# Fixed bucket grids per histogram family, chosen here once so every
# producer observes into the same boundaries (upper bounds, inclusive —
# Prometheus ``le`` semantics; an implicit +Inf bucket closes each grid).
HISTOGRAM_BUCKETS: dict[str, tuple[float, ...]] = {
    # Dispatch-to-completion per item (WORK_BATCH send to result/ack).
    "item_latency_ms": (1, 2.5, 5, 10, 25, 50, 100, 250, 500,
                        1000, 2500, 5000, 10000),
    # Items per RESULT_BATCH frame (how well the flusher coalesces).
    "result_batch_items": (1, 2, 4, 8, 16, 32, 64, 128, 256),
    # Broadcast-block chunk sizes served (host or peer side).
    "block_chunk_bytes": (1 << 12, 1 << 14, 1 << 16, 1 << 18,
                          1 << 20, 1 << 22, 1 << 24),
}
_DEFAULT_BUCKETS = (0.1, 1.0, 10.0, 100.0, 1000.0, 10000.0)


class Histogram:
    """A fixed-bucket cumulative histogram (Prometheus-style).

    Buckets are per-family upper bounds; ``counts[i]`` is the number of
    observations ``<= bounds[i]`` *in that bucket only* (the snapshot and
    exposition cumulate).  Mutation is lock-free per instance — callers go
    through :meth:`Telemetry.observe`, which serializes under the bus lock.
    """

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: tuple[float, ...]):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        i = 0
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.count += 1
        self.sum += value

    def snapshot(self) -> dict:
        """Cumulative view: [[le, count_le], ...] plus count and sum."""
        cum, buckets = 0, []
        for bound, n in zip(self.bounds, self.counts):
            cum += n
            buckets.append([bound, cum])
        return {"buckets": buckets, "count": self.count,
                "sum": round(self.sum, 6)}


class TraceWriter:
    """Append-only JSONL sink for bus events (one event per line).

    Thread-safe (the dispatcher and service threads both emit) and flushed
    per line: a post-mortem after a crash sees every event that was
    emitted, not whatever survived in a userspace buffer.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", encoding="utf-8")

    def write(self, event: dict) -> None:
        line = json.dumps(event, default=str, separators=(",", ":"))
        with self._lock:
            if self._fh.closed:
                return
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


def read_trace(path: str) -> list[dict]:
    """Load a JSONL trace back into event dicts (blank lines skipped)."""
    events = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def _deep_merge(base: dict, extra: dict) -> dict:
    """Shallow-copy merge; dict values one level down merge instead of
    replacing (a node's sampled fields join its pushed ``report``)."""
    out = dict(base)
    for key, val in extra.items():
        if isinstance(val, dict) and isinstance(out.get(key), dict):
            out[key] = {**out[key], **val}
        else:
            out[key] = val
    return out


class Telemetry:
    """Thread-safe event bus + metrics registry (see module docstring).

    Producers push:

    * :meth:`emit` — one lifecycle event onto the ring (and the trace);
    * :meth:`set_job` / :meth:`set_node` — merge-update one job's gauges /
      one node's fields;
    * :meth:`inc` — bump a cluster-level counter (``jobs_completed``...).

    Consumers pull:

    * :meth:`snapshot` — one JSON-able dict of everything (gauges merged
      with whatever the registered samplers report *right now*);
    * :meth:`events_since` — the ring's events after a cursor, in order;
    * :meth:`prometheus` — the snapshot as Prometheus text exposition.

    ``clock`` is injectable for deterministic tests; it must return epoch
    seconds (events are wall-stamped so offline traces line up with logs).
    """

    def __init__(self, *, ring_size: int = EVENT_RING_SLOTS,
                 trace_path: str | None = None,
                 clock: Callable[[], float] = time.time):
        self._lock = threading.Lock()
        self._clock = clock
        self.started_at = clock()
        self._ring: collections.deque = collections.deque(maxlen=ring_size)
        self._seq = 0
        self._dropped = 0
        self._jobs: dict[int, dict] = {}
        self._nodes: dict[str, dict] = {}
        self._counters: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}
        # Pull-side sampler callbacks (all optional):
        #   nodes()   -> {node_id: {field: value, ...}} merged per node
        #   cluster() -> {counter: value} merged into the cluster section
        #   timing()  -> arbitrary dict exported as the "timing" section
        self._samplers: dict[str, Callable[[], dict]] = {}
        self.trace: TraceWriter | None = (
            TraceWriter(trace_path) if trace_path else None
        )

    # -- event bus -----------------------------------------------------------

    def emit(self, kind: str, **fields: Any) -> dict:
        """Publish one lifecycle event: sequence-stamped, wall-stamped,
        ring-buffered, and appended to the trace (when one is attached)."""
        with self._lock:
            self._seq += 1
            event = {"seq": self._seq, "ts": round(self._clock(), 6),
                     "kind": kind, **fields}
            if len(self._ring) == self._ring.maxlen:
                self._dropped += 1
            self._ring.append(event)
            # The trace write stays under the bus lock so the JSONL file is
            # seq-ordered: concurrent emitters would otherwise race between
            # taking a seq and appending their line.  Events are per state
            # change, not per item, so the line-buffered write is cheap.
            if self.trace is not None:
                self.trace.write(event)
        return event

    def events_since(self, since: int = 0, limit: int = 500) -> list[dict]:
        """Events with ``seq > since``, oldest first, at most ``limit``.

        The cursor contract: pass the largest ``seq`` you have seen to get
        only what is new.  A cursor older than the ring's tail silently
        skips the dropped span (``events_dropped`` in the snapshot says how
        much history was lost overall).
        """
        with self._lock:
            events = [e for e in self._ring if e["seq"] > since]
        return events[:max(0, int(limit))]

    # -- metrics registry ----------------------------------------------------

    def set_job(self, job_id: int, **gauges: Any) -> None:
        with self._lock:
            self._jobs.setdefault(job_id, {}).update(gauges)

    def set_node(self, node_id: str, **fields: Any) -> None:
        with self._lock:
            self._nodes.setdefault(node_id, {}).update(fields)

    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def observe(self, name: str, value: float) -> None:
        """Record one observation into the named histogram (created on
        first use with its family's bucket grid)."""
        with self._lock:
            hist = self._histograms.get(name)
            if hist is None:
                hist = Histogram(
                    HISTOGRAM_BUCKETS.get(name, _DEFAULT_BUCKETS))
                self._histograms[name] = hist
            hist.observe(float(value))

    def set_sampler(self, name: str, fn: Callable[[], dict]) -> None:
        """Register a pull-side sampler (``"nodes"``, ``"cluster"``,
        ``"timing"``, ``"chaos"`` or ``"gateway"``) — invoked on every
        snapshot, on the reader's thread."""
        if name not in ("nodes", "cluster", "timing", "chaos", "gateway"):
            raise ValueError(f"unknown sampler section {name!r}")
        self._samplers[name] = fn

    def _sample(self, name: str) -> dict:
        fn = self._samplers.get(name)
        if fn is None:
            return {}
        try:
            return fn() or {}
        except Exception:  # a sampler must never take the endpoint down
            return {}

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """One consistent-enough view of everything, JSON-able as-is.

        Pushed gauges are copied under the lock; sampled values (wire
        counters, parked credits, host stats) are read live — they are
        monotonic counters whose exact interleaving does not matter for
        reporting.
        """
        sampled_nodes = self._sample("nodes")
        sampled_cluster = self._sample("cluster")
        timing = self._sample("timing")
        chaos = self._sample("chaos")
        gateway = self._sample("gateway")
        now = self._clock()
        with self._lock:
            jobs = {str(jid): dict(g) for jid, g in self._jobs.items()}
            nodes = {nid: dict(f) for nid, f in self._nodes.items()}
            counters = dict(self._counters)
            histograms = {name: h.snapshot()
                          for name, h in self._histograms.items()}
            seq, dropped = self._seq, self._dropped
        for nid, fields in sampled_nodes.items():
            nodes[nid] = _deep_merge(nodes.get(nid, {}), fields)
        cluster = {**counters, **sampled_cluster}
        # Cluster-wide wire totals, summed over whatever the nodes report.
        totals: dict[str, float] = {}
        for fields in nodes.values():
            for key, val in (fields.get("wire") or {}).items():
                totals[key] = totals.get(key, 0) + val
        for key, val in totals.items():
            cluster.setdefault(f"wire_{key}", val)
        snap = {
            "ts": round(now, 6),
            "uptime_s": round(now - self.started_at, 6),
            "monotonic": time.monotonic(),
            "cluster": cluster,
            "jobs": jobs,
            "nodes": nodes,
            "events": {"next": seq, "dropped": dropped},
        }
        if histograms:
            snap["histograms"] = histograms
        if timing:
            snap["timing"] = timing
        if chaos:
            snap["chaos"] = chaos
        if gateway:
            snap["gateway"] = gateway
        return snap

    def prometheus(self) -> str:
        """The snapshot as Prometheus text exposition (version 0.0.4).

        Families (all gauges — the scraper owns rate computation):

        * ``repro_uptime_seconds``
        * ``repro_cluster_<counter>`` — cluster section, numeric entries;
        * ``repro_chaos_<field>`` — fault-injection section numerics
          (present only when a chaos controller is armed);
        * ``repro_gateway_<field>`` — job-gateway section numerics, with
          the per-tenant breakdown flattened as
          ``repro_gateway_tenant_<field>{tenant=...}`` and the ticket
          ledger as ``repro_gateway_tickets{state=...}``;
        * ``repro_job_<gauge>{job="1"}`` — per-job numerics; per-stage
          list gauges add a ``stage`` label per element;
        * ``repro_node_<field>{node="node0"}`` — per-node numerics, with
          nested dicts flattened (``wire`` -> ``repro_node_wire_bytes_sent``)
          and the state string exported as ``repro_node_state{state=...} 1``.
        """
        snap = self.snapshot()
        families: dict[str, list[tuple[str, float]]] = {}

        def sample(family: str, labels: dict, value: Any) -> None:
            if isinstance(value, bool):
                value = int(value)
            if not isinstance(value, (int, float)):
                return
            label_s = ",".join(
                f'{k}="{_escape_label(str(v))}"'
                for k, v in sorted(labels.items())
            )
            families.setdefault(family, []).append(
                (f"{{{label_s}}}" if label_s else "", float(value))
            )

        sample("repro_uptime_seconds", {}, snap["uptime_s"])
        for key, val in snap["cluster"].items():
            sample(f"repro_cluster_{key}", {}, val)
        for key, val in (snap.get("chaos") or {}).items():
            sample(f"repro_chaos_{key}", {}, val)  # numerics only
        gateway = dict(snap.get("gateway") or {})
        for state, n in (gateway.pop("tickets", None) or {}).items():
            sample("repro_gateway_tickets", {"state": state}, n)
        for tenant, fields in (gateway.pop("tenants", None) or {}).items():
            for key, val in (fields or {}).items():
                sample(f"repro_gateway_tenant_{key}", {"tenant": tenant}, val)
        for key, val in gateway.items():
            sample(f"repro_gateway_{key}", {}, val)  # numerics only
        for jid, gauges in snap["jobs"].items():
            for key, val in gauges.items():
                if isinstance(val, (list, tuple)):
                    for s, elem in enumerate(val):
                        sample(f"repro_job_{key}",
                               {"job": jid, "stage": s}, elem)
                else:
                    sample(f"repro_job_{key}", {"job": jid}, val)
        for nid, fields in snap["nodes"].items():
            flat = dict(fields)
            for nest in ("wire", "report"):
                for key, val in (flat.pop(nest, None) or {}).items():
                    flat[f"{nest}_{key}"] = val
            state = flat.pop("state", None)
            if state is not None:
                sample("repro_node_state", {"node": nid, "state": state}, 1)
            flat.pop("transitions", None)
            for key, val in flat.items():
                sample(f"repro_node_{key}", {"node": nid}, val)
        lines = []
        for family in sorted(families):
            lines.append(f"# TYPE {family} gauge")
            for labels, value in sorted(families[family]):
                value_s = f"{value:g}"
                lines.append(f"{family}{labels} {value_s}")
        hists = snap.get("histograms") or {}
        for name in sorted(hists):
            h = hists[name]
            family = f"repro_{name}"
            lines.append(f"# TYPE {family} histogram")
            for le, cum in h["buckets"]:
                lines.append(f'{family}_bucket{{le="{le:g}"}} {cum}')
            lines.append(f'{family}_bucket{{le="+Inf"}} {h["count"]}')
            lines.append(f"{family}_sum {h['sum']:g}")
            lines.append(f"{family}_count {h['count']}")
        return "\n".join(lines) + "\n"

    def close(self) -> None:
        if self.trace is not None:
            self.trace.close()


def _escape_label(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def total_counts(dicts: Iterable[dict]) -> dict:
    """Sum a stream of flat numeric dicts key-wise (wire-counter folding)."""
    totals: dict[str, float] = {}
    for d in dicts:
        for key, val in d.items():
            totals[key] = totals.get(key, 0) + val
    return totals
