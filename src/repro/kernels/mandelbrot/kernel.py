"""Mandelbrot escape-time as a Pallas TPU kernel.

Hardware adaptation (DESIGN.md): the paper distributes *lines* to worker
JVMs, each running a scalar per-point ``while`` loop.  A TPU has no
per-lane control flow, so the kernel is re-tiled for the VPU:

* the image is blocked into VMEM tiles (BLOCK_H x BLOCK_W, lane-aligned to
  (8, 128) f32 tiling);
* the data-dependent per-point ``while`` becomes a *fixed-trip*
  ``fori_loop`` over ``max_iters`` with a per-lane alive mask — every lane
  does the same work and the mask retires escaped points (the standard SIMD
  escape-time formulation);
* iteration counts accumulate in VMEM f32/ i32 registers; one store per tile.

The emit/cluster/collect deployment still distributes tiles across nodes —
the kernel is what one worker core runs per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_H = 64
BLOCK_W = 256


def _mandelbrot_kernel(x0_ref, y0_ref, iters_ref, colour_ref, *, max_iters: int):
    x0 = x0_ref[...]
    y0 = y0_ref[...]

    def body(_t, state):
        zx, zy, iters, alive = state
        zx2 = zx * zx
        zy2 = zy * zy
        alive = jnp.logical_and(alive, (zx2 + zy2) < 4.0)
        new_zx = zx2 - zy2 + x0
        new_zy = 2.0 * zx * zy + y0
        zx = jnp.where(alive, new_zx, zx)
        zy = jnp.where(alive, new_zy, zy)
        iters = iters + alive.astype(jnp.int32)
        return zx, zy, iters, alive

    zeros = jnp.zeros_like(x0)
    init = (zeros, zeros, jnp.zeros(x0.shape, jnp.int32),
            jnp.ones(x0.shape, bool))
    _zx, _zy, iters, _alive = jax.lax.fori_loop(0, max_iters, body, init)
    iters_ref[...] = iters
    colour_ref[...] = (iters < max_iters).astype(jnp.int32)


def mandelbrot_pallas(
    x0: jax.Array,
    y0: jax.Array,
    max_iters: int,
    *,
    block_h: int = BLOCK_H,
    block_w: int = BLOCK_W,
    interpret: bool = True,
):
    """x0/y0: [H, W] f32 coordinate grids -> (iterations, colour) i32."""
    H, W = x0.shape
    if H % block_h or W % block_w:
        raise ValueError(
            f"grid {H}x{W} must tile by ({block_h},{block_w}); "
            "use ops.mandelbrot for automatic padding"
        )
    grid = (H // block_h, W // block_w)
    spec = pl.BlockSpec((block_h, block_w), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_mandelbrot_kernel, max_iters=max_iters),
        out_shape=(
            jax.ShapeDtypeStruct((H, W), jnp.int32),
            jax.ShapeDtypeStruct((H, W), jnp.int32),
        ),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        interpret=interpret,
    )(x0, y0)
