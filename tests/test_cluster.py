"""The multi-process cluster transport (repro.cluster), on localhost sockets.

Covers the acceptance path of the real Host-Node-Loader deployment: wire
format, socket channels, membership thresholds, bootstrap across real
subprocesses, demand-driven distribution (straggler bias), node death
mid-job with no lost or duplicated work, and clean UT shutdown with no
orphaned processes.  Everything runs on 127.0.0.1 with ephemeral ports, so
tier-1 stays hermetic.

Work functions are defined *inside* the tests: cloudpickle then ships them
by value over the LOAD frame (the code-loading channel), which also means
the node-loader subprocesses never import this test module (or jax).
"""

import socket
import threading
import time

import pytest

from repro.cluster.membership import DEAD, DONE, Membership
from repro.cluster.netchannels import ChannelClosed, ChannelMux
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    LOAD_WIRE_CHANNEL,
    UT,
    Frame,
    FrameConnection,
    FrameType,
    pack_frame,
    unpack_frame,
)
from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails
from repro.runtime.failures import HeartbeatMonitor

# Fast liveness settings for tests (death detected within ~0.4s).
FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _sum_collect():
    return ResultDetails(name="sum", init=lambda: 0,
                         collect=lambda a, x: a + x)


def _spec(nclusters, workers, n_items, work):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_sum_collect(),
    )


# ---------------------------------------------------------------------------
# wire
# ---------------------------------------------------------------------------


def test_wire_frame_roundtrip_msgpack_and_pickle():
    # plain data -> msgpack codec; frames round-trip exactly
    f = Frame(FrameType.HEARTBEAT, {"node_id": "node0"}, LOAD_WIRE_CHANNEL)
    g = unpack_frame(pack_frame(f))
    assert g.ftype is FrameType.HEARTBEAT
    assert g.payload == {"node_id": "node0"}
    assert g.channel == LOAD_WIRE_CHANNEL

    # tuples are NOT msgpack-safe (would come back as lists) -> pickle codec
    f = Frame(FrameType.WORK, {"id": 3, "obj": (1, 2)}, APP_WIRE_CHANNEL)
    g = unpack_frame(pack_frame(f))
    assert g.payload["obj"] == (1, 2)
    assert isinstance(g.payload["obj"], tuple)

    # functions (shipped code) survive
    f = Frame(FrameType.LOAD, {"function": lambda x: x + 41})
    g = unpack_frame(pack_frame(f))
    assert g.payload["function"](1) == 42

    # ints beyond the msgpack 64-bit range take the pickle path
    g = unpack_frame(pack_frame(Frame(FrameType.RESULT, {"value": 2**70})))
    assert g.payload["value"] == 2**70

    # empty payload + UT
    g = unpack_frame(pack_frame(Frame(FrameType.UT, None)))
    assert g.ftype is FrameType.UT and g.payload is None


def test_wire_rejects_corrupt_header():
    raw = bytearray(pack_frame(Frame(FrameType.WORK_REQUEST, {"node_id": "n"})))
    raw[0:4] = b"XXXX"
    with pytest.raises(ValueError, match="magic"):
        unpack_frame(bytes(raw))


def test_netchannel_mux_blocking_roundtrip_and_close():
    a, b = socket.socketpair()
    left, right = FrameConnection(a), FrameConnection(b)
    mux_l, mux_r = ChannelMux(left), ChannelMux(right)
    ch_l = mux_l.open(APP_WIRE_CHANNEL, FrameType.WORK)
    ch_r = mux_r.open(APP_WIRE_CHANNEL, FrameType.WORK)
    mux_l.start()
    mux_r.start()

    ch_l.put({"id": 0, "obj": 7})
    assert ch_r.get(timeout=5) == {"id": 0, "obj": 7}
    ch_r.put(UT)
    assert ch_l.get(timeout=5) is UT

    mux_r.close()
    with pytest.raises(ChannelClosed):
        ch_l.get(timeout=5)
    mux_l.close()


# ---------------------------------------------------------------------------
# membership
# ---------------------------------------------------------------------------


def test_membership_heartbeat_threshold_declares_death():
    m = Membership(HeartbeatMonitor(interval_s=0.1, misses=3))
    m.register("node0", "127.0.0.1:1", now=0.0)
    m.register("node1", "127.0.0.1:2", now=0.0)
    m.beat("node1", now=0.5)
    # node0 silent for > 0.3s -> dead; node1 beat recently -> alive
    dead = m.reap(now=0.6, at_item=12)
    assert [r.node_id for r in dead] == ["node0"]
    assert m.nodes["node0"].state == DEAD
    assert m.nodes["node1"].alive
    ev = m.failures[0]
    assert ev.kind == "node_loss" and ev.node == 0 and ev.step == 12
    # reap is idempotent; a late beat from the dead node is ignored
    m.beat("node0", now=0.7)
    assert m.reap(now=0.8) == []
    m.mark_done("node1", {"items": 5})
    assert m.finished()


# ---------------------------------------------------------------------------
# the real thing: subprocess clusters on localhost
# ---------------------------------------------------------------------------


def test_cluster_backend_bootstraps_and_completes():
    """ClusterSpec -> backend="cluster" -> >= 2 real subprocesses -> exact
    result, per-node timing returned, clean UT shutdown, no orphans."""

    def work(x):
        return x * x

    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 2, 40, work), backend="cluster", job_timeout=120.0, **FAST
    )
    assert app.run() == sum(i * i for i in range(40))

    # demand-driven totals: every item processed exactly once
    stats = app.host_loader.stats
    assert stats.items_total == 40
    assert stats.redispatched == 0 and stats.deaths_detected == 0

    # both node-loaders were real OS processes and exited cleanly on UT
    assert len(app.processes) == 2
    assert app.orphaned() == []
    assert all(p.returncode == 0 for p in app.processes.values())
    assert all(r.state == DONE
               for r in app.host_loader.membership.nodes.values())

    # requirement 7: nodes returned their (load, run) timing to the host
    by_id = {t.node_id: t for t in builder.timing.nodes}
    assert {"host", "node0", "node1"} <= set(by_id)
    assert by_id["node0"].items + by_id["node1"].items == 40
    assert by_id["node0"].run_ms > 0 and by_id["node1"].run_ms > 0


def test_demand_driven_distribution_biases_against_straggler():
    """An artificially slowed node must receive measurably fewer items — the
    onrl/nrfa protocol only answers *requests*, it never pushes."""

    def work(x):
        time.sleep(0.005)
        return x + 1

    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 1, 40, work), backend="cluster", job_timeout=120.0,
        slowdown={"node1": 0.05}, **FAST
    )
    assert app.run() == sum(i + 1 for i in range(40))
    items = {t.node_id: t.items for t in builder.timing.nodes
             if t.node_id.startswith("node")}
    assert items["node0"] + items["node1"] == 40
    assert items["node1"] < items["node0"], items
    # ~10x slower per item -> well under half the work
    assert items["node1"] <= 40 // 2 - 2, items
    assert app.orphaned() == []


def test_node_death_is_detected_and_work_redispatched():
    """SIGKILL one node-loader mid-job: missed heartbeats declare it dead,
    its in-flight items are re-dispatched, and the survivors finish with no
    item lost or duplicated (the sum is exact)."""

    def work(x):
        time.sleep(0.03)
        return 3 * x

    n_items = 60
    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(3, 1, n_items, work), backend="cluster", job_timeout=120.0,
        **FAST
    )
    runner = app.run_async()
    while app.host_loader is None or app.host_loader.stats.items_total < 5:
        time.sleep(0.02)
        assert runner.is_alive()
    app.kill_node("node1")
    runner.join(timeout=120)
    assert not runner.is_alive(), "cluster hung after node death"

    assert app.result == sum(3 * i for i in range(n_items))
    hl = app.host_loader
    assert hl.stats.deaths_detected == 1
    assert hl.stats.items_total == n_items
    assert hl.stats.duplicates_dropped == 0
    # detection fed the real failure path: a node_loss FailureEvent
    [ev] = hl.membership.failures
    assert ev.kind == "node_loss"
    assert hl.membership.nodes["node1"].state == DEAD
    # survivors shut down cleanly; the killed process is reaped too
    assert app.orphaned() == []
    assert app.processes["node0"].returncode == 0
    assert app.processes["node2"].returncode == 0
    assert app.processes["node1"].returncode != 0


def test_all_nodes_dead_raises_instead_of_hanging():
    def work(x):
        time.sleep(0.05)
        return x

    app = ClusterBuilder().build_application(
        _spec(1, 1, 50, work), backend="cluster", job_timeout=60.0, **FAST
    )
    runner = app.run_async()
    while app.host_loader is None or app.host_loader.stats.items_total < 2:
        time.sleep(0.02)
        assert runner.is_alive()
    app.kill_node("node0")
    runner.join(timeout=60)
    assert not runner.is_alive()
    assert app.result is None
    assert isinstance(app.error, RuntimeError)
    assert "died with work outstanding" in str(app.error)
    assert app.orphaned() == []


def test_work_function_exception_fails_job_with_node_traceback():
    """A raising work function must fail the job promptly (reported by the
    node, raised at the host) — not stall until job_timeout with a silently
    dead worker thread."""

    def work(x):
        if x == 7:
            raise ValueError("item 7 is cursed")
        return x

    app = ClusterBuilder().build_application(
        _spec(2, 1, 20, work), backend="cluster", job_timeout=60.0, **FAST
    )
    runner = app.run_async()
    runner.join(timeout=60)
    assert not runner.is_alive()
    assert app.result is None
    from repro.cluster.host_loader import WorkFunctionError

    assert isinstance(app.error, WorkFunctionError)
    assert "item 7 is cursed" in str(app.error)
    assert app.orphaned() == []


def test_pipelined_dispatch_batches_frames_and_counts_wire_traffic():
    """The credit pipeline must move N items in far fewer than the 3N frames
    of the one-item-per-round-trip protocol (request + work + result each),
    and the host must fold wire counters into the timing collector."""

    def work(x):
        return x + 1

    n_items = 200
    builder = ClusterBuilder()
    app = builder.build_application(
        _spec(2, 2, n_items, work), backend="cluster", job_timeout=120.0,
        flush_items=16, **FAST
    )
    assert app.run() == sum(i + 1 for i in range(n_items))

    stats = app.host_loader.stats
    assert stats.items_total == n_items
    # Items travelled in batches, not one frame each...
    assert stats.work_batches < n_items
    assert stats.result_batches < n_items
    assert stats.max_batch > 1
    # ...and each node issued one explicit windowed request; all other
    # demand piggybacked on result deliveries.
    assert stats.work_requests == 2

    wire_counts = builder.timing.wire
    assert wire_counts["bytes_sent"] > 0 and wire_counts["bytes_recv"] > 0
    assert wire_counts["round_trips"] == (
        stats.work_requests + stats.result_batches
    )
    # The app channel moved well under 2 host-bound frames per item
    # (heartbeats ride the same sockets, so allow them some headroom).
    assert wire_counts["frames_recv"] < 2 * n_items

    # requirement 7 extension: boot is accounted separately from load.
    by_id = {t.node_id: t for t in builder.timing.nodes}
    assert by_id["node0"].boot_ms >= 0.0
    assert by_id["node0"].load_ms > 0.0
    assert app.orphaned() == []


def test_prefetch_zero_gives_strict_per_worker_window():
    """prefetch=0 must be honoured (not clamped): the node buffers exactly
    one item per worker — the pure demand-driven pre-pipelining window."""

    def work(x):
        return x * 2

    app = ClusterBuilder().build_application(
        _spec(1, 2, 30, work), backend="cluster", job_timeout=60.0,
        prefetch=0, **FAST
    )
    assert app.run() == sum(2 * i for i in range(30))
    # window == workers -> the single up-front request asked for exactly 2.
    assert app.host_loader.stats.max_batch <= 2
    assert app.orphaned() == []


def test_unencodable_work_item_fails_job_instead_of_requeue_loop():
    """An item no wire codec can carry must fail the job loudly — not be
    mistaken for a dead pipe and requeued forever (regression)."""
    deep = []
    for _ in range(100_000):
        deep = [deep]

    spec = ClusterSpec.simple(
        host="127.0.0.1", nclusters=1, workers_per_node=1,
        emit_details=EmitDetails(
            name="deep", init=lambda: 0, init_data=(),
            create=lambda s: (None, s) if s else (deep, 1),
        ),
        work_function=lambda x: 0,
        result_details=_sum_collect(),
    )
    app = ClusterBuilder().build_application(
        spec, backend="cluster", job_timeout=60.0, **FAST
    )
    runner = app.run_async()
    runner.join(timeout=60)
    assert not runner.is_alive()
    assert isinstance(app.error, ValueError)
    assert "nested too deeply" in str(app.error)
    assert app.orphaned() == []


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        ClusterBuilder().build_application(
            _spec(1, 1, 1, lambda x: x), backend="mpi"
        )
    with pytest.raises(TypeError, match="options"):
        ClusterBuilder().build_application(
            _spec(1, 1, 1, lambda x: x), backend="threads", port=1234
        )


def test_same_spec_same_result_on_both_backends():
    """Zero user-code changes between threads and processes (§6.1)."""

    def work(x):
        return (x, x * 2)  # tuple payload: exercises the pickle codec path

    def collect(acc, item):
        return acc + item[0] + item[1]

    def make():
        return ClusterSpec.simple(
            host="127.0.0.1", nclusters=2, workers_per_node=2,
            emit_details=_range_emit(30), work_function=work,
            result_details=ResultDetails(name="s", init=lambda: 0,
                                         collect=collect),
        )

    threaded = ClusterBuilder().build_application(make()).run()
    processed = ClusterBuilder().build_application(
        make(), backend="cluster", job_timeout=120.0, **FAST
    ).run()
    assert threaded == processed == sum(3 * i for i in range(30))
