"""Jitted wrapper for the fused RMS-norm kernel (reshape + padding)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.channels import padded_size
from repro.kernels.rmsnorm.kernel import BLOCK_N, rms_norm_pallas
from repro.kernels.rmsnorm.ref import rms_norm_reference


@partial(jax.jit, static_argnames=("eps", "use_pallas", "interpret", "block_n"))
def rms_norm(
    x: jax.Array,  # [..., D]
    scale: jax.Array,  # [D]
    *,
    eps: float = 1e-6,
    use_pallas: bool = True,
    interpret: bool = True,
    block_n: int = BLOCK_N,
):
    if not use_pallas:
        return rms_norm_reference(x.reshape(-1, x.shape[-1]), scale, eps).reshape(
            x.shape
        )
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    N = x2.shape[0]
    bn = min(block_n, padded_size(N, 8))
    Np = padded_size(N, bn)
    if Np != N:
        x2 = jnp.pad(x2, ((0, Np - N), (0, 0)))
    out = rms_norm_pallas(x2, scale, eps=eps, block_n=bn, interpret=interpret)
    return out[:N].reshape(orig_shape)
