"""The Host-Node-Loader (HNL): paper §4 / Figure 1, over real sockets.

Bootstrap sequence (the load network):

1. HNL listens on the configurable "port 2000" and waits for one REGISTER
   frame per expected node (many-to-one input channel — input end created
   before any output end exists, §4's ordering rule).
2. As *each* node registers, the HNL immediately sends it the serialized
   deployment on a LOAD frame — the JCSP *code-loading channel* analogue
   (§4.1).  Early registrants therefore deserialize code and pull in heavy
   imports while stragglers are still connecting, instead of the whole
   cluster idling until the last REGISTER.
3. The application network then runs the demand-driven onrl/nrfa
   client-server protocol model-checked in ``core.verify``, pipelined:
   a WORK_REQUEST carries a *credit count* and the host answers with up to
   that many items in one WORK_BATCH frame; each RESULT_BATCH a node sends
   both delivers results and (piggybacked ``credits``) re-requests that
   many replacement items.  The CSP obligation is unchanged — every demand
   is answered in finite time with items or, once the node's input stream
   is exhausted and nothing is in flight, with UT — the window is just
   wider than one.
4. On UT each node returns its (boot_ms, load_ms, run_ms, items) timing
   record (requirement 7) and the HNL folds results via the user's
   ResultDetails.

Multi-job multiplexing (wire v2): the HNL is a *job dispatcher*, not a
one-shot farm.  All per-farm state — per-stage pending/in-flight/dedup
queues, the emit generator, the collector accumulator — lives in a
:class:`JobState` keyed by the frame-header ``job_id``, so two jobs can
interleave on the same node pool with exactly-once preserved per job.  The
classic one-shot ``run()`` is simply "one pinned job admitted at
construction, dispatch until it completes"; a warm
:class:`~repro.cluster.service.ClusterService` instead constructs the
HostLoader in *pool mode* (``spec=None, pool_nodes=N``) and drives
``serve()`` on a background thread, feeding jobs in through
``submit_job``.  Scheduling is FIFO-with-priority: parked node credits are
answered from the highest-priority admitted job that has (a) pending items
and (b) acked its LOAD on that node (``NodeRecord.jobs_loaded`` — work for
a job never races ahead of its code).

Warm code shipping: each stage function is cloudpickled once per job and
addressed by digest.  The host mirrors every node's code-cache LRU
(``NodeRecord.code_digests``, same capacity and touch order — frames
arrive in send order on one TCP stream), so a resubmission of the same
pipeline ships ``function=None`` and the node rebinds from cache: ~0ms
load on top of the pool's ~0ms boot.

Multi-stage routing (``PipelineSpec``): every one-shot node belongs to one
stage; the host keeps *per-stage* pending/in-flight/dedup state and
answers a node's credits only from its own stage's queue.  A RESULT_BATCH
from a stage-*s* node is deduplicated and its values re-enter the host as
fresh WORK items of stage *s+1* (the final stage folds into the collector)
— the host is the rendezvous between hops, exactly as the chained CSP
model has reducer *s* feeding server *s+1*.  Stage *s*'s input is
exhausted once the emit stream (s = 0) or stage *s-1* (s > 0) has fully
drained, at which point parked credits of stage-*s* nodes are answered
with UT.  Exactly-once holds per stage *per job*: result-id dedup before
forwarding means a redispatched zombie's duplicate can neither
double-collect nor double-forward.  Pool-mode nodes are not pinned — any
node serves any stage of any job (items carry their stage index ``s``).

Beyond the paper: heartbeat liveness (``membership``) — a node-loader that
dies mid-job is detected by missed beats, its in-flight items re-queued and
re-dispatched to surviving nodes (their parked credits answered first), with
result-id dedup guaranteeing no item is lost or double-collected.

Single-threaded protocol core: per-connection reader threads and a ticker
only *enqueue* events; one dispatcher consumes them.  That makes the state
machine deterministic and trivially deadlock-free (no locks around protocol
state).
"""

from __future__ import annotations

import collections
import hashlib
import queue
import socket
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Sequence

from repro.cluster.deploy.base import PlacementPolicy
from repro.cluster.membership import (
    LAUNCHING,
    REPLACED,
    Membership,
    NodeRecord,
)
from repro.cluster import peer as peer_mod
from repro.cluster.telemetry import Telemetry
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    CODE_CACHE_SLOTS,
    LOAD_WIRE_CHANNEL,
    Frame,
    FrameConnection,
    FrameType,
    _buffers_len,
    dumps_code,
    encode_payload,
)
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor, WorkFunctionError

__all__ = ["HostLoader", "HostStats", "JobState", "WorkFunctionError"]


@dataclass
class HostStats:
    items_total: int = 0
    duplicates_dropped: int = 0
    redispatched: int = 0
    deaths_detected: int = 0
    forwarded: int = 0  # stage-s results re-entered as stage-s+1 work items
    # Data-plane counters (credit pipeline).
    work_requests: int = 0  # explicit WORK_REQUEST frames received
    work_batches: int = 0  # WORK_BATCH frames sent
    result_batches: int = 0  # RESULT/RESULT_BATCH frames received
    max_batch: int = 0  # largest WORK_BATCH dispatched
    # Placement-policy counters (deployment layer).
    respawns: int = 0  # launches relaunched elsewhere (bootstrap + heals)
    heals: int = 0  # mid-run deaths answered with a replacement launch
    late_joins: int = 0  # nodes admitted after the run started
    degraded_start: bool = False  # job admitted below full strength
    # Peer data-plane counters (the host demoted to control plane).
    item_acks: int = 0  # ITEM_ACK frames received
    peer_forwarded: int = 0  # hop items shipped node-to-node (acked)
    peer_redispatched: int = 0  # peer-stranded items recomputed upstream
    host_relay_bytes: int = 0  # stage-hop payload bytes relayed via host


class JobState:
    """All farm state of one submitted job, keyed by its wire ``job_id``.

    Exactly the per-stage state the one-shot host kept in run()-local
    variables, plus lifecycle (``done``/``error``/``result``) so service
    callers can wait on a job like a future.  Mutated only by the
    dispatcher thread; ``done`` is the cross-thread completion signal.
    """

    def __init__(self, job_id: int, spec, *, priority: int = 0,
                 pinned: bool = False, timeout: float | None = None,
                 tenant: str = "default",
                 max_inflight: int | None = None):
        if hasattr(spec, "as_pipeline"):
            spec = spec.as_pipeline()
        spec.validate()
        self.job_id = job_id
        self.spec = spec
        self.priority = priority
        self.pinned = pinned  # one-shot mode: nodes serve their own stage
        self.timeout = timeout
        # Multi-tenant metering (the gateway's fairness knobs): all jobs of
        # one tenant share a host-dispatched in-flight item budget — the
        # dispatch path (_answer) stops drawing for the tenant at the cap,
        # so a wide job cannot monopolise node credits.
        self.tenant = tenant
        self.max_inflight = max_inflight
        self.S = len(spec.stages)
        S = self.S
        details = spec.emit.e_details
        self._details = details
        self.emit_state = details.initial_state()
        self.emit_done = False
        # Item ids are per-stage (a stage-s result forwarded to stage s+1
        # gets a fresh id in s+1's id space), so dedup and loss accounting
        # stay local to one hop.
        self.next_id = [0] * S
        self.pending: list[collections.deque] = [collections.deque()
                                                 for _ in range(S)]
        self.inflight: list[dict[int, tuple[str, Any]]] = [{}
                                                           for _ in range(S)]
        self.done_ids: list[set[int]] = [set() for _ in range(S)]
        # Peer-routed hops (the receiving stage's ``route="peer"`` knob):
        # source stage -> {"key_fn": ...}.  On such a hop the host only
        # *ledgers* the transfer: an ITEM_ACK moves the item into
        # ``peer_inflight[s+1]``, keyed by the stage-s result id and
        # holding (target node, input object, input stage).  The input is
        # the LAST one the host actually saw for this item — on a chain of
        # consecutive peer hops the intermediate results never transit the
        # host, so a dead target's item is recomputed from that stage
        # (``input stage``), not necessarily from ``s``.
        self.peer_hops: dict[int, dict] = (
            spec.peer_routed_hops()
            if hasattr(spec, "peer_routed_hops") else {}
        )
        self.peer_inflight: list[dict[int, tuple[str, Any, int]]] = [
            {} for _ in range(S)]
        # Chained-hop acks race: consecutive peer hops are acked by
        # *different* nodes over independent sockets, so hop s+1's ack can
        # arrive before hop s's has created the ``peer_inflight[s+1]``
        # entry it must advance.  Such an early ack parks here as
        # (s, result id) -> (acking node, target node) and is applied the
        # moment the predecessor's ack lands (dropped if the item is
        # requeued first).
        self.parked_acks: dict[tuple[int, int], tuple[str, str]] = {}
        # WORK_BATCH send time per (stage, item id): the item-latency
        # histogram observes completion-minus-dispatch.
        self.dispatch_ts: dict[tuple[int, int], float] = {}
        self.r_details = spec.collector.r_details
        self.acc = self.r_details.init()
        # Shipped code, one (digest, cloudpickle blob) per stage: pickled
        # once per job, addressed by digest for the warm-cache LRU.
        self.stage_code: list[tuple[str, bytes]] = []
        for st in spec.stages:
            blob = dumps_code(st.function)
            self.stage_code.append((hashlib.sha256(blob).hexdigest(), blob))
        # Lifecycle.
        self.done = threading.Event()
        self.error: BaseException | None = None
        self.result: Any = None
        self.deadline: float | None = None
        self.submitted_at: float | None = None
        self.first_result_at: float | None = None
        self.ended_at: float | None = None
        # Failure attribution for the retry history: which node the fatal
        # error surfaced on (if any) and a coarse cause classification
        # ("work_function" | "timeout" | "node_loss" | "internal").
        self.failed_node: str | None = None
        self.failure_kind: str | None = None
        self.items_collected = 0
        # Warm-load accounting (per job, summed over nodes).
        self.code_shipped = 0
        self.code_cached = 0
        # Per-job observability counters the telemetry gauges report; the
        # per-node splits let JobHandle.stats() attribute work and cache
        # behaviour to individual pool members.
        self.duplicates_dropped = 0
        self.forwarded = 0
        self.peer_forwarded = 0
        self.host_relay_bytes = 0
        self.items_by_node: dict[str, int] = {}
        self.cache_by_node: dict[str, dict[str, int]] = {}

    # -- farm state machine -------------------------------------------------

    def input_exhausted(self, s: int) -> bool:
        """Stage ``s`` will receive no further input items."""
        if s == 0:
            return self.emit_done
        return (self.input_exhausted(s - 1) and not self.pending[s - 1]
                and not self.inflight[s - 1]
                and not self.peer_inflight[s - 1])

    def stage_done(self, s: int) -> bool:
        return (self.input_exhausted(s) and not self.pending[s]
                and not self.inflight[s] and not self.peer_inflight[s])

    def next_item(self, s: int):
        if self.pending[s]:
            return self.pending[s].popleft()
        if s == 0 and not self.emit_done:
            obj, self.emit_state = self._details.create(self.emit_state)
            if obj is None:
                self.emit_done = True
                return None
            item = (self.next_id[0], obj)
            self.next_id[0] += 1
            return item
        return None  # upstream hasn't produced (or is exhausted)

    @property
    def active(self) -> bool:
        return not self.done.is_set()


class HostLoader:
    """Runs the host side of a node pool serving one or many jobs.

    Two construction modes share one dispatcher:

    * **one-shot** (the classic API): ``HostLoader(spec, ...)`` — the spec
      becomes a *pinned* primary job admitted immediately; ``run()``
      dispatches until it completes and returns the final result, sending
      UT to each node as its stage drains.
    * **pool** (the service): ``HostLoader(None, pool_nodes=N,
      pool_workers=W, ...)`` — no job at boot; ``serve(stop)`` dispatches
      jobs fed in via ``submit_job`` until ``stop`` is set, and nodes are
      never UT'd on drain (credits park between jobs).
    """

    def __init__(
        self,
        spec=None,
        timing: TimingCollector | None = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat: HeartbeatMonitor | None = None,
        register_timeout: float = 30.0,
        job_timeout: float | None = None,
        slowdown: dict[str, float] | None = None,
        artifacts: dict[str, bytes] | None = None,
        prefetch: int | None = None,
        flush_items: int = 8,
        flush_interval: float = 0.005,
        placement: PlacementPolicy | None = None,
        expected_nodes: Sequence[str] | None = None,
        relaunch: Callable[[str, str], bool] | None = None,
        pool_nodes: int | None = None,
        pool_workers: int = 1,
        telemetry: Telemetry | None = None,
        conn_wrapper: Callable[[FrameConnection], Any] | None = None,
    ):
        if spec is not None:
            if hasattr(spec, "as_pipeline"):
                spec = spec.as_pipeline()
            spec.validate()
            self.stages = spec.stages
            self._stage_by_node = dict(spec.node_assignments())
            total = spec.total_nodes
        else:
            if pool_nodes is None:
                raise TypeError(
                    "pool mode (spec=None) requires pool_nodes=<count>"
                )
            self.stages = []
            self._stage_by_node = {}
            total = pool_nodes
        self.spec = spec
        self.pool_workers = pool_workers
        self.total_nodes = total
        self.timing = timing or TimingCollector()
        self.host = host
        self.membership = Membership(heartbeat or HeartbeatMonitor())
        self.register_timeout = register_timeout
        self.placement = placement or PlacementPolicy()
        self.placement.validate(total)
        # Launch announcements: expected node ids become LAUNCHING records
        # at start(), which is what arms respawn tracking and late join.
        self.expected_nodes = list(expected_nodes or [])
        # Deployment-layer callback: relaunch(old_node_id, new_node_id) ->
        # bool, provided by the application so the barrier can respawn a
        # silent launch — and the reaper heal a mid-run death — without
        # knowing what a launcher is.
        self.relaunch = relaunch
        self._heals_used = 0
        # Chaos hook: every accepted connection is passed through this
        # wrapper (identity when None) before its reader thread starts, so
        # a fault layer sees every frame of every node.
        self.conn_wrapper = conn_wrapper
        self.job_timeout = job_timeout
        self.slowdown = dict(slowdown or {})
        self.artifacts = dict(artifacts or {})
        self.prefetch = prefetch
        self.flush_items = flush_items
        self.flush_interval = flush_interval
        self.stats = HostStats()
        self.result: Any = None
        # Broadcast blocks: named read-only payloads published once on the
        # host; nodes stripe the initial chunk fetches across themselves
        # and then trade chunks peer-to-peer (~1 host copy total).
        self.blocks = peer_mod.BlockRegistry()

        # Telemetry: lifecycle events and slow gauges are *pushed* from the
        # dispatcher at state changes; fast-moving values the host already
        # maintains (wire counters, parked credits, HostStats) are *pulled*
        # at snapshot time through the samplers — the hot paths pay nothing.
        self.telemetry = telemetry or Telemetry()
        self.telemetry.set_sampler("nodes", self._sample_nodes)
        self.telemetry.set_sampler("cluster", self._sample_cluster)
        self.telemetry.set_sampler("timing", self.timing.summary)
        self.membership.on_transition = self._on_node_transition

        # Job table.  Written by the dispatcher (admission/completion) and
        # by __init__ (the primary job); submit_job only allocates ids.
        self._jobs: dict[int, JobState] = {}
        self._job_seq = 0
        self._job_lock = threading.Lock()
        self._primary: JobState | None = None
        if spec is not None:
            self._primary = self._new_job(spec, pinned=True)
            self._jobs[self._primary.job_id] = self._primary
        self.pool_ready = threading.Event()
        self.serve_error: BaseException | None = None

        self._events: queue.Queue = queue.Queue()
        self._early_events: list = []  # app frames arriving mid-bootstrap
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(total + 4)
        self.port = self._listener.getsockname()[1]
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    # -- job admission ------------------------------------------------------

    def _new_job(self, spec, *, pinned: bool, priority: int = 0,
                 timeout: float | None = None, tenant: str = "default",
                 max_inflight: int | None = None) -> JobState:
        with self._job_lock:
            self._job_seq += 1
            jid = self._job_seq
        return JobState(jid, spec, priority=priority, pinned=pinned,
                        timeout=timeout, tenant=tenant,
                        max_inflight=max_inflight)

    def submit_job(self, spec, *, priority: int = 0,
                   timeout: float | None = None, tenant: str = "default",
                   max_inflight: int | None = None) -> JobState:
        """Queue one job for the dispatcher (service mode).

        Returns its :class:`JobState` — wait on ``.done``, then read
        ``.result`` / ``.error``.  Higher ``priority`` jobs are answered
        first when nodes demand work; ties dispatch FIFO (job id order).
        ``tenant``/``max_inflight`` meter the dispatch path per tenant
        (see :class:`JobState`); the gateway sets them, direct service
        users normally leave the defaults.
        """
        job = self._new_job(spec, pinned=False, priority=priority,
                            timeout=timeout, tenant=tenant,
                            max_inflight=max_inflight)
        job.submitted_at = time.monotonic()
        self.telemetry.inc("jobs_submitted")
        self.telemetry.emit("job_submit", job=job.job_id,
                            priority=priority, tenant=tenant, stages=job.S)
        self._events.put(("submit", job))
        return job

    def expect_nodes(self, node_ids: Sequence[str]) -> None:
        """Announce launches after boot (the service's ``grow()`` path):
        membership is single-writer, so the records are created on the
        dispatcher thread.  Queued before ``launcher.launch`` is called,
        so the LAUNCHING record always precedes its REGISTER."""
        self._events.put(("expect", list(node_ids)))

    def retract_nodes(self, node_ids: Sequence[str]) -> None:
        """Withdraw launch announcements whose ``launcher.launch`` failed
        (the service's ``grow()`` error path): a LAUNCHING record with no
        process behind it would otherwise count as capacity on its way
        forever — suppressing autoscale scale-ups and keeping stages
        eligible in ``_check_liveness``."""
        self._events.put(("retract", list(node_ids)))

    def retire_node(self, node_id: str) -> None:
        """Gracefully retire one pool node (the service's ``shrink()``
        path): the dispatcher stops feeding it, sends UT — the node drains
        its queue, flushes, returns its timing record and exits — and any
        items still in flight host-side are requeued on UT ack exactly as
        a death would, minus the death.  Refused (no-op) for the last
        live node."""
        self._events.put(("retire", node_id))

    def _admit(self, job: JobState) -> None:
        self._jobs[job.job_id] = job
        if job.timeout is not None:
            job.deadline = time.monotonic() + job.timeout
        self.telemetry.emit("job_admit", job=job.job_id)
        self._publish_job(job)
        for rec in self.membership.nodes.values():
            if rec.alive:
                self._send_load(rec, job)

    def _sources(self, rec: NodeRecord) -> Iterator[tuple[JobState, int]]:
        """(job, stage) queues this node may draw from, scheduling order:
        priority first, then admission order; within a job, later stages
        first (drain the pipeline before widening it).  A job is skipped
        until this node acked its LOAD — work never races ahead of code."""
        jobs = sorted(
            (j for j in self._jobs.values() if j.active and j.error is None),
            key=lambda j: (-j.priority, j.job_id),
        )
        for job in jobs:
            if job.job_id not in rec.jobs_loaded:
                continue
            if job.pinned:
                yield job, self._stage_of(rec.node_id)
            else:
                for s in range(job.S - 1, -1, -1):
                    yield job, s

    # -- bootstrap ----------------------------------------------------------

    def start(self) -> None:
        """Open the load network (accept + ticker threads)."""
        for node_id in self.expected_nodes:
            self.membership.expect(node_id)
        for fn, name in ((self._accept_loop, "hnl-accept"),
                         (self._tick_loop, "hnl-ticker")):
            t = threading.Thread(target=fn, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            if self.conn_wrapper is not None:
                conn = self.conn_wrapper(conn)
            t = threading.Thread(
                target=self._conn_reader, args=(conn, f"{addr[0]}:{addr[1]}"),
                name=f"hnl-reader-{addr[1]}", daemon=True,
            )
            t.start()
            self._threads.append(t)

    def _conn_reader(self, conn: FrameConnection, addr: str) -> None:
        node_id = None
        try:
            first = conn.recv()
            if first.ftype is not FrameType.REGISTER:
                conn.close()
                return
            node_id = first.payload["node_id"]
            self._events.put(("register", node_id, addr, conn, first.payload))
            while True:
                frame = conn.recv()
                self._events.put(("frame", node_id, frame))
        except (ConnectionError, OSError, ValueError):
            if node_id is not None:
                self._events.put(("disconnect", node_id))

    def _tick_loop(self) -> None:
        interval = self.membership.monitor.interval_s / 2
        while not self._stop.wait(interval):
            self._events.put(("tick",))

    # -- entry points -------------------------------------------------------

    def run(self) -> Any:
        """One-shot: bootstrap, dispatch the primary job to completion,
        return its final result (the classic emit/cluster/collect farm)."""
        job = self._primary
        if job is None:
            raise RuntimeError(
                "pool-mode HostLoader has no primary job; use serve() + "
                "submit_job()"
            )
        with self.timing.phase("host", "load"):
            self._await_registrations()
        # Every member is known now: ship the complete peer directory (the
        # per-registration LOADs carried partial ones).
        self._broadcast_peer_dir()
        # Demand that raced the bootstrap (an early node finishing its LOAD
        # while stragglers registered) re-enters the event stream here.
        for ev in self._early_events:
            self._events.put(ev)
        self._early_events.clear()
        job.submitted_at = time.monotonic()
        self.telemetry.inc("jobs_submitted")
        self.telemetry.emit("job_submit", job=job.job_id,
                            priority=job.priority, stages=job.S)
        self._publish_job(job)
        if self.job_timeout is not None:
            job.deadline = job.submitted_at + self.job_timeout
        with self.timing.phase("host", "run"):
            self._dispatch(until_job=job)
        self._collect_wire_stats()
        self.result = job.result
        return self.result

    def serve(self, stop: threading.Event) -> None:
        """Pool mode: bootstrap, then dispatch submitted jobs until ``stop``.

        Run on a background thread by :class:`ClusterService`; bootstrap
        failures land in ``serve_error`` (with ``pool_ready`` set so the
        caller unblocks), and any job still active at shutdown is failed
        rather than left hanging.
        """
        try:
            with self.timing.phase("host", "load"):
                self._await_registrations()
        except BaseException as exc:
            self.serve_error = exc
            self.pool_ready.set()
            return
        self._broadcast_peer_dir()
        for ev in self._early_events:
            self._events.put(ev)
        self._early_events.clear()
        self.telemetry.emit("pool_ready",
                            nodes=self.membership.arrived_count())
        self.pool_ready.set()
        try:
            with self.timing.phase("host", "run"):
                self._dispatch(stop=stop)
        except BaseException as exc:  # dispatcher bug or unroutable failure
            self.serve_error = exc
        finally:
            for job in list(self._jobs.values()):
                if job.active:
                    self._fail_job(job, self.serve_error
                                   or RuntimeError("cluster service stopped"))
            self._collect_wire_stats()

    # -- the dispatcher -----------------------------------------------------

    def _dispatch(self, until_job: JobState | None = None,
                  stop: threading.Event | None = None) -> None:
        interval = self.membership.monitor.interval_s
        while True:
            if until_job is not None:
                if until_job.error is not None:
                    raise until_job.error
                if until_job.done.is_set() and self.membership.finished():
                    break
            if stop is not None and stop.is_set():
                return
            now = time.monotonic()
            for job in [j for j in self._jobs.values() if j.active]:
                # Zero-item jobs (and jobs drained by parked-credit answers)
                # complete here rather than waiting for a RESULT_BATCH.
                self._maybe_finish(job)
                if job.active and job.deadline is not None \
                        and now > job.deadline:
                    self._fail_job(job, TimeoutError(
                        f"cluster job exceeded "
                        f"{job.timeout or self.job_timeout}s "
                        f"(done={job.items_collected}, "
                        f"inflight={[len(f) for f in job.inflight]}, "
                        f"membership:\n{self.membership.describe()})"
                    ))
            try:
                event = self._events.get(timeout=interval)
            except queue.Empty:
                continue
            kind = event[0]
            if kind == "frame":
                _, node_id, frame = event
                if frame.ftype is FrameType.WORK_REQUEST:
                    self.stats.work_requests += 1
                    p = frame.payload or {}
                    self._answer(node_id, int(p.get("credits", 1)))
                elif frame.ftype is FrameType.RESULT_BATCH:
                    p = frame.payload
                    self._collect_results(
                        node_id, frame.job_id, p["results"],
                        int(p.get("credits", 0)),
                    )
                elif frame.ftype is FrameType.RESULT:
                    # Legacy single-result form (one frame per item).
                    self._collect_results(node_id, frame.job_id,
                                          [frame.payload], 0)
                elif frame.ftype is FrameType.ITEM_ACK:
                    p = frame.payload or {}
                    self._peer_acks(node_id, frame.job_id,
                                    p.get("acks") or [],
                                    int(p.get("credits", 0)))
                elif frame.ftype is FrameType.HEARTBEAT:
                    self.membership.beat(node_id)
                    rep = (frame.payload or {}).get("report")
                    if rep:
                        # Node-side phase/cache counters piggybacked on the
                        # beat (kept as the slow fallback channel).
                        self.telemetry.set_node(node_id, report=rep)
                elif frame.ftype is FrameType.REPORT:
                    # Off-beat telemetry push: gauges track completions as
                    # they happen instead of lagging one heartbeat.  NOT a
                    # liveness beat — death detection stays on the dedicated
                    # heartbeat path, so a node whose beacon died (or is
                    # chaos-stalled) is still reaped even while its data
                    # path keeps reporting.
                    rep = (frame.payload or {}).get("report")
                    if rep:
                        self.telemetry.set_node(node_id, report=rep)
                elif frame.ftype is FrameType.BLOCK_REQUEST:
                    self._serve_block(node_id, frame.payload or {})
                elif frame.ftype is FrameType.UT:
                    self._node_finished(node_id, frame.payload)
            elif kind == "loaded":
                # A LOAD send completing (bootstrap straggler or a per-job
                # ship): parked credits may be answerable now.
                self._apply_load_result(*event[1:])
                self._flush_waiting()
            elif kind == "tick":
                self._reap()
            elif kind == "disconnect":
                # The socket died; death itself is declared by the
                # heartbeat threshold (reap), keeping one detection path.
                pass
            elif kind == "register":
                # Late join: a node registering after the run started is
                # shipped LOAD immediately (the per-registration LOAD
                # path always supported this — the membership barrier
                # was what blocked it) and its first WORK_REQUEST is
                # answered with items or, if the stream already drained,
                # with UT.  Exactly-once is untouched: result-id dedup
                # never depended on when a node joined.
                _, node_id, addr, conn, payload = event
                # An *expected* arrival — an announced launch (a degraded
                # start's straggler, a bootstrap respawn, a mid-run heal)
                # registering late — is admitted even when elastic late
                # join is disabled: the policy gates strangers, not
                # capacity the host itself asked for.
                prior = self.membership.nodes.get(node_id)
                expected = (prior is not None
                            and prior.state in (LAUNCHING, REPLACED))
                if not expected and not self.placement.allow_late_join:
                    conn.close()
                    continue
                try:
                    rec = self.membership.register(
                        node_id, addr,
                        cores=int(payload.get("cores", 1)),
                        pid=int(payload.get("pid", 0)),
                        conn=conn,
                        peer_port=int(payload.get("peer_port", 0)),
                    )
                except ValueError:
                    conn.close()  # duplicate of a live member
                    continue
                self.stats.late_joins += 1
                self.telemetry.emit("late_join", node=node_id, address=addr,
                                    expected=expected)
                if self._primary is not None:
                    self._send_load(rec, self._primary)
                else:
                    self._send_load(rec, None)  # pool config first
                    for job in self._jobs.values():
                        if job.active:
                            self._send_load(rec, job)
                # The pool's routing peers must learn the newcomer (and it
                # the pool) or peer hops route around it forever.
                self._broadcast_peer_dir()
            elif kind == "blocks":
                self._broadcast_blocks()
            elif kind == "submit":
                self._admit(event[1])
            elif kind == "expect":
                # Pool growth: announce the launches so their REGISTERs
                # take the *expected*-arrival path (admitted even with
                # elastic late join disabled).
                for node_id in event[1]:
                    if node_id not in self.membership.nodes:
                        self.membership.expect(node_id)
            elif kind == "retract":
                # A grow() launch failed after its announcement: clear
                # the phantom record (the loop-end _check_liveness then
                # fails fast any job it was the last hope of).
                for node_id in event[1]:
                    self.membership.retract(node_id)
            elif kind == "retire":
                self._retire(event[1])
            self._check_liveness()

    # -- data plane ---------------------------------------------------------

    def _send_batch(self, rec: NodeRecord, job: JobState, batch: list,
                    s: int) -> bool:
        try:
            rec.conn.send(Frame(
                FrameType.WORK_BATCH,
                {"items": [{"id": i, "obj": o, "s": s} for i, o in batch]},
                APP_WIRE_CHANNEL,
                job_id=job.job_id,
            ))
        except OSError:
            # Never lose an item on a dead pipe: all of them go back to
            # the front of the queue; the node itself is reaped shortly.
            for item in reversed(batch):
                job.pending[s].appendleft(item)
            return False
        except ValueError as exc:
            # Encode errors (unencodable/oversized payload) are a *user
            # payload* problem, not a node death — requeueing would loop
            # forever, so they fail the job (one-shot run() re-raises).
            self._fail_job(job, exc)
            return False
        now = time.monotonic()
        for item_id, obj in batch:
            job.inflight[s][item_id] = (rec.node_id, obj)
            job.dispatch_ts[(s, item_id)] = now
        self.stats.work_batches += 1
        self.stats.max_batch = max(self.stats.max_batch, len(batch))
        self._publish_job(job)
        return True

    def _send_ut(self, node_id: str) -> None:
        rec = self.membership.nodes[node_id]
        try:
            rec.conn.send(Frame(FrameType.UT, None, APP_WIRE_CHANNEL))
        except (OSError, ValueError):
            pass

    def _retire(self, node_id: str) -> None:
        """Graceful pool shrink (dispatcher thread — membership stays
        single-writer).  The node is fenced first (``retiring`` stops
        ``_answer`` feeding it) so no WORK_BATCH can race past the UT;
        its in-flight items come back via the UT-ack requeue."""
        rec = self.membership.nodes.get(node_id)
        live = [r for r in self.membership.nodes.values()
                if r.alive and not r.retiring]
        if rec is None or not rec.alive or rec.retiring or len(live) <= 1:
            self.telemetry.emit("scale_down_skipped", node=node_id,
                                live=len(live))
            return
        rec.retiring = True
        rec.credits = 0
        self._send_ut(node_id)
        self.telemetry.inc("scale_down_events")
        self.telemetry.emit("scale_down", node=node_id,
                            pool=len(live) - 1)

    def _tenant_room(self, job: JobState,
                     used: dict[str, int]) -> int | None:
        """Remaining host-dispatched in-flight budget of this job's tenant
        (None = uncapped).  ``used`` memoizes per-_answer-call totals and
        accumulates the items drawn during the call."""
        if job.max_inflight is None:
            return None
        tenant = job.tenant
        if tenant not in used:
            used[tenant] = sum(
                sum(len(f) for f in j.inflight)
                for j in self._jobs.values()
                if j.active and j.tenant == tenant
            )
        return max(0, job.max_inflight - used[tenant])

    def _stage_room(self, job: JobState, s: int, rec: NodeRecord) -> int | None:
        """Per-stage prefetch cap on a *pool* node (None = uncapped): the
        per-stage ``prefetch=`` knob used to bind only on pinned one-shot
        deployments (where the node's whole window is one stage); on a
        shared pool it becomes a host-side admission cap — at most
        ``pool_workers + prefetch`` of this (job, stage)'s items in flight
        per node."""
        if job.pinned:
            return None  # resolved node-side via the LOAD window
        st = job.spec.stages[s]
        if st.prefetch is None:
            return None
        cap = self.pool_workers + max(0, int(st.prefetch))
        held = sum(1 for nid, _ in job.inflight[s].values()
                   if nid == rec.node_id)
        return max(0, cap - held)

    def _answer(self, node_id: str, credits: int) -> None:
        """Answer demand (the onrl server obligation), up to ``credits`` +
        any previously parked credits, drawn from the node's eligible
        (job, stage) queues in scheduling order — one WORK_BATCH per job
        touched.  Two admission caps can shrink a draw below the credit
        window: the tenant in-flight budget (gateway fairness) and the
        per-stage prefetch cap (pool jobs)."""
        rec = self.membership.nodes.get(node_id)
        if rec is None or not rec.alive or rec.retiring:
            return
        want = credits + rec.credits
        rec.credits = 0
        if want <= 0:
            return
        sent = 0
        tenant_used: dict[str, int] = {}
        for job, s in self._sources(rec):
            limit = want - sent
            room = self._tenant_room(job, tenant_used)
            if room is not None:
                limit = min(limit, room)
            stage_room = self._stage_room(job, s, rec)
            if stage_room is not None:
                limit = min(limit, stage_room)
            batch = []
            while len(batch) < limit:
                item = job.next_item(s)
                if item is None:
                    break
                batch.append(item)
            if not batch:
                continue
            if not self._send_batch(rec, job, batch, s):
                return  # dead pipe (items requeued) or job failed on encode
            if job.max_inflight is not None:
                tenant_used[job.tenant] += len(batch)
            sent += len(batch)
            if sent >= want:
                break
        leftover = want - sent
        if leftover:
            primary = self._primary
            if (primary is not None and primary.error is None
                    and primary.stage_done(self._stage_of(node_id))):
                # One-shot: this node's stage drained — it is owed UT.
                self._send_ut(node_id)
            else:
                rec.credits = leftover  # parked until items (re)appear

    def _flush_waiting(self) -> None:
        for rec in list(self.membership.nodes.values()):
            if rec.alive and rec.credits > 0:
                self._answer(rec.node_id, 0)

    # -- peer control plane --------------------------------------------------

    def _peer_acks(self, node_id: str, job_id: int, acks: list,
                   credits: int) -> None:
        """A stage-s node shipped results directly to stage-s+1 peers and
        acked the ids: advance the exactly-once ledger without the values.

        Each acked item moves into ``peer_inflight[s+1]`` — from
        ``inflight[s]`` when its stage-s input was host-dispatched, or
        from ``peer_inflight[s]`` when the input itself arrived over a
        peer edge (two consecutive ``route="peer"`` hops).  The ledger
        entry carries the last input the host saw and its stage, so a
        death of the target re-computes the item from that stage.
        Credits piggyback exactly as on a RESULT_BATCH (the sender
        already excluded peer-delivered inputs, which never consumed a
        window slot).
        """
        self.stats.item_acks += 1
        job = self._jobs.get(job_id)
        if job is None or job.error is not None:
            if credits:
                self._answer(node_id, credits)
            return
        for a in acks:
            s = int(a.get("s", 0))
            rid = a.get("id")
            target = a.get("to")
            if not 0 <= s < job.S - 1:
                continue  # malformed: the last stage has no peer hop
            self._apply_peer_ack(job, node_id, s, rid, target)
        self._publish_job(job)
        if credits:
            self._answer(node_id, credits)
        self._flush_waiting()
        self._maybe_finish(job)

    def _apply_peer_ack(self, job: JobState, node_id: str, s: int,
                        rid: int, target: str) -> None:
        """Advance the exactly-once ledger for one acked hop s -> s+1.

        Called for each ack on arrival, and again for a *parked* ack the
        moment its predecessor hop creates the ledger entry it advances
        (consecutive hops are acked by different nodes over independent
        sockets, so chained acks can arrive out of order — processing
        hop s+1's ack before hop s's would otherwise drop it as stale
        and leak the ledger entry, stalling termination forever)."""
        entry = job.inflight[s].pop(rid, None)
        # Chained peer hop: the stage-s input was itself delivered by
        # a peer, so the live ledger entry sits in peer_inflight[s].
        pentry = (job.peer_inflight[s].pop(rid, None)
                  if entry is None else None)
        t0 = job.dispatch_ts.pop((s, rid), None)
        if t0 is not None:
            self.telemetry.observe(
                "item_latency_ms", (time.monotonic() - t0) * 1e3)
        if rid in job.done_ids[s]:
            self.stats.duplicates_dropped += 1
            job.duplicates_dropped += 1
            return
        if entry is None and pentry is None:
            if s > 0 and (s - 1) in job.peer_hops:
                # Chained-hop ack race: this hop's ack beat the previous
                # hop's, so the entry it must advance does not exist yet.
                # Park it for the predecessor's arrival.
                job.parked_acks[(s, rid)] = (node_id, target)
                return
            # A stale ack: the host already requeued this item (its
            # first peer target died) — the requeued copy is
            # authoritative, and marking this one done would lose it.
            return
        if entry is not None:
            _, input_obj = entry
            in_s = s  # the host dispatched stage s's input itself
        else:
            _, input_obj, in_s = pentry
        trec = self.membership.nodes.get(target) if target else None
        if rid not in job.done_ids[s + 1] and (
                trec is None or not trec.alive):
            # Ack-after-death race: the copy was shipped into a node
            # the host has already reaped (so _requeue_node_items
            # never saw this ledger entry) and nothing downstream
            # delivered it — it is lost.  Recompute from the last
            # stage the host holds an input for, exactly as the
            # stranded-ledger path does; the done marks of the
            # replayed hops must lift or dedup would eat the redo.
            for t in range(in_s, s):
                job.done_ids[t].discard(rid)
            self._drop_parked_acks(job, rid)
            job.pending[in_s].append((rid, input_obj))
            self.stats.redispatched += 1
            self.stats.peer_redispatched += 1
            return
        job.done_ids[s].add(rid)
        # Result-before-ack race: the target may have computed and
        # delivered the forwarded item before this ack arrived (two
        # independent TCP streams).  Ledger it only if stage s+1 has
        # not already completed it, or it would sit in peer_inflight
        # forever and stall termination.
        if rid not in job.done_ids[s + 1]:
            job.peer_inflight[s + 1][rid] = (target, input_obj, in_s)
        self.stats.forwarded += 1
        self.stats.peer_forwarded += 1
        job.forwarded += 1
        job.peer_forwarded += 1
        job.items_by_node[node_id] = \
            job.items_by_node.get(node_id, 0) + 1
        rec = self.membership.nodes.get(node_id)
        if rec is not None:
            rec.items_done += 1
        self.timing.count_item(node_id)
        # A parked successor ack was waiting for exactly the ledger
        # entry created above: apply it now, same as if it had just
        # arrived (cascades down chains of any length).
        parked = job.parked_acks.pop((s + 1, rid), None)
        if parked is not None and rid in job.peer_inflight[s + 1]:
            p_node, p_target = parked
            self._apply_peer_ack(job, p_node, s + 1, rid, p_target)

    def _drop_parked_acks(self, job: JobState, rid: int) -> None:
        """An item is being requeued for recompute: acks parked by its
        now-abandoned downstream copies must never apply to the replay."""
        for key in [k for k in job.parked_acks if k[1] == rid]:
            del job.parked_acks[key]

    def _peer_dir(self) -> dict[str, tuple[str, int]]:
        """node_id -> (ip, peer data-plane port) for every routable member
        (a node that reported no peer port is simply unreachable for peer
        traffic and omitted — its results fall back through the host)."""
        out: dict[str, tuple[str, int]] = {}
        for rec in self.membership.nodes.values():
            if not rec.alive or not rec.peer_port:
                continue
            # The observed address is "ip:port"; split from the RIGHT and
            # strip any brackets so an IPv6 ip ("::1:54321", "[::1]:54321")
            # survives — a left split would truncate it to "" and silently
            # demote every peer edge to host relay.
            ip = "127.0.0.1"
            if rec.address:
                ip = rec.address.rsplit(":", 1)[0].strip("[]") or ip
            out[rec.node_id] = (ip, rec.peer_port)
        return out

    def _peer_routes(self, job: JobState | None) -> dict:
        """Host-assigned routing table for one job's peer hops: for each
        source stage the ordered target list (stage-s+1 capacity), the
        partition mode, and the serialized key function for keyed
        shuffles.  Pool jobs route over every routable member (any node
        serves any stage); pinned one-shot jobs route to the nodes
        assigned to the receiving stage."""
        if job is None or not job.peer_hops:
            return {}
        directory = self._peer_dir()
        routes: dict[str, dict] = {}
        for s, cfg in sorted(job.peer_hops.items()):
            if job.pinned:
                targets = [nid for nid, st in job.spec.node_assignments()
                           if st == s + 1 and nid in directory]
            else:
                targets = [nid for nid in directory]
            key_fn = cfg.get("key_fn")
            routes[str(s)] = {
                "targets": targets,
                "mode": "keyed" if key_fn is not None else "rr",
                "key_fn": (dumps_code(key_fn)
                           if key_fn is not None else None),
            }
        return routes

    def _broadcast_peer_dir(self) -> None:
        """Ship the complete peer directory to every live node (a LOAD
        with no ``workers`` key is a refresh, not a deployment).  Called
        after the membership barrier and on every late join/heal — the
        per-registration LOADs only carried the directory known so far."""
        directory = self._peer_dir()
        if not directory:
            return
        payload = {"peer": {"dir": directory, "routes": {}}}
        for rec in self.membership.nodes.values():
            if not rec.alive or rec.conn is None:
                continue
            try:
                rec.conn.send(Frame(FrameType.LOAD, payload,
                                    LOAD_WIRE_CHANNEL))
            except (OSError, ValueError):
                pass

    def _broadcast_blocks(self) -> None:
        """Push the block manifest to every live node so striped fetches
        start now rather than on the next job LOAD."""
        manifest = self.blocks.manifest()
        if not manifest:
            return
        payload = {"blocks": manifest, "peer": {"dir": self._peer_dir(),
                                                "routes": {}}}
        for rec in self.membership.nodes.values():
            if not rec.alive or rec.conn is None:
                continue
            try:
                rec.conn.send(Frame(FrameType.LOAD, payload,
                                    LOAD_WIRE_CHANNEL))
            except (OSError, ValueError):
                pass

    def _serve_block(self, node_id: str, p: dict) -> None:
        """Answer one striped BLOCK_REQUEST with its chunk (data=None on a
        miss — the node retries from peers or re-requests later)."""
        rec = self.membership.nodes.get(node_id)
        if rec is None or rec.conn is None:
            return
        name = p.get("name")
        idx = int(p.get("chunk", 0))
        data = self.blocks.get_chunk(name, idx)
        if data is not None:
            self.telemetry.observe("block_chunk_bytes", len(data))
        try:
            rec.conn.send(Frame(
                FrameType.BLOCK_CHUNK,
                {"name": name, "chunk": idx, "data": data},
                LOAD_WIRE_CHANNEL,
            ))
        except (OSError, ValueError):
            pass

    def publish_block(self, name: str, data: bytes) -> str:
        """Publish a named read-only payload for the whole pool; returns
        its digest.  Registration is synchronous (any thread); the
        manifest broadcast rides the event queue so socket writes stay on
        the dispatcher."""
        digest = self.blocks.publish(name, data)
        self._events.put(("blocks",))
        return digest

    def _items_collected(self) -> int:
        if self._primary is not None:
            return self._primary.items_collected
        return sum(j.items_collected for j in self._jobs.values())

    def _reap(self, now: float | None = None) -> None:
        newly_dead = self.membership.reap(now, at_item=self._items_collected())
        for rec in newly_dead:
            self._on_node_death(rec)
        if newly_dead:
            self._flush_waiting()

    def _on_node_death(self, rec: NodeRecord) -> None:
        """One detected mid-run death: surface it on the bus with its
        detection metadata, requeue the node's in-flight items, and — if
        the policy grants a heal — relaunch a replacement."""
        self.stats.deaths_detected += 1
        ev = rec.last_failure
        self.telemetry.inc("failures_detected")
        self.telemetry.emit(
            "failure",
            failure=ev.kind if ev else "node_loss",
            node=rec.node_id,
            node_index=rec.index,
            detect_latency_ms=(round(ev.detect_latency_s * 1e3, 3)
                               if ev else None),
            at_item=ev.step if ev else None,
        )
        self._requeue_node_items(rec.node_id)
        self._heal(rec)

    def _requeue_node_items(self, node_id: str) -> bool:
        """Requeue every item a departed node can no longer deliver.

        Host-dispatched in-flight items re-enter their own stage's queue.
        Peer-shipped items stranded on the node are *recomputed* upstream:
        the ledger holds the last input the host saw (on a chain of
        consecutive peer hops that can be several stages back), so the
        replayed hops' result ids are un-done and the item re-dispatched
        at the input's stage under the same id — the dedup sets absorb
        any racing late delivery from the first computation.
        """
        requeued = False
        for job in self._jobs.values():
            if not job.active:
                continue
            for s in range(job.S):
                lost = [iid for iid, (nid, _) in job.inflight[s].items()
                        if nid == node_id]
                for iid in lost:
                    _, obj = job.inflight[s].pop(iid)
                    self._drop_parked_acks(job, iid)
                    job.pending[s].append((iid, obj))
                    self.stats.redispatched += 1
                    requeued = True
                stranded = [rid for rid, (nid, _, _)
                            in job.peer_inflight[s].items()
                            if nid == node_id]
                for rid in stranded:
                    _, obj, in_s = job.peer_inflight[s].pop(rid)
                    for t in range(in_s, s):
                        job.done_ids[t].discard(rid)
                    self._drop_parked_acks(job, rid)
                    job.pending[in_s].append((rid, obj))
                    self.stats.redispatched += 1
                    self.stats.peer_redispatched += 1
                    requeued = True
        return requeued

    def _heal(self, rec: NodeRecord) -> bool:
        """Mid-run pool healing: answer a death with a fresh launch through
        the same ``relaunch`` path the bootstrap respawn uses.

        The replacement is announced (LAUNCHING) and registers through the
        dispatcher like any expected straggler — LOAD (warm code cache
        re-shipped), credits armed by its first WORK_REQUEST — completing
        the dead → launching → registered transition chain.  Budgeted by
        ``PlacementPolicy.max_heals`` (0 = historical shrink-to-survivors).
        """
        if (self.relaunch is None or self._stop.is_set()
                or self._heals_used >= self.placement.max_heals):
            return False
        attempts = rec.attempts + 1
        new_id = f"{rec.node_id}r{attempts}"
        while new_id in self.membership.nodes:  # bootstrap respawn took it
            attempts += 1
            new_id = f"{rec.node_id}r{attempts}"
        try:
            ok = self.relaunch(rec.node_id, new_id)
        except Exception:
            ok = False
        if not ok:
            self.telemetry.emit("heal_failed", node=rec.node_id,
                                replacement=new_id)
            return False
        nrec = self.membership.expect(new_id)
        nrec.attempts = attempts
        self._heals_used += 1
        self.stats.heals += 1
        self.stats.respawns += 1
        self.telemetry.inc("heals")
        self.telemetry.emit("heal", node=rec.node_id, replacement=new_id,
                            heals_used=self._heals_used,
                            heals_budget=self.placement.max_heals)
        return True

    def _collect_results(self, node_id: str, job_id: int, results: list,
                         credits: int) -> None:
        self.stats.result_batches += 1
        job = self._jobs.get(job_id)
        if job is None or job.error is not None:
            # A zombie batch for a torn-down/failed job: the results are
            # moot but the credits still replenish the node's window.
            if credits:
                self._answer(node_id, credits)
            return
        self.telemetry.observe("result_batch_items", len(results))
        for p in results:
            s = int(p.get("s", 0))
            if "error" in p:
                self._fail_job(job, WorkFunctionError(
                    f"work function raised on {node_id} for item "
                    f"{p['id']}: {p['error']}\n"
                    f"{p.get('traceback', '')}"
                ), node=node_id)
                break
            # Always clear inflight — a redispatched item can complete
            # twice (zombie result + survivor result) and both entries
            # must go or termination stalls.  Peer-delivered items live in
            # the peer ledger instead.
            job.inflight[s].pop(p["id"], None)
            job.peer_inflight[s].pop(p["id"], None)
            t0 = job.dispatch_ts.pop((s, p["id"]), None)
            if t0 is not None:
                self.telemetry.observe(
                    "item_latency_ms", (time.monotonic() - t0) * 1e3)
            if p["id"] in job.done_ids[s]:
                self.stats.duplicates_dropped += 1
                job.duplicates_dropped += 1
            else:
                job.done_ids[s].add(p["id"])
                if s + 1 < job.S:
                    # Any payload passing through here rode the host for
                    # its stage hop — on a peer hop that only happens in
                    # degraded relay (every peer target unreachable), on a
                    # host-routed hop it is the normal path.  Either way
                    # the bytes are the traffic the peer plane exists to
                    # absorb, so both count toward host_relay_bytes.
                    _, bufs = encode_payload(p["value"])
                    nbytes = _buffers_len(bufs)
                    job.host_relay_bytes += nbytes
                    self.stats.host_relay_bytes += nbytes
                    if s in job.peer_hops:
                        # Keep the result-id space so host-relayed and
                        # peer-shipped copies of one item dedup against each
                        # other at stage s+1.
                        job.pending[s + 1].append((p["id"], p["value"]))
                    else:
                        # The hop rendezvous: this result *is* stage s+1's
                        # next work item (dedup above makes it exactly
                        # once).
                        job.pending[s + 1].append((job.next_id[s + 1],
                                                   p["value"]))
                        job.next_id[s + 1] += 1
                    self.stats.forwarded += 1
                    job.forwarded += 1
                else:
                    job.acc = job.r_details.collect(job.acc, p["value"])
                    job.items_collected += 1
                    if job.first_result_at is None:
                        job.first_result_at = time.monotonic()
                    self.stats.items_total += 1
                job.items_by_node[node_id] = \
                    job.items_by_node.get(node_id, 0) + 1
                rec = self.membership.nodes[node_id]
                rec.items_done += 1
                self.timing.count_item(node_id)
        self._publish_job(job)
        if credits:
            self._answer(node_id, credits)
        # Forwarded items may satisfy parked downstream demand, and a
        # stage draining may owe its nodes UT: both are answered here.
        self._flush_waiting()
        self._maybe_finish(job)

    # -- job lifecycle ------------------------------------------------------

    def _maybe_finish(self, job: JobState) -> None:
        if not job.active or job.error is not None:
            return
        if not job.stage_done(job.S - 1):
            return
        job.result = job.r_details.finalise(job.acc)
        job.ended_at = time.monotonic()
        self.telemetry.inc("jobs_completed")
        elapsed_ms = None
        if job.submitted_at is not None:
            elapsed_ms = round((job.ended_at - job.submitted_at) * 1e3, 3)
        self.telemetry.emit("job_done", job=job.job_id,
                            items=job.items_collected, elapsed_ms=elapsed_ms)
        self._publish_job(job)
        # Publish the terminal gauges *before* releasing waiters: a caller
        # snapshotting /metrics the instant result() returns must already
        # see done=True.
        job.done.set()
        if not job.pinned:
            self._send_job_close(job)

    def _fail_job(self, job: JobState, exc: BaseException, *,
                  node: str | None = None, kind: str | None = None) -> None:
        if job.done.is_set():
            return
        job.error = exc
        job.ended_at = time.monotonic()
        if node is not None:
            job.failed_node = node
        if kind is None:
            if isinstance(exc, WorkFunctionError):
                kind = "work_function"
            elif isinstance(exc, TimeoutError):
                kind = "timeout"
            else:
                kind = "internal"
        job.failure_kind = kind
        self.telemetry.inc("jobs_failed")
        self.telemetry.emit("job_failed", job=job.job_id, error=str(exc),
                            cause=kind, node=job.failed_node)
        self._publish_job(job)
        # As in _maybe_finish: gauges first, then release waiters.
        job.done.set()
        # Aborted/timed-out jobs must tear down on *every* error path —
        # pinned included — or nodes keep stale bindings (and keep
        # computing a window of items for a job nobody will collect).
        self._send_job_close(job)

    def _send_job_close(self, job: JobState) -> None:
        """Per-job teardown: nodes drop the job's bindings (warm code cache
        entries survive) and their credits stay pooled for the next job.

        Sent to *every* live node, not just those that acked the job's
        LOAD: a node whose LOAD is still in flight when the job dies would
        otherwise bind a dead job and hold it forever (the close for an
        unknown job is a no-op node-side, so over-sending is harmless).
        """
        for rec in self.membership.nodes.values():
            rec.jobs_loaded.discard(job.job_id)
            if not rec.alive or rec.conn is None:
                continue
            try:
                rec.conn.send(Frame(FrameType.JOB_CLOSE,
                                    {"job_id": job.job_id},
                                    APP_WIRE_CHANNEL, job_id=job.job_id))
            except (OSError, ValueError):
                pass

    def _check_liveness(self) -> None:
        """A job with obligations left but no eligible live nodes can never
        finish — fail it fast instead of idling to its deadline.  LAUNCHING
        members keep a stage eligible: a degraded start's straggler (or a
        respawned launch) may still register and carry the stage — but only
        within ``register_timeout`` of its announcement; a launch silent
        longer than the boot barrier would wait is a phantom (the process
        died pre-REGISTER) and must not hold jobs open forever."""
        now = time.monotonic()
        for job in [j for j in self._jobs.values() if j.active]:
            failed = False
            for s in range(job.S):
                if job.stage_done(s):
                    continue
                if job.pinned:
                    members = [rec for rec in self.membership.nodes.values()
                               if self._stage_of(rec.node_id) == s]
                else:
                    members = list(self.membership.nodes.values())
                if any(rec.alive
                       or (rec.state == LAUNCHING
                           and now - rec.state_changed_at
                               < self.register_timeout)
                       for rec in members):
                    continue
                self._fail_job(job, RuntimeError(
                    f"all node-loaders of stage {job.spec.stages[s].name!r} "
                    f"died with work outstanding ({len(job.inflight[s])} "
                    f"in flight, {len(job.pending[s])} queued; no launch "
                    "pending)"
                ))
                failed = True
                break
            if failed:
                continue

    def _stage_of(self, node_id: str) -> int:
        """Stage index of a one-shot node (respawn replacements via their
        base id; unknown elastic joiners default to stage 0)."""
        s = self._stage_by_node.get(node_id)
        if s is not None:
            return s
        base = node_id.split("r", 1)[0]
        return self._stage_by_node.get(base, 0)

    # -- bootstrap helpers --------------------------------------------------

    def _await_registrations(self) -> None:
        """The membership barrier, driven by the placement policy.

        Strict mode (the default policy) reproduces the seed behaviour:
        block until all ``nclusters`` launches registered or raise at
        ``register_timeout``.  The policy relaxes it three ways:

        * *respawn-on-silent-node* — an announced launch quiet past its
          ``respawn_after`` window is retired (REPLACED) and relaunched
          elsewhere through the deployment layer's ``relaunch`` callback,
          up to ``max_respawns`` times cluster-wide;
        * *degraded start* — at the timeout the job is admitted with the
          survivors if at least ``min_nodes`` arrived, instead of raising;
          the missing stragglers stay LAUNCHING and may still late-join;
        * a launch arriving *during* the barrier under a REPLACED id is
          re-admitted (membership handles the transition) — first
          registration wins, extra capacity is never turned away.
        """
        pol = self.placement
        expected = self.total_nodes
        min_nodes = expected if pol.min_nodes is None else pol.min_nodes
        respawn_after = pol.respawn_after
        if respawn_after is None:
            respawn_after = self.register_timeout / (pol.max_respawns + 1)
        respawns_left = pol.max_respawns
        t0 = time.monotonic()
        deadline = t0 + self.register_timeout
        # The silence clock starts *now*: launch announcements were stamped
        # at start(), before the launcher's prepare() (possibly a slow code
        # sync to many machines) and the sequential launch() calls — judging
        # silence from that stamp would respawn healthy just-launched nodes.
        for rec in self.membership.launching_nodes():
            rec.launched_at = t0
        while self.membership.arrived_count() < expected:
            now = time.monotonic()
            next_respawn_due: float | None = None
            if self.relaunch is not None and respawns_left > 0:
                for rec in self.membership.launching_nodes():
                    if respawns_left <= 0:
                        break
                    due = rec.launched_at + respawn_after
                    if now >= due:
                        if self._respawn(rec):
                            respawns_left -= 1
                    elif next_respawn_due is None or due < next_respawn_due:
                        next_respawn_due = due
            if now >= deadline:
                arrived = self.membership.arrived_count()
                if arrived >= min_nodes:
                    # Degraded start: the survivors carry the job; the
                    # demand-driven protocol needs no topology change.
                    self.stats.degraded_start = arrived < expected
                    if self.stats.degraded_start:
                        self.telemetry.emit("degraded_start",
                                            arrived=arrived,
                                            expected=expected)
                    return
                raise TimeoutError(
                    f"only {arrived}/{expected} node-loaders registered "
                    f"within {self.register_timeout}s (min_nodes="
                    f"{min_nodes}, respawns used="
                    f"{pol.max_respawns - respawns_left})"
                )
            timeout = deadline - now
            if next_respawn_due is not None:
                timeout = min(timeout, next_respawn_due - now)
            try:
                event = self._events.get(timeout=max(0.01, timeout))
            except queue.Empty:
                continue
            if event[0] == "loaded":
                self._apply_load_result(*event[1:])
                continue
            if event[0] == "frame":
                # Early heartbeats (nodes beat from REGISTER onwards) must
                # count, or a node registering early could be declared dead
                # while the stragglers are still connecting.  Other early
                # frames (a loaded node's first WORK_REQUEST) are replayed
                # into the dispatcher once bootstrap completes.
                _, node_id, frame = event
                if frame.ftype in (FrameType.HEARTBEAT, FrameType.REPORT):
                    if frame.ftype is FrameType.HEARTBEAT:
                        self.membership.beat(node_id)
                    rep = (frame.payload or {}).get("report")
                    if rep:
                        self.telemetry.set_node(node_id, report=rep)
                elif frame.ftype is FrameType.BLOCK_REQUEST:
                    # A fast-booting node striping pre-published blocks
                    # while stragglers still register.
                    self._serve_block(node_id, frame.payload or {})
                else:
                    self._early_events.append(event)
                continue
            if event[0] == "submit":
                # A service job submitted before the pool finished booting:
                # admission happens in the dispatcher, after the barrier.
                self._early_events.append(event)
                continue
            if event[0] != "register":
                continue  # pre-bootstrap noise
            _, node_id, addr, conn, payload = event
            try:
                rec = self.membership.register(
                    node_id, addr,
                    cores=int(payload.get("cores", 1)),
                    pid=int(payload.get("pid", 0)),
                    conn=conn,
                    peer_port=int(payload.get("peer_port", 0)),
                )
            except ValueError:
                conn.close()  # duplicate node_id: reject it, keep waiting
                continue
            # Overlapped load: ship code the moment a node shows up, so its
            # deserialization/imports run while stragglers still register.
            self._send_load(rec, self._primary)

    def _respawn(self, rec: NodeRecord) -> bool:
        """Retire a silent launch and start a replacement elsewhere."""
        new_id = f"{rec.node_id}r{rec.attempts + 1}"
        try:
            ok = self.relaunch(rec.node_id, new_id)
        except Exception:
            ok = False
        if not ok:
            # Could not place a replacement: re-arm the silence window so
            # the original keeps its chance instead of burning the budget
            # in a tight loop.
            rec.launched_at = time.monotonic()
            return False
        self.membership.replace(rec.node_id)
        nrec = self.membership.expect(new_id)
        nrec.attempts = rec.attempts + 1
        self.stats.respawns += 1
        self.telemetry.emit("respawn", node=rec.node_id, replacement=new_id)
        return True

    # -- code shipping ------------------------------------------------------

    def _load_entries(self, rec: NodeRecord, job: JobState) -> list[dict]:
        """Per-stage LOAD entries for one node, consulting (and updating)
        the host's mirror of its code-cache LRU: a digest the node still
        holds ships ``function=None`` (the warm-resubmit fast path)."""
        if job.pinned:
            s_list = [self._stage_of(rec.node_id)]
        else:
            s_list = list(range(job.S))
        entries = []
        cache = job.cache_by_node.setdefault(rec.node_id,
                                             {"hits": 0, "misses": 0})
        for s in s_list:
            digest, blob = job.stage_code[s]
            if digest in rec.code_digests:
                rec.code_digests.move_to_end(digest)
                fn_blob = None
                job.code_cached += 1
                cache["hits"] += 1
            else:
                rec.code_digests[digest] = None
                while len(rec.code_digests) > CODE_CACHE_SLOTS:
                    rec.code_digests.popitem(last=False)
                fn_blob = blob
                job.code_shipped += 1
                cache["misses"] += 1
            entry = {"s": s, "stage": job.spec.stages[s].name,
                     "digest": digest, "function": fn_blob}
            # Per-stage data-plane knobs for *pool* jobs ride the job's
            # LOAD entries instead of the host-global pool config: the
            # node tightens its flush cadence per job (min over bound
            # stages), the host caps per-stage in-flight items per node
            # (_stage_room) — pinned one-shot deployments keep resolving
            # them into the node-global window/flush as before.
            if not job.pinned:
                st = job.spec.stages[s]
                if st.flush_ms is not None:
                    entry["flush_ms"] = float(st.flush_ms)
                if st.prefetch is not None:
                    entry["prefetch"] = int(st.prefetch)
            entries.append(entry)
        return entries

    def _send_load(self, rec: NodeRecord, job: JobState | None) -> None:
        """Ship a deployment (pool config and/or one job's stages) to one
        node from a dedicated sender thread.

        A node booting heavy deps drains its socket only once its preloader
        finishes; a large LOAD (MBs of artifacts) would therefore block a
        synchronous send past the kernel buffer — and block the dispatcher
        with it, re-serializing the very bootstrap the overlap parallelizes.
        The payload is built *here* (dispatcher thread — it touches job and
        LRU state); the sender thread only sends, reporting back through
        the event queue (``("loaded", node_id, ok, job_id)``) so membership
        stays single-writer.
        """
        if job is not None:
            entries = self._load_entries(rec, job)
        else:
            entries = []
        # Per-stage data-plane knobs resolve host-side: a pinned node's
        # single stage may override the cluster-wide prefetch/flush values.
        prefetch, flush_interval = self.prefetch, self.flush_interval
        if job is not None and job.pinned:
            st = job.spec.stages[self._stage_of(rec.node_id)]
            workers = st.workers_per_node
            if st.prefetch is not None:
                prefetch = st.prefetch
            if st.flush_ms is not None:
                flush_interval = st.flush_ms / 1000.0
        else:
            workers = self.pool_workers
        job_id = 0 if job is None else job.job_id
        payload = {
            "node_id": rec.node_id,
            "workers": workers,
            "heartbeat_interval": self.membership.monitor.interval_s,
            "slowdown": float(self.slowdown.get(rec.node_id, 0.0)),
            "artifacts": self.artifacts,
            "prefetch": prefetch,
            "flush_items": self.flush_items,
            "flush_interval": flush_interval,
            "stages": entries,
            # Peer data plane: the directory known so far (completed by the
            # post-barrier broadcast) and, per peer-routed hop, this job's
            # routing table.  Published broadcast blocks ride along so the
            # node starts its striped fetch during the load window.
            "peer": {"dir": self._peer_dir(),
                     "routes": self._peer_routes(job)},
        }
        manifest = self.blocks.manifest()
        if manifest:
            payload["blocks"] = manifest

        def sender() -> None:
            try:
                rec.conn.send(Frame(FrameType.LOAD, payload,
                                    LOAD_WIRE_CHANNEL, job_id=job_id))
            except Exception:
                # Dead pipe or an unserializable deployment: either way the
                # node can never load — report it so it is marked dead
                # (unloadable everywhere -> "all node-loaders died") rather
                # than leaving the job to idle until job_timeout.
                self._events.put(("loaded", rec.node_id, False, job_id))
                return
            self._events.put(("loaded", rec.node_id, True, job_id))

        t = threading.Thread(target=sender, name=f"hnl-load-{rec.node_id}",
                             daemon=True)
        t.start()
        self._threads.append(t)

    def _apply_load_result(self, node_id: str, ok: bool,
                           job_id: int = 0) -> None:
        rec = self.membership.nodes.get(node_id)
        if ok:
            if rec is not None and rec.alive:  # never resurrect a reaped node
                self.membership.mark_loaded(node_id)
                job = self._jobs.get(job_id)
                if job is not None and not job.active:
                    # The job ended while its LOAD was in flight: close it
                    # on this node immediately instead of binding a corpse.
                    try:
                        rec.conn.send(Frame(FrameType.JOB_CLOSE,
                                            {"job_id": job_id},
                                            APP_WIRE_CHANNEL, job_id=job_id))
                    except (OSError, ValueError):
                        pass
                else:
                    rec.jobs_loaded.add(job_id)
            return
        # Died between REGISTER and LOAD: a bootstrap-time node loss,
        # handled like any other — requeue + surface + (policy permitting)
        # heal, exactly as a heartbeat-detected death.
        if self.membership.mark_dead(node_id) is not None:
            self._on_node_death(rec)
            self._flush_waiting()

    def _node_finished(self, node_id: str, payload: Any) -> None:
        timing = payload or {}
        self.membership.mark_done(node_id, timing)
        self.timing.add(node_id, "boot", float(timing.get("boot_ms", 0.0)))
        self.timing.add(node_id, "load", float(timing.get("load_ms", 0.0)))
        self.timing.add(node_id, "run", float(timing.get("run_ms", 0.0)))
        self.telemetry.emit("node_done", node=node_id,
                            items=int(timing.get("items", 0)))
        # A node retiring with jobs still active (it hit a decode error, or
        # its host-side channel died under it) will never deliver results
        # for its in-flight items — requeue them exactly as a death does,
        # or the job stalls to its deadline.
        if self._requeue_node_items(node_id):
            self._flush_waiting()

    def _collect_wire_stats(self) -> None:
        """Fold per-connection traffic counters + protocol counters into the
        timing collector (reported by benchmarks/run.py)."""
        agg = {"bytes_sent": 0, "bytes_recv": 0,
               "frames_sent": 0, "frames_recv": 0}
        for rec in self.membership.nodes.values():
            if rec.conn is None:
                continue
            for key, val in rec.conn.counters.as_dict().items():
                agg[key] += val
        agg["work_requests"] = self.stats.work_requests
        agg["work_batches"] = self.stats.work_batches
        agg["result_batches"] = self.stats.result_batches
        agg["max_batch"] = self.stats.max_batch
        # One round-trip = one host-bound demand frame (explicit request or
        # piggybacked result batch) plus its answer.
        agg["round_trips"] = self.stats.work_requests + self.stats.result_batches
        self.timing.add_wire(**agg)

    # -- telemetry ----------------------------------------------------------

    def _on_node_transition(self, rec: NodeRecord, old: str) -> None:
        """Membership hook (dispatcher thread): every node state change
        becomes one bus event plus a node gauge update."""
        self.telemetry.emit("membership", node=rec.node_id, state=rec.state,
                            prev=old)
        self.telemetry.set_node(rec.node_id, state=rec.state)

    def _publish_job(self, job: JobState) -> None:
        """Push one job's farm gauges (dispatcher thread, per state change /
        batch — never per item)."""
        self.telemetry.set_job(
            job.job_id,
            priority=job.priority,
            stages=job.S,
            pending=[len(q) for q in job.pending],
            inflight=[len(f) for f in job.inflight],
            items_collected=job.items_collected,
            duplicates_dropped=job.duplicates_dropped,
            forwarded=job.forwarded,
            peer_forwarded=job.peer_forwarded,
            host_relay_bytes=job.host_relay_bytes,
            code_shipped=job.code_shipped,
            code_cached=job.code_cached,
            # ended_at, not the event: terminal publishes happen just
            # before done.set() releases waiters (see _maybe_finish).
            done=job.ended_at is not None,
            error=None if job.error is None else str(job.error),
        )

    def _sample_nodes(self) -> dict:
        """Pull-side node fields, read on the snapshot caller's thread.

        The dispatcher mutates ``membership.nodes`` (and each record)
        concurrently; rather than lock the protocol hot path, dict
        iteration simply retries on RuntimeError — the values are
        monotonic-enough counters where a midway-consistent read is fine
        for reporting.
        """
        for _ in range(8):
            try:
                out = {}
                for rec in list(self.membership.nodes.values()):
                    fields = {
                        "state": rec.state,
                        "address": rec.address,
                        "items": rec.items_done,
                        "credits": rec.credits,
                        "beats": rec.beats,
                        "attempts": rec.attempts,
                        "state_changed_at": round(rec.state_changed_at, 6),
                        "transitions": [
                            {"state": s, "at": round(at, 6)}
                            for s, at in list(rec.transitions)[-8:]
                        ],
                    }
                    if rec.conn is not None:
                        fields["wire"] = rec.conn.counters.as_dict()
                    out[rec.node_id] = fields
                return out
            except RuntimeError:
                continue
        return {}

    def _sample_cluster(self) -> dict:
        """Pull-side cluster counters: the HostStats the dispatcher already
        maintains, plus liveness/credit aggregates."""
        out = dict(vars(self.stats))
        for _ in range(8):
            try:
                nodes = list(self.membership.nodes.values())
                jobs = list(self._jobs.values())
                break
            except RuntimeError:
                continue
        else:
            return out
        out["nodes_total"] = len(nodes)
        out["nodes_alive"] = sum(1 for r in nodes if r.alive)
        out["credits_parked"] = sum(r.credits for r in nodes if r.alive)
        out["jobs_active"] = sum(1 for j in jobs if j.active)
        out["blocks_published"] = len(self.blocks.manifest())
        out["block_chunks_served"] = self.blocks.chunks_served
        out["block_bytes_served"] = self.blocks.chunk_bytes_served
        return out

    # -- teardown -----------------------------------------------------------

    def _member_snapshot(self) -> list[NodeRecord]:
        """Cross-thread membership snapshot for teardown paths: the
        dispatcher may still be inserting records (a queued ``expect``)
        while the closing thread walks them, and dict iteration during a
        resize raises RuntimeError."""
        for _ in range(8):
            try:
                return list(self.membership.nodes.values())
            except RuntimeError:
                continue
        return []

    def shutdown_nodes(self) -> None:
        """Send UT to every live node (pool teardown — they exit cleanly)."""
        for rec in self._member_snapshot():
            if rec.alive and rec.conn is not None:
                try:
                    rec.conn.send(Frame(FrameType.UT, None, APP_WIRE_CHANNEL))
                except (OSError, ValueError):
                    pass

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for rec in self._member_snapshot():
            if rec.conn is not None:
                rec.conn.close()
        self.telemetry.close()  # flush the trace; the bus itself stays readable
