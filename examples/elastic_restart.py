"""Fault-tolerance & elasticity demo (paper requirement 4: redeploy on a
different set of workstations with no user changes).

Runs in a subprocess with 8 forced host devices: trains on a 4-node x 2-chip
mesh, loses node 3 at step 5, elastically re-meshes onto the survivors,
restores the checkpoint against the new shardings, finishes training.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import subprocess
import sys
import textwrap

CODE = """
import logging, tempfile, dataclasses
logging.basicConfig(level=logging.WARNING, format="%(levelname)s %(message)s")
import jax, jax.numpy as jnp
from repro.configs.registry import get_config
from repro.configs.base import ShapeConfig
from repro.runtime.executor import Trainer, TrainerConfig
from repro.runtime.elastic import ElasticController
from repro.runtime.failures import FailurePlan, FailureEvent
from repro.optim.adamw import AdamWConfig

cfg = dataclasses.replace(get_config('yi-9b').smoke(), compute_dtype='float32')
shape = ShapeConfig('t', seq_len=32, global_batch=8, kind='train')
elastic = ElasticController(model_axis=2, devices_per_node=1,
                            shape_kind='train')
mesh, rules = elastic.build(elastic.available_nodes())
print('initial mesh:', dict(mesh.shape), '->', len(jax.devices()), 'devices')
with tempfile.TemporaryDirectory() as d:
    tr = Trainer(cfg, shape,
                 TrainerConfig(num_steps=12, checkpoint_every=2,
                               checkpoint_dir=d, warmup_steps=1, tp=2),
                 opt_cfg=AdamWConfig(), rules=rules, mesh=mesh,
                 failure_plan=FailurePlan([
                     FailureEvent(step=5, kind='node_loss', node=3)]),
                 elastic=elastic)
    out = tr.run()
print('post-failure mesh:', dict(tr.mesh.shape))
print('restarts:', out['restarts'], ' final step:', out['final_step'])
print('last loss: %.4f' % out['last_metrics']['loss'])
print(out['timing'])
"""


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(CODE)],
                         env=env, text=True, capture_output=True)
    print(out.stdout)
    if out.returncode != 0:
        print(out.stderr[-3000:])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
