"""The Node-Loader (NL): the identical executable every worker machine runs.

Paper §4: the user starts *one* NodeLoader per node — it knows only the
host's load address ("ip:2000/1"); everything else (code, topology, worker
count) arrives over the load network.  Mirroring that:

    python -m repro.cluster.node_loader --host 127.0.0.1 --port <p>

Lifecycle (timed per requirement 7, split three ways):

1. *boot*: connect + REGISTER (node id, cores, pid) on the load channel
   while a background thread pre-imports heavy dependencies named on the
   command line (``--preload jax.numpy``) — the environment cost of the
   workstation, accounted separately from code distribution.  The dial
   retries with exponential backoff inside ``--connect-timeout``: a
   remotely launched node may come up before the host is listening;
2. *load*: receive LOAD frames — the deployment payload (work functions
   shipped by value over the code-loading channel; optional AOT-serialized
   executables land in :data:`ARTIFACTS`).  The first LOAD configures the
   node (worker count, credit window, flush cadence) and starts the
   workers; every LOAD binds its job's stage functions.  Deserialization is
   deferred until the preloader finishes so shipped-code imports hit a warm
   module cache instead of serializing on the import lock inside the load
   window;
3. *run*: the node-local Figure-2 fragment, pipelined.  The nrfa client
   keeps a *window* of ``workers + prefetch`` items resident: one initial
   WORK_REQUEST carries ``credits=window``, the host answers with
   WORK_BATCH frames, and every RESULT_BATCH the flusher sends piggybacks
   ``credits=len(results)`` — each completed item frees a window slot, so
   demand travels with delivery and workers never idle on a round-trip.
   Results coalesce in small per-job buffers flushed on a threshold or a
   few-ms interval instead of one frame + one syscall per item;
4. on UT: flood workers with UT, join them, return
   (boot_ms, load_ms, run_ms, items) to the host in a final UT frame,
   exit 0.

Warm multi-job service (wire v2): the node is long-lived.  Work items
arrive tagged with the frame-header ``job_id`` and their stage index
``s``; the worker dispatches through a ``(job_id, s) -> function`` table
so two jobs interleave on one worker pool.  Stage functions are addressed
by digest and kept in a bounded LRU (:data:`CODE_CACHE_SLOTS` entries):
when the host re-ships a stage this node already holds, the LOAD entry
carries ``function=None`` and the node rebinds from cache — a warm
resubmit pays neither boot nor code transfer.  JOB_CLOSE drops one job's
bindings (the cache survives — that *is* the warmth); UT still terminates
the node itself.

This module must import without jax — a node-loader on a fresh workstation
is a bare bootstrap; the shipped code pulls in its own dependencies when
deserialized (or earlier, via ``--preload``).
"""

from __future__ import annotations

import argparse
import collections
import importlib
import os
import queue
import random
import socket
import threading
import time
import traceback
from typing import Any, Callable, Sequence

from repro.cluster import peer as peer_mod
from repro.cluster.netchannels import ChannelClosed
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    CODE_CACHE_SLOTS,
    DEFAULT_HEARTBEAT_S,
    LOAD_WIRE_CHANNEL,
    UT,
    Frame,
    FrameConnection,
    FrameType,
    loads_code,
)

# Minimum spacing of unsolicited REPORT frames: enough for live gauges to
# track batch completion instead of lagging one heartbeat, small enough to
# stay invisible next to the result traffic itself.
REPORT_MIN_INTERVAL_S = 0.05

# Peer-delivered items a node holds locally (queued for workers + parked
# for a late stage binding) before its peer-serve readers stop draining
# their sockets.  Host-dispatched work is bounded by the credit window;
# this is the peer plane's equivalent bound — once full, the reader
# blocks, the kernel buffers fill, and TCP throttles the upstream sender
# instead of this node's queue growing without bound.
PEER_INTAKE_MAX_ITEMS = 256

# AOT-serialized executables shipped in the LOAD payload, keyed by name.
# Work functions may read these (e.g. deserialize_and_load a compiled step).
ARTIFACTS: dict[str, bytes] = {}


def connect_with_retry(host: str, port: int, timeout: float = 30.0, *,
                       max_delay: float = 2.0, jitter: float = 0.5,
                       _sleep: Callable[[float], None] = time.sleep,
                       _rng: Any = None) -> socket.socket:
    """Dial the host, retrying with exponential backoff until ``timeout``.

    On a real network the start order is uncontrolled: an ssh-launched
    node-loader routinely comes up before the host binds its load port (or
    while the host is still syncing code to other machines).  Dying on the
    first ECONNREFUSED would turn every such race into a lost workstation;
    instead the node keeps dialling — 0.2s, 0.4s, ... capped at
    ``max_delay`` between attempts — and only gives up once the whole
    window is spent.

    Each pause is scaled by a uniform draw from ``[1 - jitter, 1]`` so a
    mass (re)spawn — every node of a healed or freshly fanned-out pool
    dialling the same listener — decorrelates instead of hammering the
    accept queue in lockstep (the thundering herd).  ``_sleep``/``_rng``
    are test seams.
    """
    deadline = time.monotonic() + timeout
    delay = 0.2
    rng = random if _rng is None else _rng
    while True:
        remaining = deadline - time.monotonic()
        try:
            return socket.create_connection(
                (host, port), timeout=max(0.2, min(5.0, remaining))
            )
        except OSError as exc:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise ConnectionError(
                    f"could not reach host-node-loader at {host}:{port} "
                    f"within {timeout}s: {exc}"
                ) from exc
            pause = min(delay, remaining)
            if jitter > 0:
                pause *= rng.uniform(max(0.0, 1.0 - jitter), 1.0)
            _sleep(pause)
            delay = min(delay * 2, max_delay)


def run_node(
    host: str,
    port: int,
    *,
    node_id: str | None = None,
    connect_timeout: float = 30.0,
    preload: Sequence[str] = (),
    on_conn: Callable[[FrameConnection], None] | None = None,
) -> dict[str, Any]:
    """Run one Node-Loader to completion; returns its timing record.

    ``on_conn`` (test hook) is called with the live :class:`FrameConnection`
    right after the dial succeeds, so an in-process harness can sever the
    socket to simulate this node dying mid-run.
    """
    node_id = node_id or f"{socket.gethostname()}-{os.getpid()}"
    t_boot0 = time.perf_counter()

    # Heavy dependencies import concurrently with registration: the cost of
    # booting the environment lands in boot_ms, not in the code-distribution
    # (load) window the paper accounts in §8.2.
    def preloader() -> None:
        for name in preload:
            try:
                importlib.import_module(name)
            except Exception:  # the shipped code will surface a real error
                pass

    preload_thread = threading.Thread(target=preloader, name="nl-preload",
                                      daemon=True)
    preload_thread.start()

    sock = connect_with_retry(host, port, timeout=connect_timeout)
    sock.settimeout(None)
    conn = FrameConnection(sock)
    if on_conn is not None:
        on_conn(conn)

    # The peer data plane: a listening socket siblings dial directly (stage
    # forwarding + block trading).  Opened before REGISTER so the host can
    # put this node in peer directories immediately; items arriving before
    # the worker pool exists are held inside the server and drained once
    # the handler is installed below.
    block_store = peer_mod.BlockStore()
    peer_dir: dict[str, tuple] = {}
    peer_client = peer_mod.PeerClient(node_id, peer_dir)
    peer_server = peer_mod.PeerServer(node_id, block_store)
    peer_server.start()

    conn.send(Frame(
        FrameType.REGISTER,
        {"node_id": node_id, "cores": os.cpu_count() or 1,
         "pid": os.getpid(), "peer_port": peer_server.port},
        LOAD_WIRE_CHANNEL,
    ))

    # The beacon starts right after REGISTER: the boot/load phases may take
    # seconds (jax import), and the host must not mistake them for death.
    # The interval is refined once the plan says what the host expects.
    stop_beat = threading.Event()
    beat_interval = [DEFAULT_HEARTBEAT_S]

    # Node-side telemetry, piggybacked on every beat — the only node->host
    # reporting channel that exists before UT.  Mutated in place by the
    # load/worker paths (single-value updates; a torn read costs nothing).
    report = {"boot_ms": 0.0, "load_ms": 0.0, "items": 0,
              "cache_hits": 0, "cache_misses": 0, "jobs_bound": 0}

    def snapshot_report() -> dict:
        rep = dict(report)
        rep.update(peer_server.counters())
        rep.update(block_store.counters())
        rep["peer_items_sent"] = peer_client.items_sent
        rep["peer_bytes_sent"] = peer_client.bytes_sent
        return rep

    def heartbeat() -> None:
        while not stop_beat.wait(beat_interval[0]):
            try:
                conn.send(Frame(
                    FrameType.HEARTBEAT,
                    {"node_id": node_id, "report": snapshot_report()},
                    LOAD_WIRE_CHANNEL,
                ))
            except OSError:
                return

    beat_thread = threading.Thread(target=heartbeat, name="nl-heartbeat",
                                   daemon=True)
    beat_thread.start()

    # LOAD decoding (and the shipped code's imports with it) must not
    # contend with the preloader inside the load window; inbound frames
    # simply wait in the kernel socket buffer until it joins.
    preload_thread.join()
    boot_ms = (time.perf_counter() - t_boot0) * 1e3
    report["boot_ms"] = round(boot_ms, 3)
    load_ms = 0.0
    items_done = 0
    run_ms = 0.0

    def early_record() -> dict[str, Any]:
        # Host aborted (UT) or vanished during bootstrap: nothing ran.
        stop_beat.set()
        peer_server.close()
        peer_client.close()
        block_store.release()
        conn.close()
        return {"node_id": node_id, "boot_ms": round(boot_ms, 3),
                "load_ms": 0.0, "run_ms": 0.0, "items": 0}

    # -- multi-job state ----------------------------------------------------
    # fns: the worker dispatch table; code_cache: the digest-keyed warm LRU
    # the host mirrors (same capacity, same touch order — frames arrive in
    # send order on one TCP stream, so both sides evict identically).
    fns: dict[tuple[int, int], Callable[[Any], Any]] = {}
    code_cache: collections.OrderedDict = collections.OrderedDict()
    configured = False
    workers = 1
    slowdown = 0.0
    window = 2
    flush_items = 8
    flush_interval = 0.005

    work_q: queue.Queue = queue.Queue()
    items_lock = threading.Lock()
    out_lock = threading.Lock()
    out_bufs: dict[int, list[dict]] = {}  # job_id -> pending results
    # Per-job flush cadence: a pool job whose stages carry ``flush_ms=``
    # tightens the flusher's wake interval while it is bound (min over its
    # stages' values; the node-global flush_interval is the ceiling).
    flush_overrides: dict[int, float] = {}
    flush_now = threading.Event()
    stop_flush = threading.Event()

    # Peer routing state: per-job routing tables from LOAD, plus a holding
    # pen for peer-delivered items whose stage binding has not arrived yet
    # (a sibling's LOAD can complete before ours).
    route_tables: dict[int, peer_mod.RouteTable] = {}
    hold_lock = threading.Lock()
    peer_hold: dict[int, list[dict]] = {}
    last_report = [0.0]
    # Peer intake accounting: items admitted from the peer plane that the
    # workers have not consumed yet.  The gate below blocks the peer-serve
    # reader threads at PEER_INTAKE_MAX_ITEMS (TCP backpressure on the
    # sender); self-delivery and the pre-handler held drain never block,
    # so the flusher and the main frame loop cannot deadlock on it.
    intake_cv = threading.Condition()
    peer_backlog = [0]

    def peer_intake_gate(n: int) -> None:
        with intake_cv:
            while (peer_backlog[0] >= PEER_INTAKE_MAX_ITEMS
                   and not stop_flush.is_set()):
                intake_cv.wait(0.05)

    def peer_intake_release(n: int) -> None:
        with intake_cv:
            peer_backlog[0] -= n
            intake_cv.notify_all()

    def send_report(force: bool = False) -> None:
        # The dedicated REPORT frame: pushed right after result activity so
        # host-side gauges track completions instead of lagging one beat.
        now = time.monotonic()
        if not force and now - last_report[0] < REPORT_MIN_INTERVAL_S:
            return
        last_report[0] = now
        try:
            conn.send(Frame(
                FrameType.REPORT,
                {"node_id": node_id, "report": snapshot_report()},
                LOAD_WIRE_CHANNEL,
            ))
        except OSError:
            pass

    def on_peer_items(job_id: int, items: list) -> None:
        with intake_cv:
            peer_backlog[0] += len(items)
        with hold_lock:
            for item in items:
                s = int(item.get("s", 0))
                if (job_id, s) in fns:
                    work_q.put((job_id, item))
                else:
                    peer_hold.setdefault(job_id, []).append(item)

    peer_server.set_on_items(on_peer_items)
    peer_server.set_intake_gate(peer_intake_gate)

    def complete(job_id: int, result: dict, urgent: bool = False) -> None:
        with out_lock:
            out_bufs.setdefault(job_id, []).append(result)
            n = sum(len(b) for b in out_bufs.values())
        if urgent or n >= flush_items:
            flush_now.set()

    def peer_deliver(jid: int, target: str, items: list[dict]) -> bool:
        if target == node_id:
            # Our own node is a valid next-stage target: skip the wire.
            on_peer_items(jid, items)
            peer_client.items_sent += len(items)
            return True
        try:
            peer_client.send_items(jid, target, items)
            return True
        except ChannelClosed:
            return False

    def flush() -> None:
        with out_lock:
            batches = [(jid, buf) for jid, buf in out_bufs.items() if buf]
            out_bufs.clear()
        sent_any = False
        for jid, batch in batches:
            rt = route_tables.get(jid)
            host_results = batch
            if rt is not None:
                host_results = []
                acks: list[dict] = []
                ack_credits = 0
                # Group by each item's first-preference target so one frame
                # carries a whole flush worth of same-destination items.
                groups: dict[str, list[tuple[dict, list[str]]]] = {}
                for r in batch:
                    s = int(r.get("s", 0))
                    targets = (rt.targets_for(s, r["value"])
                               if "value" in r and rt.has(s) else [])
                    if not targets:
                        host_results.append(r)
                        continue
                    groups.setdefault(targets[0], []).append((r, targets))

                def fwd(r: dict) -> dict:
                    return {"id": r["id"], "s": int(r["s"]) + 1,
                            "obj": r["value"], "peer": True}

                for primary, entries in groups.items():
                    shipped: list[tuple[dict, str]] = []
                    if peer_deliver(jid, primary,
                                    [fwd(r) for r, _ in entries]):
                        shipped = [(r, primary) for r, _ in entries]
                    else:
                        # Primary unreachable: walk each item's fallback
                        # list; anything with no live peer goes to the host
                        # as an ordinary relayed result (correct, degraded).
                        for r, targets in entries:
                            for t in targets[1:]:
                                if peer_deliver(jid, t, [fwd(r)]):
                                    shipped.append((r, t))
                                    break
                            else:
                                host_results.append(r)
                    for r, t in shipped:
                        acks.append({"id": r["id"], "s": int(r["s"]),
                                     "to": t})
                        # Window credits return only for host-dispatched
                        # inputs; peer-delivered ones never consumed a
                        # credit, so crediting them would grow the window.
                        if not r.get("peer"):
                            ack_credits += 1
                if acks:
                    try:
                        conn.send(Frame(
                            FrameType.ITEM_ACK,
                            {"node_id": node_id, "acks": acks,
                             "credits": ack_credits},
                            APP_WIRE_CHANNEL, job_id=jid,
                        ))
                    except OSError:
                        pass
                    sent_any = True
            if not host_results:
                continue
            payload = {"node_id": node_id, "results": host_results,
                       # Each finished item frees one window slot: demand
                       # piggybacks on delivery (no separate request frame).
                       # Peer-delivered inputs carry no credit (see above).
                       "credits": sum(1 for r in host_results
                                      if not r.get("peer"))}
            try:
                conn.send(Frame(FrameType.RESULT_BATCH, payload,
                                APP_WIRE_CHANNEL, job_id=jid))
                sent_any = True
            except OSError:
                pass  # host gone: the nrfa loop shuts the node down
            except Exception as exc:
                # A result refused to serialize: report instead of stalling
                # the job with a silently dead flusher (the host fails fast).
                try:
                    conn.send(Frame(
                        FrameType.RESULT_BATCH,
                        {"node_id": node_id, "credits": payload["credits"],
                         "results": [{
                             "id": host_results[0]["id"],
                             "s": host_results[0].get("s", 0),
                             "error": f"{type(exc).__name__}: {exc}",
                             "traceback": traceback.format_exc(),
                         }]},
                        APP_WIRE_CHANNEL, job_id=jid,
                    ))
                    sent_any = True
                except OSError:
                    pass
        if sent_any:
            send_report()

    def flusher() -> None:
        while not stop_flush.is_set():
            interval = flush_interval
            # Snapshot under out_lock: bind_stages/JOB_CLOSE resize the
            # dict on the frame thread, and iterating a dict mid-resize
            # raises RuntimeError — an uncaught one would kill the
            # flusher and stall every job on this node to its deadline.
            with out_lock:
                overrides = list(flush_overrides.values())
            if overrides:
                interval = min(interval, min(overrides))
            flush_now.wait(interval)
            flush_now.clear()
            flush()
        flush()  # drain the tail after the workers joined

    def worker() -> None:
        nonlocal items_done
        while True:
            got = work_q.get()
            if got is UT:
                return
            job_id, item = got
            s = int(item.get("s", 0))
            # Results remember whether their input arrived from a peer: the
            # flusher returns window credits only for host-dispatched items.
            tag = {"peer": True} if item.get("peer") else {}
            if tag:
                peer_intake_release(1)  # consumed: reopen the intake gate
            fn = fns.get((job_id, s))
            if fn is None:
                # JOB_CLOSE raced ahead of in-flight items: the job is
                # already finished/failed host-side, so the result is moot —
                # but the credit is not (a dropped item would shrink the
                # window forever).  Report an error result; the host ignores
                # results of closed jobs and banks the piggybacked credit.
                complete(job_id, {"id": item["id"], "s": s,
                                  "error": "stage binding dropped "
                                           "(job closed)", **tag},
                         urgent=True)
                continue
            try:
                value = fn(item["obj"])
                if slowdown > 0.0:
                    time.sleep(slowdown)  # injected straggler (§6.1 testing)
                complete(job_id, {"id": item["id"], "s": s, "value": value,
                                  **tag})
            except BaseException as exc:
                # Report instead of dying silently: a dead worker thread
                # would stall the node (heartbeats keep flowing, so the
                # host would never re-dispatch).  The host fails the job.
                complete(job_id,
                         {"id": item["id"], "s": s,
                          "error": f"{type(exc).__name__}: {exc}",
                          "traceback": traceback.format_exc(), **tag},
                         urgent=True)
                continue
            with items_lock:
                items_done += 1
                report["items"] = items_done

    worker_threads: list[threading.Thread] = []
    flush_thread = threading.Thread(target=flusher, name="nl-flusher",
                                    daemon=True)
    t_run0 = time.perf_counter()

    def bind_stages(job_id: int, plan: dict) -> None:
        bound = False
        for entry in plan.get("stages", ()):
            ms = entry.get("flush_ms")
            if ms is not None:
                iv = max(0.0005, float(ms) / 1000.0)
                with out_lock:  # the flusher snapshots under the same lock
                    prior = flush_overrides.get(job_id)
                    flush_overrides[job_id] = (iv if prior is None
                                               else min(prior, iv))
            digest = entry["digest"]
            blob = entry["function"]
            if blob is not None:
                fn = loads_code(blob)
                code_cache[digest] = fn
                while len(code_cache) > CODE_CACHE_SLOTS:
                    code_cache.popitem(last=False)
                report["cache_misses"] += 1
            else:
                # The host's LRU mirror says we still hold it — if the two
                # ever diverged this KeyError kills the node, the host reaps
                # it and redispatches: degraded, not wrong.
                fn = code_cache[digest]
                code_cache.move_to_end(digest)
                report["cache_hits"] += 1
            fns[(job_id, int(entry["s"]))] = fn
            bound = True
        if bound:
            report["jobs_bound"] += 1
            # Drain peer-delivered items that raced ahead of this binding.
            with hold_lock:
                held = peer_hold.pop(job_id, [])
                for item in held:
                    if (job_id, int(item.get("s", 0))) in fns:
                        work_q.put((job_id, item))
                    else:
                        peer_hold.setdefault(job_id, []).append(item)

    fetching_blocks: set[tuple] = set()

    def fetch_blocks_async(manifest: list[dict]) -> None:
        # The manifest rides every LOAD (a publish broadcast, then each
        # job ship): dedup in-flight fetches or each repeat would re-stripe
        # the host for chunks already on their way.
        with hold_lock:
            manifest = [m for m in manifest
                        if (m.get("name"), m.get("digest"))
                        not in fetching_blocks]
            if not manifest:
                return
            fetching_blocks.update(
                (m.get("name"), m.get("digest")) for m in manifest)

        def host_request(name: str, chunk: int) -> None:
            try:
                conn.send(Frame(FrameType.BLOCK_REQUEST,
                                {"name": name, "chunk": chunk},
                                LOAD_WIRE_CHANNEL))
            except OSError:
                pass

        def runner() -> None:
            try:
                peer_mod.fetch_blocks(manifest, store=block_store,
                                      client=peer_client,
                                      host_request=host_request)
            except Exception:
                pass  # a failed fetch surfaces as get_block() timing out
            finally:
                # A block that failed to assemble may be retried by the
                # next LOAD carrying it.
                with hold_lock:
                    for m in manifest:
                        if not block_store.has(m.get("name")):
                            fetching_blocks.discard(
                                (m.get("name"), m.get("digest")))
            send_report(force=True)

        threading.Thread(target=runner, name="nl-block-fetch",
                         daemon=True).start()

    def apply_load(job_id: int, plan: dict) -> None:
        nonlocal configured, workers, slowdown, window
        nonlocal flush_items, flush_interval, t_run0
        pd = plan.get("peer")
        if pd:
            for nid, addr in (pd.get("dir") or {}).items():
                peer_dir[nid] = (addr[0], int(addr[1]))
            routes = pd.get("routes")
            if routes:
                route_tables[job_id] = peer_mod.RouteTable(routes)
        blocks = plan.get("blocks")
        if blocks:
            fetch_blocks_async(blocks)
        if "workers" not in plan:
            return  # a directory/blocks refresh, not a deployment
        if not configured:
            configured = True
            workers = int(plan["workers"])
            slowdown = float(plan.get("slowdown", 0.0))
            beat_interval[0] = float(
                plan.get("heartbeat_interval", DEFAULT_HEARTBEAT_S)
            )
            prefetch = plan.get("prefetch")
            # None = one extra per worker; 0 is honoured (strict
            # one-item-per-worker window, the pure demand-driven
            # pre-pipelining behaviour).
            prefetch = workers if prefetch is None else max(0, int(prefetch))
            window = workers + prefetch
            flush_items = max(1, int(plan.get("flush_items", 8)))
            flush_interval = float(plan.get("flush_interval", 0.005))
            ARTIFACTS.clear()
            ARTIFACTS.update(plan.get("artifacts") or {})
            bind_stages(job_id, plan)
            for i in range(workers):
                t = threading.Thread(target=worker, name=f"nl-worker{i}",
                                     daemon=True)
                t.start()
                worker_threads.append(t)
            flush_thread.start()
            t_run0 = time.perf_counter()
            # The windowed nrfa client: one up-front demand for the whole
            # window, then WORK_BATCH frames fill it and RESULT_BATCH
            # credits (sent by the flusher) keep it full.  Sent *after* the
            # stages bound above, so work can never outrun code.
            conn.send(Frame(
                FrameType.WORK_REQUEST,
                {"node_id": node_id, "credits": window},
                APP_WIRE_CHANNEL,
            ))
        else:
            bind_stages(job_id, plan)

    # First frame: the host answers REGISTER with LOAD (or UT on abort).
    # Bound the wait — a host that never loads us is indistinguishable from
    # a wedged bootstrap, and the paper's NL is supposed to fail loudly.
    sock.settimeout(connect_timeout)
    try:
        first = conn.recv()
    except socket.timeout:
        stop_beat.set()
        conn.close()
        raise ConnectionError(
            f"no LOAD received from the host within {connect_timeout}s "
            "(are all expected node-loaders up?)"
        ) from None
    except (ConnectionError, OSError, ValueError):
        return early_record()
    sock.settimeout(None)

    terminated_by_host = False
    frame: Frame | None = first
    try:
        while True:
            if frame is None:
                frame = conn.recv()
            if frame.ftype is FrameType.UT:
                if not configured:
                    return early_record()
                terminated_by_host = True
                break
            if frame.ftype is FrameType.LOAD:
                t0 = time.perf_counter()
                apply_load(frame.job_id, frame.payload)
                load_ms += (time.perf_counter() - t0) * 1e3
                report["load_ms"] = round(load_ms, 3)
            elif frame.ftype is FrameType.WORK_BATCH:
                for item in frame.payload["items"]:
                    work_q.put((frame.job_id, item))
            elif frame.ftype is FrameType.WORK:  # legacy single form
                work_q.put((frame.job_id, frame.payload))
            elif frame.ftype is FrameType.BLOCK_CHUNK:
                # A host reply to one of our striped BLOCK_REQUESTs.
                p = frame.payload or {}
                block_store.add_chunk(p.get("name"),
                                      int(p.get("chunk", 0)), p.get("data"))
            elif frame.ftype is FrameType.JOB_CLOSE:
                # The job is done (or failed) host-side: drop its dispatch
                # bindings.  The code cache is untouched — keeping it hot
                # is what makes the next submit of the same pipeline warm.
                jid = frame.job_id
                for key in [k for k in fns if k[0] == jid]:
                    del fns[key]
                with out_lock:  # the flusher snapshots under the same lock
                    flush_overrides.pop(jid, None)
                route_tables.pop(jid, None)
                with hold_lock:
                    dropped = peer_hold.pop(jid, None)
                if dropped:
                    # Parked items die with their job; their intake slots
                    # must reopen or the gate leaks capacity.
                    peer_intake_release(len(dropped))
            frame = None
    except (ConnectionError, OSError, ValueError):
        # Host vanished (mid-recv): there is nobody to deliver to; shut
        # down quietly.
        if not configured:
            return early_record()

    for _ in range(workers):
        work_q.put(UT)
    for t in worker_threads:
        t.join()
    stop_flush.set()
    flush_now.set()
    flush_thread.join()
    run_ms = (time.perf_counter() - t_run0) * 1e3
    stop_beat.set()
    peer_server.close()
    peer_client.close()
    # Release resident broadcast blocks: the process-global read mirror is
    # refcounted per holding store, and an exited node must not pin its
    # blocks there forever (in-process pools share the mirror).
    block_store.release()

    record = {
        "node_id": node_id,
        "boot_ms": round(boot_ms, 3),
        "load_ms": round(load_ms, 3),
        "run_ms": round(run_ms, 3),
        "items": items_done,
    }
    if terminated_by_host:
        try:
            conn.send(Frame(FrameType.UT, record, LOAD_WIRE_CHANNEL))
        except OSError:
            pass
    conn.close()
    return record


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        description="ClusterBuilder Node-Loader (paper §4)"
    )
    parser.add_argument("--host", required=True,
                        help="Host-Node-Loader address")
    parser.add_argument("--port", type=int, required=True,
                        help="load network port (the paper's 2000)")
    parser.add_argument("--node-id", default=None)
    parser.add_argument(
        "--connect-timeout", type=float, default=30.0,
        help="seconds to keep retrying the initial host dial (with "
             "exponential backoff) before giving up",
    )
    parser.add_argument(
        "--preload", default="",
        help="comma-separated modules to import during boot, overlapping "
             "registration (e.g. 'jax.numpy')",
    )
    args = parser.parse_args(argv)
    preload = tuple(m for m in args.preload.split(",") if m)
    try:
        record = run_node(
            args.host, args.port,
            node_id=args.node_id,
            connect_timeout=args.connect_timeout,
            preload=preload,
        )
    except (ConnectionError, socket.timeout, OSError) as exc:
        print(
            f"node-loader: cannot reach host-node-loader at "
            f"{args.host}:{args.port}: {exc}",
            flush=True,
        )
        return 1
    print(f"node-loader done: {record}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
