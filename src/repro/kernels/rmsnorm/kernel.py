"""Fused RMS-norm as a Pallas TPU kernel.

Row-blocked: grid over N / BLOCK_N; each program loads a [BLOCK_N, D] panel
into VMEM, reduces the mean-square per row in f32 on the VPU, applies
rsqrt + (1 + scale) and writes once — one HBM read + one write per element
(XLA's unfused graph does ~3 passes at bf16).  D is a single lane panel
(D <= ~8192 f32 fits comfortably in VMEM at BLOCK_N = 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rms_norm_pallas(
    x: jax.Array,  # [N, D]
    scale: jax.Array,  # [D]
    *,
    eps: float = 1e-6,
    block_n: int = BLOCK_N,
    interpret: bool = True,
):
    N, D = x.shape
    if N % block_n:
        raise ValueError(f"N={N} must tile by block_n={block_n}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        interpret=interpret,
    )(x, scale)
