"""Chaos injection + self-healing (repro.cluster.chaos).

The fault harness attacks the *real* transport — node kills through the
deployment layer, drop/delay/duplicate/corrupt at the frame layer — and
these tests assert the healing machinery it exists to exercise: mid-run
pool healing (dead -> launching -> registered, warm code re-shipped),
per-job retry with attempt history and the poisoned-job guard, zombie
dedup under stalled heartbeats, the decode-error death path, and the
JOB_CLOSE / backoff-jitter robustness fixes that ride along.  Everything
runs on 127.0.0.1 with an InProcessLauncher, so tier-1 stays hermetic.
"""

import os
import random
import socket
import threading
import time

import pytest

from repro.cluster.chaos import (
    ChaosController,
    Fault,
    FaultPlan,
    FaultyConnection,
    WireFaults,
)
from repro.cluster.deploy.inprocess import InProcessLauncher
from repro.cluster.host_loader import HostLoader
from repro.cluster.node_loader import connect_with_retry
from repro.cluster.service import ClusterService
from repro.cluster.wire import Frame, FrameType
from repro.core.dsl import ClusterSpec
from repro.core.processes import EmitDetails, ResultDetails
from repro.runtime.failures import WorkFunctionError

# Fast liveness (death detected within ~0.4s) — the same settings the
# service tests use; anything tighter makes healthy-but-GIL-contended
# in-process nodes flap dead.
FAST = dict(heartbeat_interval=0.1, heartbeat_misses=4)


def _range_emit(n):
    return EmitDetails(
        name="range",
        init=lambda limit: (0, limit),
        init_data=(n,),
        create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
    )


def _list_collect():
    return ResultDetails(name="list", init=lambda: [],
                         collect=lambda a, x: a + [x], finalise=sorted)


def _spec(work, n_items, *, nclusters=2, workers=2):
    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=nclusters, workers_per_node=workers,
        emit_details=_range_emit(n_items), work_function=work,
        result_details=_list_collect(),
    )


def _service(**kw):
    kw.setdefault("nodes", 2)
    kw.setdefault("workers", 2)
    kw.setdefault("launcher", InProcessLauncher())
    for key, val in FAST.items():
        kw.setdefault(key, val)
    return ClusterService(**kw)


def _event_kinds(svc):
    return [e["kind"] for e in svc.telemetry.events_since(0, limit=500)]


def _double(x):
    return x * 2


def _triple(x):
    return x * 3


def _slow_double(x):
    time.sleep(0.02)
    return x * 2


def _always_raises(x):
    raise RuntimeError(f"poisoned item {x}")


# ---------------------------------------------------------------------------
# plan validation
# ---------------------------------------------------------------------------


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan([Fault("meteor")]).validate()
    with pytest.raises(ValueError, match="must name their node"):
        FaultPlan([Fault("kill_node")]).validate()
    with pytest.raises(ValueError, match="probability"):
        FaultPlan([Fault("drop", probability=0.0)]).validate()
    with pytest.raises(ValueError, match="unknown frame type"):
        FaultPlan([Fault("drop", frame_types=("BOGUS",))]).validate()
    with pytest.raises(ValueError, match="count"):
        FaultPlan([Fault("corrupt", count=0)]).validate()
    # A sane plan validates (and the controller validates on construction).
    FaultPlan([
        Fault("kill_node", node="node1", after_items=3),
        Fault("straggler", node="node0", at_s=0.1, delay_s=0.01),
    ]).validate()


# ---------------------------------------------------------------------------
# the acceptance scenario: kill one node mid-job on a 4-node pool, heal
# ---------------------------------------------------------------------------


def test_kill_mid_job_heals_pool_and_completes():
    """A FaultPlan kills node1 mid-job on a 4-node pool with a heal
    budget: the job completes with exact results, the pool heals (a
    replacement launch registers), and the kill + failure + heal are all
    on the telemetry bus and in the metrics snapshot."""
    plan = FaultPlan([Fault("kill_node", node="node1", after_items=10)])
    with _service(nodes=4, max_heals=1, chaos=plan) as svc:
        handle = svc.submit(_spec(_slow_double, 400, nclusters=4), timeout=120)
        assert handle.result(timeout=120) == [2 * i for i in range(400)]

        stats = handle.stats()
        assert stats["respawns"] >= 1
        assert stats["heals"] >= 1

        hl = svc.host_loader
        assert hl.stats.deaths_detected >= 1
        # The replacement is a real membership member, not just a counter:
        # node1's heal announced node1r2, which must have launched and
        # (given the in-process launcher's instant boot) registered.
        replacements = [nid for nid in hl.membership.nodes if nid.startswith("node1r")]
        assert replacements, hl.membership.nodes.keys()
        new_rec = hl.membership.nodes[replacements[0]]
        states = [s for s, _ in new_rec.transitions]
        assert states[0] == "launching"
        assert "registered" in states
        # The dead original records its failure with detection metadata.
        dead = hl.membership.nodes["node1"]
        assert dead.state == "dead"
        assert dead.last_failure is not None
        assert dead.last_failure.node_id == "node1"
        assert dead.last_failure.detect_latency_s > 0.0

        kinds = _event_kinds(svc)
        assert "chaos_inject" in kinds
        assert "failure" in kinds
        assert "heal" in kinds

        snap = svc.metrics_snapshot()
        assert snap["chaos"]["faults_injected"] == 1
        assert snap["chaos"]["fired"][0]["kind"] == "kill_node"
        assert snap["cluster"]["heals"] >= 1
        assert snap["cluster"]["failures_detected"] >= 1
        # Attempt history is published even for the single-attempt job.
        assert stats["attempts"][0]["job_id"] == handle.job_id
        assert stats["attempts"][0]["error"] is None
    assert svc.orphaned() == []


def test_heal_relaunch_failure_shrinks_to_survivors():
    """When the launcher cannot place a replacement the heal is reported
    (heal_failed) and the historical shrink-to-survivors behaviour carries
    the job; close() still orphans nothing."""

    class NoReplacements(InProcessLauncher):
        def launch(self, node_id, *, avoid=()):
            if "r" in node_id.removeprefix("node"):
                raise RuntimeError("no capacity for replacements")
            return super().launch(node_id, avoid=avoid)

    plan = FaultPlan([Fault("kill_node", node="node1", after_items=5)])
    with _service(nodes=2, launcher=NoReplacements(), max_heals=2,
                  chaos=plan) as svc:
        handle = svc.submit(_spec(_slow_double, 80), timeout=120)
        assert handle.result(timeout=120) == [2 * i for i in range(80)]
        assert svc.host_loader.stats.heals == 0
        assert svc.host_loader.stats.deaths_detected >= 1
        kinds = _event_kinds(svc)
        assert "heal_failed" in kinds
        assert "heal" not in kinds
    assert svc.orphaned() == []


def test_heal_budget_defaults_to_zero():
    """Without max_heals a mid-run death shrinks the pool — no launches,
    no LAUNCHING records, exactly the pre-heal behaviour."""
    plan = FaultPlan([Fault("kill_node", node="node1", after_items=5)])
    with _service(nodes=2, chaos=plan) as svc:
        handle = svc.submit(_spec(_slow_double, 60), timeout=120)
        assert handle.result(timeout=120) == [2 * i for i in range(60)]
        hl = svc.host_loader
        assert hl.stats.heals == 0
        assert hl.stats.respawns == 0
        assert not [n for n in hl.membership.nodes if "r" in n.removeprefix("node")]
    assert svc.orphaned() == []


# ---------------------------------------------------------------------------
# per-job retry policy
# ---------------------------------------------------------------------------


def test_poisoned_job_stops_after_retries_with_history():
    """A deterministically failing work function is retried exactly
    ``retries`` times, then the handle resolves with the error and the
    full attempt history (cause, node, timing) on the handle."""
    with _service() as svc:
        handle = svc.submit(_spec(_always_raises, 8), timeout=30,
                            retries=2, backoff=0.01)
        with pytest.raises(WorkFunctionError, match="poisoned item"):
            handle.result(timeout=60)
        assert handle.done()
        assert len(handle.attempts) == 3  # 1 original + 2 retries
        for i, rec in enumerate(handle.attempts):
            assert rec["attempt"] == i + 1
            assert rec["cause"] == "work_function"
            assert rec["error_type"] == "WorkFunctionError"
            assert rec["node"] in svc.host_loader.membership.nodes
            assert rec["elapsed_ms"] is not None
        # Each attempt was a distinct job id on the pool.
        assert len({rec["job_id"] for rec in handle.attempts}) == 3
        stats = handle.stats()
        assert stats["retries"] == 2
        assert [a["attempt"] for a in stats["attempts"]] == [1, 2, 3]
        kinds = _event_kinds(svc)
        assert kinds.count("job_retry") == 2
        # The history is also in the metrics snapshot's job gauges.
        snap = svc.metrics_snapshot()
        last_job = str(handle.job_id)
        assert len(snap["jobs"][last_job]["attempts"]) == 3
    assert svc.orphaned() == []


def test_retry_recovers_from_transient_failure(tmp_path):
    """A failure that clears (the transient kind retries exist for) is
    healed by the second attempt; the result is exact and the history
    shows one failed and one clean attempt."""
    trip = tmp_path / "trip"
    trip.write_text("armed")

    def flaky(x):
        if os.path.exists(str(trip)):
            raise RuntimeError("transient outage")
        return x * 2

    with _service() as svc:
        handle = svc.submit(_spec(flaky, 12), timeout=30,
                            retries=3, backoff=0.3)
        # Clear the failure condition once the first attempt has failed.
        deadline = time.monotonic() + 20
        while not handle.attempts and time.monotonic() < deadline:
            time.sleep(0.01)
        assert handle.attempts, "first attempt never finished"
        trip.unlink()
        assert handle.result(timeout=60) == [2 * i for i in range(12)]
        assert len(handle.attempts) >= 2
        assert handle.attempts[0]["error_type"] == "WorkFunctionError"
        assert handle.attempts[0]["backoff_ms"] > 0
        assert handle.attempts[-1]["error"] is None
    assert svc.orphaned() == []


def test_submit_rejects_bad_retry_policy():
    svc = _service()
    try:
        with pytest.raises(ValueError, match="retries"):
            svc.submit(_spec(_double, 4), retries=-1)
        with pytest.raises(ValueError, match="backoff"):
            svc.submit(_spec(_double, 4), retries=1, backoff=-0.5)
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# wire faults: zombies, duplicates, corruption
# ---------------------------------------------------------------------------


def test_stalled_heartbeats_make_a_zombie_dedup_reconciles():
    """stall_heartbeat drops only the beats: the host declares a healthy
    node dead and redispatches, while the zombie keeps delivering — the
    result-id dedup keeps collection exactly-once and the job exact."""
    plan = FaultPlan([Fault("stall_heartbeat", node="node1", at_s=0.2)])
    with _service(chaos=plan) as svc:
        handle = svc.submit(_spec(_slow_double, 150), timeout=120)
        assert handle.result(timeout=120) == [2 * i for i in range(150)]
        hl = svc.host_loader
        assert hl.stats.deaths_detected >= 1  # a false positive, by design
        assert hl.membership.nodes["node1"].state in ("dead", "done")
        # Host-level and job-level dedup accounting reconcile.
        assert hl.stats.duplicates_dropped == handle.stats()["duplicates_dropped"]
    assert svc.orphaned() == []


def test_corrupt_frame_exercises_decode_death_path():
    """A corrupted WORK_BATCH (codec byte rewritten on the wire) makes the
    node's decode raise, which it treats as a dead host and exits; the
    host reaps it and survivors finish the job exactly."""
    plan = FaultPlan([Fault("corrupt", node="node1", at_s=0.1, count=1)])
    with _service(chaos=plan) as svc:
        handle = svc.submit(_spec(_slow_double, 80), timeout=120)
        assert handle.result(timeout=120) == [2 * i for i in range(80)]
        rec = svc.host_loader.membership.nodes["node1"]
        assert rec.state in ("dead", "done")  # clean retire or reaped
        snap = svc.metrics_snapshot()
        assert snap["chaos"]["faults_injected"] == 1
    assert svc.orphaned() == []


def test_soak_interleaved_faults_two_concurrent_jobs():
    """The satellite soak: kill + delay + duplicate interleaved while two
    jobs share a 3-node pool.  Both results stay exact and the dedup
    counters reconcile between the host and the per-job stats."""
    plan = FaultPlan([
        Fault("duplicate", node="node0", at_s=0.0),
        Fault("delay", node="node2", at_s=0.1, duration_s=1.0, delay_s=0.01),
        Fault("kill_node", node="node1", after_items=15),
    ])
    with _service(nodes=3, max_heals=1, chaos=plan) as svc:
        h1 = svc.submit(_spec(_slow_double, 200, nclusters=3), timeout=120)
        h2 = svc.submit(_spec(_triple, 90, nclusters=3), timeout=120,
                        priority=1)
        assert h1.result(timeout=120) == [2 * i for i in range(200)]
        assert h2.result(timeout=120) == [3 * i for i in range(90)]
        hl = svc.host_loader
        s1, s2 = h1.stats(), h2.stats()
        # Exactly-once per job: every item collected once, and the host's
        # duplicate count is exactly the sum of the per-job drops.
        assert s1["items_collected"] == 200
        assert s2["items_collected"] == 90
        assert (hl.stats.duplicates_dropped
                == s1["duplicates_dropped"] + s2["duplicates_dropped"])
        # The duplicate fault ran against node0's results, so dedup really
        # was exercised (not a vacuous reconciliation).
        assert hl.stats.duplicates_dropped >= 1
        # Per-job node attribution still sums to the collected items.
        assert sum(d.get("items", 0) for d in s1["nodes"].values()) == 200
        assert sum(d.get("items", 0) for d in s2["nodes"].values()) == 90
        assert hl.stats.deaths_detected >= 1
        snap = svc.metrics_snapshot()
        assert snap["chaos"]["faults_injected"] == 3
    assert svc.orphaned() == []


# ---------------------------------------------------------------------------
# FaultyConnection unit behaviour (no cluster needed)
# ---------------------------------------------------------------------------


class _ScriptedConn:
    """A FrameConnection stand-in: recv pops a script, send records."""

    def __init__(self, frames):
        self.frames = list(frames)
        self.sent = []
        self.raw = []
        self.peer = "scripted"

    def recv(self):
        if not self.frames:
            raise ConnectionError("script exhausted")
        return self.frames.pop(0)

    def send(self, frame):
        self.sent.append(frame)

    def send_raw(self, bufs):
        self.raw.append(bufs)

    def close(self):
        pass


def _beat(node="nodeX"):
    return Frame(FrameType.HEARTBEAT, {"node_id": node}, 2)


def _register(node="nodeX"):
    return Frame(FrameType.REGISTER, {"node_id": node}, 1)


def test_faulty_connection_drop_delay_duplicate_and_corrupt():
    faults = WireFaults(random.Random(0))
    conn = _ScriptedConn([_register(), _beat(), _beat(),
                          Frame(FrameType.RESULT_BATCH, {"results": []}, 2)])
    fc = FaultyConnection(conn, faults)

    # Identity is learned from REGISTER passing through.
    assert fc.recv().ftype is FrameType.REGISTER
    assert fc.node_id == "nodeX"

    # Install: drop heartbeats, duplicate result batches.
    plan = FaultPlan([
        Fault("stall_heartbeat", node="nodeX"),
        Fault("duplicate", node="nodeX"),
    ])
    ctl = ChaosController(plan)
    ctl.wire = faults  # route the rules into this test's registry
    ctl._armed_at = 0.0
    for f in plan.faults:
        ctl._fire(f, 0.0, 0)

    # Both beats are swallowed; the RESULT_BATCH arrives twice.
    first = fc.recv()
    assert first.ftype is FrameType.RESULT_BATCH
    dup = fc.recv()
    assert dup.ftype is FrameType.RESULT_BATCH
    assert ctl.injected == 2

    # Corrupt on send: the frame goes out raw with the codec byte mangled.
    ctl._fire(Fault("corrupt", node="nodeX", count=1), 0.0, 0)
    fc.send(Frame(FrameType.WORK_BATCH, {"items": []}, 2, job_id=1))
    assert len(conn.raw) == 1
    header = bytes(conn.raw[0][0])
    assert header[6] == 0x7F  # invalid codec id
    # The count is spent: the next send goes through clean.
    fc.send(Frame(FrameType.WORK_BATCH, {"items": []}, 2, job_id=1))
    assert len(conn.sent) == 1


def test_wire_rules_expire_and_respect_probability():
    faults = WireFaults(random.Random(1))
    fault = Fault("drop", node=None, duration_s=0.05,
                  frame_types=("HEARTBEAT",))
    plan = FaultPlan([fault])
    ctl = ChaosController(plan)
    ctl.wire = faults
    ctl._fire(fault, 0.0, 0)
    assert faults.match("any", "recv", _beat()) is not None
    time.sleep(0.08)
    assert faults.match("any", "recv", _beat()) is None
    assert faults.active_count() == 0


# ---------------------------------------------------------------------------
# satellite: JOB_CLOSE on every error path
# ---------------------------------------------------------------------------


class _RecordingConn:
    def __init__(self):
        self.sent = []
        self.peer = "fake"

    def send(self, frame):
        self.sent.append(frame)

    def close(self):
        pass


def test_failed_jobs_always_send_job_close():
    """Timed-out/aborted jobs tear down on the wire: JOB_CLOSE reaches
    every live node — pinned jobs and nodes whose LOAD never acked
    included — so nobody keeps computing for a corpse."""
    hl = HostLoader(None, pool_nodes=2, pool_workers=1)
    try:
        conn_a = _RecordingConn()
        conn_b = _RecordingConn()
        hl.membership.register("node0", "a:1", conn=conn_a)
        hl.membership.register("node1", "b:1", conn=conn_b)
        job = hl._new_job(_spec(_double, 4), pinned=True)
        hl._jobs[job.job_id] = job
        # node0 acked the LOAD, node1's is still in flight.
        hl.membership.nodes["node0"].jobs_loaded.add(job.job_id)

        hl._fail_job(job, TimeoutError("deadline"))
        assert job.failure_kind == "timeout"
        for conn in (conn_a, conn_b):
            closes = [f for f in conn.sent
                      if f.ftype is FrameType.JOB_CLOSE
                      and f.job_id == job.job_id]
            assert len(closes) == 1
        assert job.job_id not in hl.membership.nodes["node0"].jobs_loaded

        # A LOAD ack landing after the job ended closes instead of binding.
        hl._apply_load_result("node1", True, job.job_id)
        assert job.job_id not in hl.membership.nodes["node1"].jobs_loaded
        late_closes = [f for f in conn_b.sent
                       if f.ftype is FrameType.JOB_CLOSE]
        assert len(late_closes) == 2
    finally:
        hl.close()


# ---------------------------------------------------------------------------
# satellite: connect retry backoff jitter + cap
# ---------------------------------------------------------------------------


def test_connect_retry_backoff_jitter_and_cap(monkeypatch):
    """The reconnect schedule doubles to a cap, and jitter decorrelates it
    (a healed pool's mass redial must not reconnect in lockstep)."""
    attempts = {"n": 0}
    server, client = socket.socketpair()

    def flaky_create(addr, timeout=None):
        attempts["n"] += 1
        if attempts["n"] <= 5:
            raise OSError("connection refused")
        return client

    monkeypatch.setattr(socket, "create_connection", flaky_create)
    try:
        sleeps = []
        sock = connect_with_retry("127.0.0.1", 1, timeout=60.0,
                                  max_delay=1.0, jitter=0.0,
                                  _sleep=sleeps.append)
        assert sock is client
        assert sleeps == [0.2, 0.4, 0.8, 1.0, 1.0]  # doubling, capped

        attempts["n"] = 0
        jittered = []
        connect_with_retry("127.0.0.1", 1, timeout=60.0, max_delay=1.0,
                           jitter=0.5, _sleep=jittered.append,
                           _rng=random.Random(42))
        base = [0.2, 0.4, 0.8, 1.0, 1.0]
        assert all(0.5 * b <= s <= b for s, b in zip(jittered, base))
        assert jittered != base  # the draw actually moved the schedule
    finally:
        server.close()
        client.close()
