"""Failure & straggler injection + detection.

The container has one host, so failures are *injected* (the paper's cluster
had real workstations; our substitute keeps the entire detect -> checkpoint
-> re-mesh -> resume control path real and testable, with only the fault
itself simulated).  Detection thresholds follow standard heartbeat/step-time
practice.
"""

from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Literal

# The node beacon and the host threshold share one default interval so
# neither side beats at a rate the other does not expect.  Guarded so the
# runtime layer stays usable if the cluster transport (or its optional
# deps) is ever stripped from a deployment.
try:
    from repro.cluster.wire import DEFAULT_HEARTBEAT_S
except ImportError:  # pragma: no cover - cluster package absent
    DEFAULT_HEARTBEAT_S = 0.2

FailureKind = Literal["crash", "node_loss", "straggler"]


class WorkFunctionError(RuntimeError):
    """The user's work function raised inside a worker; the job fails fast.

    Shared by both backends so a spec validated on the threads runtime
    (paper §6.1 single-host confidence building) fails with the same
    exception type it would on the real cluster.
    """


class SimulatedNodeFailure(RuntimeError):
    def __init__(self, step: int, kind: FailureKind, node: int):
        super().__init__(f"simulated {kind} of node {node} at step {step}")
        self.step = step
        self.kind = kind
        self.node = node


@dataclass
class FailureEvent:
    """Shared failure vocabulary for both backends.

    The SPMD executor records simulated events (``step``/``node`` index);
    the real transport's membership layer records *detected* ones and
    fills the detection metadata: the dead node's string id and how long
    the heartbeat monitor took to notice after the last beat.  Telemetry
    consumers (``failure`` bus events, ``/metrics``) read the superset.
    """

    step: int
    kind: FailureKind = "crash"
    node: int = 0
    # straggler: multiplicative slowdown applied to the injected node
    slowdown: float = 4.0
    # detection metadata (real transport only; defaults for simulated events)
    node_id: str = ""
    detect_latency_s: float = 0.0


@dataclass
class FailurePlan:
    events: list[FailureEvent] = field(default_factory=list)
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> FailureEvent | None:
        for ev in self.events:
            if ev.step == step and id(ev) not in self._fired:
                self._fired.add(id(ev))
                return ev
        return None


@dataclass
class HeartbeatMonitor:
    """Missed-heartbeat node-death detection (paper-style workstation loss).

    A node is declared dead after ``misses`` consecutive missed beats — the
    standard heartbeat threshold (cf. GFS/Borg practice).  Used by the real
    multi-process transport (``repro.cluster.membership``): a dead subprocess
    triggers the same re-dispatch path the injected ``node_loss`` events
    exercise in the SPMD executor.
    """

    interval_s: float = DEFAULT_HEARTBEAT_S
    misses: int = 5

    @property
    def deadline_s(self) -> float:
        return self.interval_s * self.misses

    def is_dead(self, last_beat_s: float, now_s: float) -> bool:
        return (now_s - last_beat_s) > self.deadline_s


@dataclass
class StragglerMonitor:
    """Step-time EMA + median straggler detection.

    In SPMD every device runs in lockstep, so a straggling node slows the
    *whole step* (the collectives wait).  Detection is therefore on the
    global step time; mitigation is demand-driven re-dispatch at the data
    layer where possible (the paper's client-server protocol, exercised by
    the DSL runtime) or elastic exclusion of the slow node (executor path).
    """

    window: int = 32
    threshold: float = 2.0
    times: list[float] = field(default_factory=list)

    def record(self, step_time_s: float) -> bool:
        """Returns True when the last step looks straggler-afflicted."""
        self.times.append(step_time_s)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < 8:
            return False
        med = statistics.median(self.times[:-1])
        return step_time_s > self.threshold * med

    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
