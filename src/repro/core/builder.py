"""ClusterBuilder — compiles a specification into a deployed application.

This is the paper's central artifact: the builder consumes a
:class:`~repro.core.dsl.ClusterSpec` (or a bare SPMD step function plus typed
channels) and produces *everything else* with no user intervention:

* the **deployment plan** — the Host-Node-Loader / Node-Loader bootstrap
  of paper §4 and Figure 1 (load network on port 2000/channel 1, application
  network on a separate port, input-end-before-output-end ordering, sync
  barriers, timing return);
* the **wired process network** — for emit/cluster/collect applications, a
  runnable network (``runtime.local``) whose topology is exactly Figure 2 and
  whose protocol is the one model-checked by ``core.verify``;
* the **compiled SPMD step** — for cluster stages that are JAX step
  functions, a lowered+compiled executable with shardings derived by
  ``core.channels`` (requirement 4), AOT-serialisable so one host compiles
  and every node loads the binary (the analogue of JCSP code-loading
  channels, §4.1).

Load time (lower+compile+serialise) and run time are accounted separately
per requirement 7.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import jax

from repro.core import hlo as hlo_mod
from repro.core.channels import Channel, ShardingRules
from repro.core.dsl import ClusterSpec
from repro.core.timing import TimingCollector

try:  # executable broadcast (JCSP code-loading channel analogue)
    from jax.experimental.serialize_executable import (
        deserialize_and_load as _deserialize_and_load,
    )
    from jax.experimental.serialize_executable import serialize as _serialize

    _HAVE_SERIALIZE = True
except Exception:  # pragma: no cover - older jax
    _HAVE_SERIALIZE = False


LOAD_PORT = 2000  # paper §6: the load network uses port 2000 ...
LOAD_CHANNEL = 1  # ... and channel number 1 on every node.
APP_PORT = 3000  # application network runs on a different port (§6.1).


# ---------------------------------------------------------------------------
# Deployment plan (HNL / NL analogue).
# ---------------------------------------------------------------------------


@dataclass
class NodePlan:
    node_id: str
    address: str  # ip:port/channel — the only address a node needs
    workers: int
    stage: str = ""  # pipeline stage this node serves ("" pre-pipeline)


@dataclass
class StagePlan:
    """One pipeline stage's slice of the deployment."""

    name: str
    workers: int
    nodes: list[NodePlan] = field(default_factory=list)


@dataclass
class DeploymentPlan:
    """The generated loading/bootstrap schedule of paper §4 / Figure 1."""

    host: str
    nodes: list[NodePlan]
    stages: list[StagePlan] = field(default_factory=list)
    load_port: int = LOAD_PORT
    load_channel: int = LOAD_CHANNEL
    app_port: int = APP_PORT

    @property
    def host_load_address(self) -> str:
        return f"{self.host}:{self.load_port}/{self.load_channel}"

    def load_order(self) -> list[str]:
        """The bootstrap sequence the paper prescribes (§4)."""
        steps = [
            f"HNL: create many-to-one input channel {self.host_load_address}",
            "USER: start one NodeLoader executable per node (identical binary)",
        ]
        for np_ in self.nodes:
            steps.append(
                f"NL[{np_.node_id}]: create input {np_.address}; "
                f"send own IP to {self.host_load_address}"
            )
        steps += [
            f"HNL: received {len(self.nodes)} node IPs; create output channels",
            "HNL: send node-specific NodeProcess to every node "
            "(code-loading channel; single source of class files)",
            "HNL: create HostProcess (Emit + Collect) on the host node",
        ]
        if len(self.stages) > 1:
            chain = " -> ".join(
                f"{sp.name}[{len(sp.nodes)}]" for sp in self.stages
            )
            steps.append(
                f"HNL: route stage results host-side: emit -> {chain} "
                "-> collect (per-stage credit accounting)"
            )
        steps += [
            "ALL: application net channels — input ends created before output "
            "ends; synchronisation messages on the loading network enforce "
            "the order",
            "HP: final barrier; application execution commences",
            "ALL: on termination, nodes return (load_ms, run_ms) to host; "
            "host combines with its own and reports; all resources reclaimed",
        ]
        return steps

    def describe(self) -> str:
        lines = [
            f"DeploymentPlan host={self.host} nodes={len(self.nodes)} "
            f"(load port {self.load_port}, app port {self.app_port})"
        ]
        for np_ in self.nodes:
            stage = f"  stage={np_.stage}" if np_.stage else ""
            lines.append(
                f"  node {np_.node_id}: {np_.address}  "
                f"workers={np_.workers}{stage}"
            )
        lines.append("load order:")
        for i, s in enumerate(self.load_order()):
            lines.append(f"  {i + 1}. {s}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Compiled SPMD step.
# ---------------------------------------------------------------------------


@dataclass
class StepArtifact:
    """A lowered+compiled SPMD step with analysis accessors."""

    name: str
    fn: Callable
    jitted: Any
    lowered: Any
    compiled: Any
    mesh: Any
    load_ms: float

    def __call__(self, *args, **kw):
        return self.jitted(*args, **kw)

    # -- analysis -----------------------------------------------------------

    def cost(self) -> dict[str, float]:
        """Per-device HLO cost estimates (flops / bytes accessed).

        NOTE: XLA counts ``while``/scan bodies once; use unrolled probe
        programs (launch.roofline) for totals.
        """
        ca = self.compiled.cost_analysis() or {}
        return {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        }

    def memory(self):
        return self.compiled.memory_analysis()

    def hlo_text(self) -> str:
        return self.compiled.as_text()

    def collectives(self) -> hlo_mod.CollectiveSummary:
        return hlo_mod.parse_collectives(self.hlo_text())

    # -- executable broadcast (code-loading channel analogue) ----------------

    def serialize(self) -> bytes:
        if not _HAVE_SERIALIZE:
            raise RuntimeError("jax.experimental.serialize_executable unavailable")
        payload, _in_tree, _out_tree = _serialize(self.compiled)
        return payload


# ---------------------------------------------------------------------------
# The builder.
# ---------------------------------------------------------------------------


class ClusterBuilder:
    """Builds deployments from specifications.

    One builder is bound to one mesh (one "cluster"); building the same spec
    with a different builder re-deploys on different hardware with zero user
    changes (paper requirement 4 / §6.1 single-node confidence building).
    """

    def __init__(
        self,
        mesh=None,
        rules: ShardingRules | None = None,
        timing: TimingCollector | None = None,
    ):
        self.mesh = mesh
        self.rules = rules
        self.timing = timing or TimingCollector()

    # -- SPMD step path ------------------------------------------------------

    def build_step(
        self,
        fn: Callable,
        example_args: Sequence[Any],
        *,
        name: str = "step",
        donate_argnums: Sequence[int] = (),
        static_argnums: Sequence[int] = (),
        out_shardings: Any = None,
        compile_now: bool = True,
    ) -> StepArtifact:
        """Lower + compile ``fn`` against ShapeDtypeStruct channels.

        ``example_args`` may be real arrays or ShapeDtypeStructs (dry-run);
        input shardings are carried by the structs (derived via
        ``ShardingRules.struct``), so the user supplies none.
        """
        t0 = time.perf_counter()
        jit_kw: dict[str, Any] = {
            "donate_argnums": tuple(donate_argnums),
            "static_argnums": tuple(static_argnums),
        }
        if out_shardings is not None:
            jit_kw["out_shardings"] = out_shardings
        jitted = jax.jit(fn, **jit_kw)
        from repro.launch.mesh import use_mesh

        with use_mesh(self.mesh):
            lowered = jitted.lower(*example_args)
            compiled = lowered.compile() if compile_now else None
        load_ms = (time.perf_counter() - t0) * 1e3
        self.timing.add("host", "load", load_ms)
        return StepArtifact(
            name=name,
            fn=fn,
            jitted=jitted,
            lowered=lowered,
            compiled=compiled,
            mesh=self.mesh,
            load_ms=load_ms,
        )

    @staticmethod
    def load_serialized_step(payload: bytes, in_tree, out_tree) -> Any:
        """Node-side: load an executable broadcast by the host (§4.1)."""
        if not _HAVE_SERIALIZE:
            raise RuntimeError("jax.experimental.serialize_executable unavailable")
        return _deserialize_and_load(payload, in_tree, out_tree)

    # -- emit/cluster/collect application path -------------------------------

    def deployment_plan(
        self,
        spec,
        *,
        hosts: Sequence[str] | None = None,
        bind_host: str | None = None,
        launcher: Any = None,
    ) -> DeploymentPlan:
        """Derive the per-stage deployment plan for a spec.

        Node addresses come from the deployment layer when it is known:
        ``hosts=`` (the ssh fan-out shorthand) or a launcher exposing
        ``.hosts`` assigns machines round-robin exactly as the launcher
        will; otherwise ``bind_host`` (every local node-loader dials it).
        With no deployment information at all — a plan derived from the
        spec alone — documentation-placeholder addresses are used, as the
        paper's §4 walkthrough does.
        """
        pipe = spec.as_pipeline() if hasattr(spec, "as_pipeline") else spec
        pipe.validate()
        machines = list(hosts) if hosts else list(
            getattr(launcher, "hosts", None) or []
        )

        def addr_host(i: int) -> str:
            if machines:
                return machines[i % len(machines)]
            if bind_host:
                # Local node-loaders dial the host's bind address; an
                # unroutable wildcard bind resolves to loopback for them.
                return "127.0.0.1" if bind_host == "0.0.0.0" else bind_host
            return f"192.168.1.{100 + i}"  # placeholder: deployment unknown

        nodes: list[NodePlan] = []
        stage_plans: list[StagePlan] = []
        i = 0
        for st in pipe.stages:
            sp = StagePlan(name=st.name, workers=st.workers_per_node)
            for _ in range(st.nclusters):
                np_ = NodePlan(
                    node_id=f"node{i}",
                    address=f"{addr_host(i)}:{LOAD_PORT}/{LOAD_CHANNEL}",
                    workers=st.workers_per_node,
                    stage=st.name if len(pipe.stages) > 1 else "",
                )
                nodes.append(np_)
                sp.nodes.append(np_)
                i += 1
            stage_plans.append(sp)
        return DeploymentPlan(host=pipe.host, nodes=nodes, stages=stage_plans)

    def build_application(self, spec, *, backend: str = "threads",
                          **backend_options):
        """Wire the process network and return a runnable application.

        ``spec`` is a :class:`~repro.core.dsl.ClusterSpec` (the paper's
        emit/cluster/collect shape) or a
        :class:`~repro.core.dsl.PipelineSpec` (one emit, N chained stages,
        one collect); a ClusterSpec is normalised to its one-stage pipeline
        view, so both backends run one code path.

        Backends (all run the *same* spec with zero user-code changes):

        * ``"threads"`` — threads + rendezvous queues in one process
          (``repro.runtime.local``; the paper's §6.1 single-host
          confidence-building mode).  One option:
          ``readonly_delivery=True`` hands work functions read-only
          ndarray views, mirroring the cluster backend's zero-copy
          delivery semantics so in-place mutation bugs surface on one
          host.
        * ``"cluster"`` — real OS processes connected by TCP sockets via the
          Host-Node-Loader / Node-Loader bootstrap of §4 / Figure 1
          (``repro.cluster``).  ``backend_options`` are forwarded to
          :class:`repro.cluster.spawn.ProcessClusterApplication` (e.g.
          ``port=0``, ``slowdown={node_id: seconds_per_item}``).
          *Where* the node-loaders run is pluggable (the deployment
          layer, ``repro.cluster.deploy``): ``launcher=`` takes any
          :class:`~repro.cluster.deploy.base.Launcher` (LocalLauncher
          subprocesses by default, SSHLauncher for real workstations,
          InProcessLauncher threads for tests), and ``hosts=["ws01",...]``
          is shorthand for ssh fan-out over those machines.  The
          registration barrier is policy-driven: ``min_nodes=`` admits a
          degraded start with survivors, ``max_respawns=`` relaunches a
          node that never registers elsewhere, and late joiners are
          shipped LOAD + credits mid-run (``allow_late_join``).
          Robustness knobs: ``max_heals=`` budgets mid-run pool healing
          (a node dying *during* the run is relaunched, warm code
          re-shipped) and ``chaos=`` arms a
          :class:`repro.cluster.chaos.FaultPlan` of injected faults
          (kill/drop/delay/duplicate/corrupt/stall-heartbeat/partition/
          straggler) against the live transport.
          One transport caveat: ndarray payloads cross the wire on a
          zero-copy codec and arrive as *read-only* views — a work
          function that mutates its input in place must ``np.copy`` it
          first (the threads backend hands over the original, writable
          array).
        * ``"service"`` — the same process transport over a *persistent
          warm node pool* (:class:`repro.cluster.service.ClusterService`).
          Pass ``service=`` to run this application as one job of a
          caller-owned pool that stays up (repeat builds of the same spec
          become warm resubmits: no boot, no code shipped); without it an
          ephemeral pool sized from the spec boots for this run and closes
          after.  Remaining ``backend_options`` configure the pool
          (``nodes=``/``workers=`` geometry comes from the spec) —
          including the same ``max_heals=`` / ``chaos=`` robustness
          knobs; the service additionally retries failed jobs when its
          ``submit(..., retries=, backoff=)`` policy is used directly.

        Observability (``"cluster"`` and ``"service"`` backends): pass
        ``trace_path="run.jsonl"`` to append every lifecycle event
        (membership transitions, job submit/done, respawns) as one JSON
        line, and ``http_port=0`` (ephemeral) or a fixed port to serve the
        live status endpoint — ``GET /metrics`` (JSON, or Prometheus text
        with ``?format=prom``), ``/jobs``, ``/nodes``, ``/events?since=N``
        and an auto-refreshing HTML dashboard at ``/``.  See
        :mod:`repro.cluster.telemetry` and ARCHITECTURE.md "Observability".

        Runtimes are imported lazily to keep core dependency-free.
        """
        pipe = spec.as_pipeline() if hasattr(spec, "as_pipeline") else spec
        pipe.validate()
        if backend == "threads":
            readonly = bool(backend_options.pop("readonly_delivery", False))
            if backend_options:
                raise TypeError(
                    f"threads backend takes no options (beyond "
                    f"readonly_delivery), got {sorted(backend_options)}"
                )
            from repro.runtime.local import LocalClusterApplication

            return LocalClusterApplication(
                spec=pipe, plan=self.deployment_plan(pipe),
                timing=self.timing, readonly_delivery=readonly,
            )
        if backend == "cluster":
            from repro.cluster.spawn import ProcessClusterApplication

            # The plan reflects the actual deployment layer: hosts=/launcher
            # machine assignments, or the bind address local loaders dial.
            plan = self.deployment_plan(
                pipe,
                hosts=backend_options.get("hosts"),
                bind_host=backend_options.get("bind_host", "127.0.0.1"),
                launcher=backend_options.get("launcher"),
            )
            return ProcessClusterApplication(
                spec=pipe, plan=plan, timing=self.timing, **backend_options
            )
        if backend == "service":
            from repro.cluster.service import ServiceClusterApplication

            plan = self.deployment_plan(
                pipe,
                hosts=backend_options.get("hosts"),
                bind_host=backend_options.get("bind_host", "127.0.0.1"),
                launcher=backend_options.get("launcher"),
            )
            return ServiceClusterApplication(
                spec=pipe, plan=plan, timing=self.timing, **backend_options
            )
        raise ValueError(
            f"unknown backend {backend!r}; expected 'threads', 'cluster', "
            "or 'service'"
        )
