"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

The recurrence (per channel) is

    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t + b_a))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

with an input gate ``i_t = sigmoid(W_x x_t + b_x)``.  Training uses an
*associative scan* over the sequence (log-depth on TPU); decoding steps the
recurrence with O(1) state — which is why recurrentgemma runs the
``long_500k`` shape that dense-attention archs skip.

Block structure (Griffin "recurrent block"): two branches from the residual
stream — (linear -> GeLU) gate branch and (linear -> temporal conv1d ->
RG-LRU) recurrent branch — merged by elementwise product and projected back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, fan_in_normal

_C = 8.0  # Griffin's fixed constant c


def rglru_param_specs(layers: int, width: int) -> dict:
    return {
        "lambda": ParamSpec((layers, width), ("layers", "rnn_state"),
                            init="rglru_lambda"),
        "w_a": ParamSpec((layers, width), ("layers", "rnn_state"),
                         init="normal", stddev=fan_in_normal((width, width))),
        "b_a": ParamSpec((layers, width), ("layers", "rnn_state"), init="zeros"),
        "w_x": ParamSpec((layers, width), ("layers", "rnn_state"),
                         init="normal", stddev=fan_in_normal((width, width))),
        "b_x": ParamSpec((layers, width), ("layers", "rnn_state"), init="zeros"),
    }


def recurrent_block_specs(layers: int, d: int, width: int, conv_w: int) -> dict:
    return {
        "w_branch_x": ParamSpec((layers, d, width),
                                ("layers", "d_model_fsdp", "rnn_state"),
                                stddev=fan_in_normal((d, width))),
        "w_branch_gate": ParamSpec((layers, d, width),
                                   ("layers", "d_model_fsdp", "rnn_state"),
                                   stddev=fan_in_normal((d, width))),
        "conv1d": ParamSpec((layers, conv_w, width),
                            ("layers", None, "rnn_state"), stddev=0.02),
        "w_out": ParamSpec((layers, width, d),
                           ("layers", "rnn_state", "d_model_fsdp"),
                           stddev=fan_in_normal((width, d))),
        "rglru": rglru_param_specs(layers, width),
    }


def _gates(params: dict, x: jax.Array):
    """Per-timestep gate values. x: [B, S, W] (bf16 ok, gates in f32)."""
    xf = x.astype(jnp.float32)
    log_a_scale = -_C * jax.nn.softplus(params["lambda"].astype(jnp.float32))
    r = jax.nn.sigmoid(xf * params["w_a"].astype(jnp.float32) + params["b_a"].astype(jnp.float32))
    log_a = log_a_scale * r  # [B, S, W], <= 0
    a = jnp.exp(log_a)
    gated_x = xf * jax.nn.sigmoid(
        xf * params["w_x"].astype(jnp.float32) + params["b_x"].astype(jnp.float32)
    )
    # sqrt(1 - a^2) computed stably via expm1: 1 - exp(2 log a).
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a) + 1e-12)
    return a, beta * gated_x


def rglru_scan(params: dict, x: jax.Array, h0: jax.Array | None = None):
    """Associative-scan RG-LRU. x: [B, S, W] -> (y [B, S, W], h_last)."""
    a, bx = _gates(params, x)
    if h0 is not None:
        # Fold the carried state into the first step: h_1 = a_1 h_0 + bx_1.
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_c, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    del a_c
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params: dict, x_t: jax.Array, h: jax.Array):
    """Single decode step. x_t: [B, W]; h: [B, W] -> (y_t, h')."""
    a, bx = _gates(params, x_t[:, None])
    h_new = a[:, 0] * h.astype(jnp.float32) + bx[:, 0]
    return h_new.astype(x_t.dtype), h_new


def causal_conv1d(w: jax.Array, x: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. w: [K, W]; x: [B, S, W]; state: [B, K-1, W]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, W]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k : k + x.shape[1]].astype(jnp.float32) * w[k].astype(
            jnp.float32
        )
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out.astype(x.dtype), new_state


def recurrent_block(
    params: dict,
    x: jax.Array,
    *,
    compute_dtype=jnp.bfloat16,
    state: dict | None = None,
):
    """Griffin recurrent block.  x: [B, S, D].

    ``state`` (decode): {"h": [B, W], "conv": [B, K-1, W]}.  Returns
    (out [B, S, D], new_state | None).
    """
    xc = x.astype(compute_dtype)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", xc, params["w_branch_gate"].astype(compute_dtype))
    )
    u = jnp.einsum("bsd,dw->bsw", xc, params["w_branch_x"].astype(compute_dtype))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(params["conv1d"], u, conv_state)
    if state is not None:
        y, h_new = rglru_step(params["rglru"], u[:, 0], state["h"])
        y = y[:, None]
    else:
        y, h_new = rglru_scan(params["rglru"], u)
    merged = y * gate
    out = jnp.einsum("bsw,wd->bsd", merged.astype(compute_dtype),
                     params["w_out"].astype(compute_dtype))
    new_state = {"h": h_new, "conv": new_conv}
    return out.astype(x.dtype), new_state
