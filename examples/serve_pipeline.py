"""Serving example: the paper's demand-driven client-server protocol as a
continuous-batching LLM engine.

Requests arrive in bursts; decode slots *request* work when idle (onrl/nrfa
adaptation — see DESIGN.md section 2); completed sequences are collected and
verified against offline greedy decode.

Run:  PYTHONPATH=src python examples/serve_pipeline.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.common import init_params
from repro.runtime.serving import Request, ServingEngine


def main() -> None:
    cfg = dataclasses.replace(get_config("gemma3-4b").smoke(),
                              compute_dtype="float32")
    params = init_params(lm.lm_param_specs(cfg, 1), jax.random.PRNGKey(0),
                         jnp.float32)
    engine = ServingEngine(cfg, params, max_slots=4, max_seq=96)
    rng = np.random.default_rng(0)

    # Burst 1
    for rid in range(6):
        engine.submit(Request(
            rid=rid,
            prompt=list(map(int, rng.integers(0, cfg.vocab_size,
                                              int(rng.integers(4, 16))))),
            max_new_tokens=int(rng.integers(4, 12)),
        ))
    # run a few ticks, then a second burst joins mid-flight
    for _ in range(3):
        engine.step()
    for rid in range(6, 10):
        engine.submit(Request(
            rid=rid,
            prompt=list(map(int, rng.integers(0, cfg.vocab_size, 8))),
            max_new_tokens=6,
        ))
    t0 = time.perf_counter()
    done = engine.shutdown()
    dt = time.perf_counter() - t0

    n_tokens = sum(len(c.tokens) - c.prompt_len for c in done)
    print(f"served {len(done)} requests / {n_tokens} tokens "
          f"({n_tokens / max(dt, 1e-9):.1f} tok/s tail-phase)")
    # verify a sample against offline greedy decode
    c = sorted(done, key=lambda c: c.rid)[0]
    prompt, gen = c.tokens[: c.prompt_len], c.tokens[c.prompt_len:]
    logits, cache = lm.prefill(cfg, params,
                               jnp.asarray(prompt, jnp.int32)[None],
                               max_seq=96)
    out = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
    last, clen = out[0], len(prompt)
    for _ in range(len(gen) - 1):
        lg, cache = lm.decode_step(cfg, params, cache,
                                   jnp.asarray([[last]], jnp.int32),
                                   jnp.int32(clen))
        last = int(jnp.argmax(lg[0, 0, : cfg.vocab_size]))
        clen += 1
        out.append(last)
    assert gen == out, "continuous batching must match offline decode"
    print(f"request {c.rid}: engine output == offline greedy decode "
          f"({len(gen)} tokens)")
    print(engine.timing.report())


if __name__ == "__main__":
    main()
