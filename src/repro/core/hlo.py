"""Parsing of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` does not report collective traffic, so the
roofline pipeline extracts it from ``compiled.as_text()`` directly: every
``all-reduce`` / ``all-gather`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op line carries its result shape and replica groups,
from which per-device link traffic follows (ring algorithm).

Shapes in the partitioned module are per-device shards, so the byte counts
derived here are *per device*; the roofline collective term is
``per_device_bytes / link_bw`` == the assignment's
``collective_bytes / (chips * link_bw)`` with global ``collective_bytes``.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-reduce.2 = f32[2,128,512]{2,1,0} all-reduce(%x), channel_id=1,
#       replica_groups=[4,16]<=[64], ...
_OP_RE = re.compile(
    r"=\s*(?P<shape>\(?[\w\[\],{} ]+?\)?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_ARRAY_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(?P<ngroups>\d+),(?P<gsize>\d+)\]<=")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{(?P<first>[\d,]+)\}")


def _array_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[dims] array in a shape string."""
    total = 0
    for m in _ARRAY_RE.finditer(text):
        dt = m.group("dtype")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int  # per-device bytes of the op result
    group_size: int
    line: str

    @property
    def link_bytes(self) -> float:
        """Per-device bytes moved over ICI links (ring algorithm).

        all-reduce moves 2*B*(g-1)/g (reduce-scatter + all-gather phases);
        all-gather's result IS the gathered array: B*(g-1)/g received;
        reduce-scatter's result is the shard: each device sends/receives
        ~B_result*(g-1); all-to-all exchanges (g-1)/g of the buffer;
        collective-permute forwards the whole buffer once.
        """
        g = max(self.group_size, 1)
        b = float(self.result_bytes)
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * b * (g - 1) / g
        if self.kind == "all-gather":
            return b * (g - 1) / g
        if self.kind == "reduce-scatter":
            return b * (g - 1)
        if self.kind == "all-to-all":
            return b * (g - 1) / g
        if self.kind == "collective-permute":
            return b
        return b


@dataclass
class CollectiveSummary:
    ops: list[CollectiveOp] = field(default_factory=list)

    @property
    def total_link_bytes(self) -> float:
        return sum(op.link_bytes for op in self.ops)

    @property
    def total_result_bytes(self) -> int:
        return sum(op.result_bytes for op in self.ops)

    def by_kind(self) -> dict[str, tuple[int, float]]:
        agg: dict[str, tuple[int, float]] = defaultdict(lambda: (0, 0.0))
        for op in self.ops:
            n, b = agg[op.kind]
            agg[op.kind] = (n + 1, b + op.link_bytes)
        return dict(agg)

    def schedule(self) -> list[str]:
        """The collective schedule in program order (kind x group size)."""
        return [f"{op.kind}(g={op.group_size}, {op.result_bytes}B)" for op in self.ops]

    def describe(self) -> str:
        lines = [f"{'kind':<22}{'count':>6}{'link MiB/device':>18}"]
        for kind, (n, b) in sorted(self.by_kind().items()):
            lines.append(f"{kind:<22}{n:>6}{b / 2**20:>18.3f}")
        lines.append(
            f"{'TOTAL':<22}{len(self.ops):>6}{self.total_link_bytes / 2**20:>18.3f}"
        )
        return "\n".join(lines)


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Extract all collective ops (with per-device sizes) from HLO text.

    Ops inside ``while`` bodies appear once; callers lowering scanned
    programs must scale by trip count themselves (the roofline pipeline
    lowers unrolled probes precisely to avoid that).
    """
    summary = CollectiveSummary()
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[0]:
            continue  # async completion op: counted at its -start
        kind = m.group("op")
        result_bytes = _array_bytes(m.group("shape"))
        g = 1
        gm = _IOTA_GROUPS_RE.search(line)
        if gm:
            g = int(gm.group("gsize"))
        else:
            gm = _EXPL_GROUPS_RE.search(line)
            if gm:
                g = len(gm.group("first").split(","))
        summary.ops.append(
            CollectiveOp(kind=kind, result_bytes=result_bytes, group_size=g, line=line)
        )
    return summary


def count_op(hlo_text: str, opname: str) -> int:
    """Count occurrences of an HLO op (e.g. 'fusion', 'while', 'custom-call')."""
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
