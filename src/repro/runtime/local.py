"""Executable emit/stages/collect network (the paper's Figure 2), local mode.

This is the runtime behind ``ClusterBuilder.build_application``: the wired
process network running as threads with bounded rendezvous channels on one
machine — precisely the paper's §6.1 *"operation and testing of a system can
be conducted on a single host node before using multiple nodes"* mode.  The
topology, the demand-driven client-server protocol (``onrl``/``nrfa``), the
one-place buffer invariant and Universal-Terminator shutdown are the ones
model-checked in ``core.verify``; this module is their operational twin.

Generalised to a :class:`~repro.core.dsl.PipelineSpec`: each stage is the
Figure-2 fragment, and stage *s*'s host-side merge (``afo``) feeds stage
*s+1*'s server through a one-place rendezvous queue — the same channel
discipline as Emit feeding the first stage, which is exactly how the chained
CSP model composes.  A ``ClusterSpec`` is accepted and normalised to its
one-stage pipeline view.

Worker functions are expected to be JAX/numpy computations: XLA releases the
GIL during execution, so worker threads genuinely overlap (Table 1 of the
paper is reproduced this way in ``benchmarks/``).

``readonly_delivery=True`` delivers work items as read-only ndarray views,
mirroring the cluster backend's zero-copy wire codec (whose decoded arrays
are immutable) — so an in-place-mutating work function fails here, on one
host, the same way it would on the real cluster.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any

from repro.core.builder import DeploymentPlan
from repro.core.timing import TimingCollector
from repro.runtime.failures import WorkFunctionError


class _UT:
    """Universal Terminator (paper §4, Listing 3 {3:21})."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "UT"


UT = _UT()


def _readonly_view(obj: Any) -> Any:
    """Recursively replace ndarrays with read-only views (no copy).

    Mirrors what the wire codec does to payloads: a bare ndarray decodes to
    a read-only ``np.frombuffer`` view, and ndarrays nested in containers
    arrive read-only through the ExtType path.  Non-array leaves pass
    through untouched.
    """
    import numpy as np

    if isinstance(obj, np.ndarray):
        view = obj.view()
        view.flags.writeable = False
        return view
    if isinstance(obj, dict):
        return {k: _readonly_view(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return tuple(_readonly_view(v) for v in obj)
    if isinstance(obj, list):
        return [_readonly_view(v) for v in obj]
    return obj


@dataclass
class LocalClusterApplication:
    spec: Any  # PipelineSpec (a ClusterSpec is normalised on construction)
    plan: DeploymentPlan
    timing: TimingCollector
    readonly_delivery: bool = False

    result: Any = None
    _ran: bool = False

    def __post_init__(self) -> None:
        if hasattr(self.spec, "as_pipeline"):
            self.spec = self.spec.as_pipeline()

    def run(self) -> Any:
        """Load the network, run to termination, return the finalised result."""
        if self._ran:
            raise RuntimeError("application already ran; build a fresh one")
        self._ran = True
        pipe = self.spec
        stages = pipe.stages
        S = len(stages)
        # Flat node ids in stage order ("node0".. — the one-stage case keeps
        # the historical naming), grouped per stage for wiring.
        assignments = pipe.node_assignments()
        stage_node_ids: list[list[str]] = [[] for _ in range(S)]
        for node_id, s in assignments:
            stage_node_ids[s].append(node_id)

        errors: list[BaseException] = []
        err_lock = threading.Lock()

        with self.timing.phase("host", "load"):
            # -- channel construction (input ends before output ends, §6) --
            # stage_in[s] is the a.s channel: emit -> server 0, and the
            # stage-to-stage rendezvous (reducer s-1 -> server s) otherwise.
            stage_in = [queue.Queue(maxsize=1) for _ in range(S)]
            request_q = [queue.Queue() for _ in range(S)]  # b.s many-to-one
            node_in = [
                [queue.Queue(maxsize=1) for _ in range(st.nclusters)]
                for st in stages
            ]  # c.s.i
            work_q = [
                [queue.Queue(maxsize=1) for _ in range(st.nclusters)]
                for st in stages
            ]  # d.s.i (one-place buffer)
            afoc_q = [
                [queue.Queue(maxsize=st.workers_per_node)
                 for _ in range(st.nclusters)]
                for st in stages
            ]  # e.s.i
            afo_q = [queue.Queue() for _ in range(S)]  # node merge -> afo_s
            collect_q: queue.Queue = queue.Queue()  # f

            threads: list[threading.Thread] = []

            def _spawn(fn, *args, name: str) -> None:
                t = threading.Thread(target=fn, args=args, name=name, daemon=True)
                threads.append(t)

            # ---- host: Emit ------------------------------------------------
            def emit_proc() -> None:
                details = pipe.emit.e_details
                state = details.initial_state()
                while True:
                    item, state = details.create(state)
                    if item is None:  # normalTermination
                        stage_in[0].put(UT)
                        return
                    stage_in[0].put(item)

            # ---- per stage: onrl (server) ----------------------------------
            def onrl_proc(s: int) -> None:
                n = stages[s].nclusters
                while True:
                    obj = stage_in[s].get()
                    if obj is UT:
                        # Server_End: answer each node's next request with UT.
                        for _ in range(n):
                            node = request_q[s].get()
                            node_in[s][node].put(UT)
                        return
                    node = request_q[s].get()  # wait for any node's request
                    node_in[s][node].put(obj)  # answer it in finite time

            # ---- per node: nrfa (client, one-place buffer) -----------------
            def nrfa_proc(s: int, j: int) -> None:
                node_id = stage_node_ids[s][j]
                w = stages[s].workers_per_node
                with self.timing.phase(node_id, "load"):
                    pass  # channel ends created above; record the touchpoint
                t0 = time.perf_counter()
                while True:
                    request_q[s].put(j)  # b!j.S — only after prior delivery
                    obj = node_in[s][j].get()  # c?j.o
                    if obj is UT:
                        for _ in range(w):
                            work_q[s][j].put(UT)
                        break
                    work_q[s][j].put(obj)  # d!j.o (blocks until a worker idles)
                self.timing.add(node_id, "run",
                                (time.perf_counter() - t0) * 1e3)

            # ---- per node: workers -----------------------------------------
            def worker_proc(s: int, j: int, _wi: int) -> None:
                fn = stages[s].function
                node_id = stage_node_ids[s][j]
                readonly = self.readonly_delivery
                while True:
                    obj = work_q[s][j].get()
                    if obj is UT:
                        afoc_q[s][j].put(UT)
                        return
                    try:
                        value = fn(_readonly_view(obj) if readonly else obj)
                    except BaseException as exc:
                        # Record and keep consuming: a worker that died here
                        # would strand UTs and hang the network; instead the
                        # run raises WorkFunctionError after shutdown —
                        # matching the cluster backend's fail-fast report.
                        with err_lock:
                            errors.append(exc)
                        continue
                    afoc_q[s][j].put(value)
                    self.timing.count_item(node_id)

            # ---- per node: afoc (merge workers, net output) ----------------
            def afoc_proc(s: int, j: int) -> None:
                remaining = stages[s].workers_per_node
                while remaining:
                    obj = afoc_q[s][j].get()
                    if obj is UT:
                        remaining -= 1
                        continue
                    afo_q[s].put(obj)
                afo_q[s].put(UT)  # single UT per node

            # ---- per stage: afo (merge nodes -> next stage / collect) ------
            def afo_proc(s: int) -> None:
                downstream = stage_in[s + 1] if s + 1 < S else collect_q
                remaining = stages[s].nclusters
                while remaining:
                    obj = afo_q[s].get()
                    if obj is UT:
                        remaining -= 1
                        continue
                    downstream.put(obj)
                downstream.put(UT)

            # ---- host: collect ---------------------------------------------
            def collect_proc() -> None:
                details = pipe.collector.r_details
                acc = details.init()
                while True:
                    obj = collect_q.get()
                    if obj is UT:
                        self.result = details.finalise(acc)
                        return
                    acc = details.collect(acc, obj)

            _spawn(emit_proc, name="emit")
            for s, st in enumerate(stages):
                _spawn(onrl_proc, s, name=f"onrl{s}")
                for j in range(st.nclusters):
                    _spawn(nrfa_proc, s, j, name=f"nrfa{s}.{j}")
                    for wi in range(st.workers_per_node):
                        _spawn(worker_proc, s, j, wi,
                               name=f"worker{s}.{j}.{wi}")
                    _spawn(afoc_proc, s, j, name=f"afoc{s}.{j}")
                _spawn(afo_proc, s, name=f"afo{s}")
            _spawn(collect_proc, name="collect")

        with self.timing.phase("host", "run"):
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        if errors:
            first = errors[0]
            self.result = None
            raise WorkFunctionError(
                f"work function raised: {type(first).__name__}: {first}"
            ) from first
        return self.result
