"""Pure-jnp oracle for the Mandelbrot escape-time computation.

This is the paper's workload (Appendix B, ``Mdata.calculateColour``): for
each point c = x + iy iterate z <- z^2 + c until |z|^2 >= 4 or the escape
value is reached.  The oracle mirrors the paper's loop exactly, vectorised:
``iterations`` counts loop trips (capped at ``max_iters``) and ``colour`` is
WHITE (1) when the point escaped, BLACK (0) otherwise — the paper's
convention {4:53}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mandelbrot_reference(x0: jax.Array, y0: jax.Array, max_iters: int):
    """x0, y0: f32 arrays of identical shape -> (iterations i32, colour i32)."""
    shape = x0.shape

    def body(_t, state):
        zx, zy, iters, alive = state
        zx2 = zx * zx
        zy2 = zy * zy
        alive_now = alive & ((zx2 + zy2) < 4.0)
        new_zx = zx2 - zy2 + x0
        new_zy = 2.0 * zx * zy + y0
        zx = jnp.where(alive_now, new_zx, zx)
        zy = jnp.where(alive_now, new_zy, zy)
        iters = iters + alive_now.astype(jnp.int32)
        return zx, zy, iters, alive_now

    zx = jnp.zeros(shape, jnp.float32)
    zy = jnp.zeros(shape, jnp.float32)
    iters = jnp.zeros(shape, jnp.int32)
    alive = jnp.ones(shape, bool)
    zx, zy, iters, alive = jax.lax.fori_loop(
        0, max_iters, body, (zx, zy, iters, alive)
    )
    colour = (iters < max_iters).astype(jnp.int32)  # WHITE=1 escaped
    return iters, colour


def line_coords(width: int, line_y: int, *, min_x=-2.5, min_y=1.0,
                range_x=3.5):
    """The paper's ``createInstance`` coordinate layout {4:26-39}."""
    delta = range_x / width
    x = min_x + jnp.arange(width, dtype=jnp.float32) * delta
    y = jnp.full((width,), min_y - line_y * delta, jnp.float32)
    return x, y


def grid_coords(height: int, width: int, **kw):
    xs, ys = [], []
    for r in range(height):
        x, y = line_coords(width, r, **kw)
        xs.append(x)
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)
