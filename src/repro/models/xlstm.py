"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory with memory mixing, sequential scan).

mLSTM recurrence per head (head dim ``d``)::

    C_t = f_t C_{t-1} + i_t v_t k_t^T          (matrix memory, d x d)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

with exponential input gate ``i = exp(itilde)``, forget gate
``f = sigmoid/exp`` and the max-stabiliser ``m_t``.  Training uses the
**chunkwise-parallel** form (intra-chunk quadratic + inter-chunk state),
the TPU-native formulation (same family as GLA/Mamba-2 SSD); decoding steps
the recurrence with O(1) state — hence xlstm runs ``long_500k``.

A step-by-step sequential reference (``mlstm_sequential``) is kept as the
oracle for the chunkwise implementation and the decode path.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, fan_in_normal


# ---------------------------------------------------------------------------
# mLSTM core
# ---------------------------------------------------------------------------


def mlstm_sequential(q, k, v, i_raw, f_raw, initial=None):
    """Oracle: step the recurrence. q/k/v: [B, S, H, D]; gates: [B, S, H].

    Returns (h [B, S, H, D], state (C, n, m)).
    """
    B, S, H, D = q.shape
    k = k / math.sqrt(D)
    if initial is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = initial

    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))

    def step(carry, t):
        C, n, m = carry
        qt = q[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        it = i_raw[:, t].astype(jnp.float32)
        ft = logf[:, t]
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhde,bhe->bhd", C, qt)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 1)  # [B, S, H, D]
    return h.astype(q.dtype), (C, n, m)


def mlstm_chunkwise(q, k, v, i_raw, f_raw, *, chunk: int = 64, initial=None,
                    unroll: bool = False):
    """Chunkwise-parallel mLSTM. Same signature/semantics as the oracle."""
    B, S, H, D = q.shape
    if S % chunk != 0:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    NC = S // chunk
    k = k / math.sqrt(D)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    ii = i_raw.astype(jnp.float32)

    if initial is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = initial

    def reshape_c(x, extra=()):  # [B, S, ...] -> [NC, B, chunk, ...]
        return jnp.moveaxis(x.reshape((B, NC, chunk) + extra), 1, 0)

    qs = reshape_c(q.astype(jnp.float32), (H, D))
    ks = reshape_c(k.astype(jnp.float32), (H, D))
    vs = reshape_c(v.astype(jnp.float32), (H, D))
    is_ = reshape_c(ii, (H,))
    fs = reshape_c(logf, (H,))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C, n, m = carry  # [B,H,D,D], [B,H,D], [B,H]
        qc, kc, vc, ic, fc = inp  # [B, chunk, H, ...]
        b = jnp.cumsum(fc, axis=1)  # [B, chunk, H] cumulative log-forget
        # g_i = cummax_{j<=i} (itilde_j - b_j); local max for stabilisation.
        g = jax.lax.cummax(ic - b, axis=1)
        m_loc = b + jnp.maximum(m[:, None, :], g)  # m_i, [B, chunk, H]
        # Intra-chunk decay matrix: D_ij = exp(b_i - b_j + i_j - m_i), j<=i.
        logD = (
            b[:, :, None, :] - b[:, None, :, :] + ic[:, None, :, :]
            - m_loc[:, :, None, :]
        )  # [B, i, j, H]
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        Dm = jnp.exp(logD)
        scores = jnp.einsum("bihd,bjhd->bijh", qc, kc) * Dm
        num_intra = jnp.einsum("bijh,bjhd->bihd", scores, vc)
        # n contribution: sum_{j<=i} D_ij k_j
        n_intra = jnp.einsum("bijh,bjhd->bihd", Dm, kc)
        # Inter-chunk: decay from carried state.
        inter_scale = jnp.exp(b + m[:, None, :] - m_loc)  # [B, chunk, H]
        num_inter = jnp.einsum("bihe,bhde->bihd", qc, C) * inter_scale[..., None]
        n_eff = n_intra + n[:, None, :, :] * inter_scale[..., None]
        num = num_intra + num_inter
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bihd,bihd->bih", n_eff, qc)),
            jnp.exp(-m_loc),
        )
        h = num / den[..., None]

        # -- state update to end of chunk ------------------------------------
        m_new = m_loc[:, -1, :]  # [B, H]
        b_last = b[:, -1:, :]  # [B, 1, H]
        w = jnp.exp(b_last - b + ic - m_new[:, None, :])  # [B, chunk, H]
        C_new = C * jnp.exp(b_last[:, 0] + m - m_new)[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", w, vc, kc
        )
        n_new = n * jnp.exp(b_last[:, 0] + m - m_new)[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", w, kc
        )
        return (C_new, n_new, m_new), h

    if unroll:
        carry = (C0, n0, m0)
        hs_list = []
        for ci in range(NC):
            carry, h_c = chunk_step(
                carry, (qs[ci], ks[ci], vs[ci], is_[ci], fs[ci])
            )
            hs_list.append(h_c)
        C, n, m = carry
        hs = jnp.stack(hs_list)
    else:
        (C, n, m), hs = jax.lax.scan(
            chunk_step, (C0, n0, m0), (qs, ks, vs, is_, fs)
        )
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, D)
    return h.astype(q.dtype), (C, n, m)


def mlstm_step(q1, k1, v1, i1, f1, state):
    """Single decode step: q1/k1/v1 [B, H, D]; gates [B, H]."""
    h, new_state = mlstm_sequential(
        q1[:, None], k1[:, None], v1[:, None], i1[:, None], f1[:, None],
        initial=state,
    )
    return h[:, 0], new_state


# ---------------------------------------------------------------------------
# sLSTM core (sequential; scalar memory with per-head memory mixing)
# ---------------------------------------------------------------------------


def slstm_scan(x_gates, r_weights, initial=None):
    """x_gates: dict of [B, S, H, D] pre-activations (i, f, z, o from the
    input projections); r_weights: dict of [H, D, D] recurrent (per-head
    block-diagonal) matrices.  Returns (h [B, S, H, D], state).
    """
    zi, fi, ii, oi = (x_gates[k] for k in ("z", "f", "i", "o"))
    B, S, H, D = zi.shape
    if initial is None:
        c0 = jnp.zeros((B, H, D), jnp.float32)
        n0 = jnp.ones((B, H, D), jnp.float32)
        m0 = jnp.zeros((B, H, D), jnp.float32)
        h0 = jnp.zeros((B, H, D), jnp.float32)
    else:
        c0, n0, m0, h0 = initial

    def step(carry, t):
        c, n, m, h = carry
        rz = jnp.einsum("bhd,hde->bhe", h, r_weights["z"].astype(jnp.float32))
        rf = jnp.einsum("bhd,hde->bhe", h, r_weights["f"].astype(jnp.float32))
        ri = jnp.einsum("bhd,hde->bhe", h, r_weights["i"].astype(jnp.float32))
        ro = jnp.einsum("bhd,hde->bhe", h, r_weights["o"].astype(jnp.float32))
        z = jnp.tanh(zi[:, t].astype(jnp.float32) + rz)
        f_raw = fi[:, t].astype(jnp.float32) + rf
        i_raw = ii[:, t].astype(jnp.float32) + ri
        o = jax.nn.sigmoid(oi[:, t].astype(jnp.float32) + ro)
        logf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(logf + m, i_raw)
        i_s = jnp.exp(i_raw - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0), jnp.arange(S))
    out = jnp.moveaxis(hs, 0, 1)  # [B, S, H, D]
    return out.astype(zi.dtype), (c, n, m, h)


# ---------------------------------------------------------------------------
# Blocks (projection structure around the cores)
# ---------------------------------------------------------------------------


def mlstm_block_specs(layers: int, d: int, heads: int, head_dim: int) -> dict:
    width = heads * head_dim
    return {
        "w_up": ParamSpec((layers, d, 2 * width), ("layers", "d_model_fsdp", "d_attn"),
                          stddev=fan_in_normal((d, width))),
        "conv1d": ParamSpec((layers, 4, width), ("layers", None, "d_attn"),
                            stddev=0.02),
        "w_q": ParamSpec((layers, width, width), ("layers", None, "d_attn"),
                         stddev=fan_in_normal((width, width))),
        "w_k": ParamSpec((layers, width, width), ("layers", None, "d_attn"),
                         stddev=fan_in_normal((width, width))),
        "w_v": ParamSpec((layers, width, width), ("layers", None, "d_attn"),
                         stddev=fan_in_normal((width, width))),
        "w_gates": ParamSpec((layers, width, 2 * heads), ("layers", "d_attn", None),
                             stddev=fan_in_normal((width, heads))),
        "norm": ParamSpec((layers, width), ("layers", "d_attn"), init="zeros"),
        "w_down": ParamSpec((layers, width, d), ("layers", "d_attn", "d_model_fsdp"),
                            stddev=fan_in_normal((width, d))),
    }


def slstm_block_specs(layers: int, d: int, heads: int, head_dim: int) -> dict:
    width = heads * head_dim
    return {
        "w_in": ParamSpec((layers, d, 4 * width), ("layers", "d_model_fsdp", "d_attn"),
                          stddev=fan_in_normal((d, width))),
        "r": {
            g: ParamSpec((layers, heads, head_dim, head_dim),
                         ("layers", "heads", None, None),
                         stddev=fan_in_normal((head_dim, head_dim)))
            for g in ("z", "f", "i", "o")
        },
        "norm": ParamSpec((layers, width), ("layers", "d_attn"), init="zeros"),
        "w_down": ParamSpec((layers, width, d), ("layers", "d_attn", "d_model_fsdp"),
                            stddev=fan_in_normal((width, d))),
    }


def _group_rms(x, scale, heads, eps=1e-6):
    """Per-head RMS norm over head_dim (GroupNorm analogue). x: [B,S,W]."""
    B, S, W = x.shape
    xh = x.reshape(B, S, heads, W // heads).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, W) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def mlstm_block(params, x, *, heads: int, chunk: int = 64,
                compute_dtype=jnp.bfloat16, state=None, unroll: bool = False):
    """x: [B, S, D] -> (out, new_state|None).  state: (conv, (C, n, m))."""
    from repro.models.recurrent import causal_conv1d

    B, S, D = x.shape
    xc = x.astype(compute_dtype)
    up = jnp.einsum("bsd,dw->bsw", xc, params["w_up"].astype(compute_dtype))
    width = up.shape[-1] // 2
    u, gate = up[..., :width], up[..., width:]
    conv_state = state[0] if state is not None else None
    uc, new_conv = causal_conv1d(params["conv1d"], u, conv_state)
    uc = jax.nn.silu(uc)
    hd = width // heads

    def heads_of(w):
        y = jnp.einsum("bsw,wu->bsu", uc, w.astype(compute_dtype))
        return y.reshape(B, S, heads, hd)

    q, k = heads_of(params["w_q"]), heads_of(params["w_k"])
    v = jnp.einsum("bsw,wu->bsu", u, params["w_v"].astype(compute_dtype)).reshape(
        B, S, heads, hd
    )
    gates = jnp.einsum("bsw,wg->bsg", uc, params["w_gates"].astype(compute_dtype))
    i_raw, f_raw = gates[..., :heads], gates[..., heads:]
    if state is not None:
        h, new_core = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                 i_raw[:, 0], f_raw[:, 0], state[1])
        h = h[:, None]
    else:
        h, new_core = mlstm_chunkwise(q, k, v, i_raw, f_raw,
                                      chunk=min(chunk, S), unroll=unroll)
    h = h.reshape(B, S, width)
    h = _group_rms(h, params["norm"], heads)
    h = h * jax.nn.silu(gate)
    out = jnp.einsum("bsw,wd->bsd", h.astype(compute_dtype),
                     params["w_down"].astype(compute_dtype))
    return out.astype(x.dtype), (new_conv, new_core)


def slstm_block(params, x, *, heads: int, compute_dtype=jnp.bfloat16, state=None):
    """x: [B, S, D] -> (out, new_state|None).  state: (c, n, m, h)."""
    B, S, D = x.shape
    xc = x.astype(compute_dtype)
    pre = jnp.einsum("bsd,dw->bsw", xc, params["w_in"].astype(compute_dtype))
    width = pre.shape[-1] // 4
    hd = width // heads

    def split(idx):
        g = pre[..., idx * width : (idx + 1) * width]
        return g.reshape(B, S, heads, hd)

    gates = {"z": split(0), "f": split(1), "i": split(2), "o": split(3)}
    r = {k: params["r"][k] for k in ("z", "f", "i", "o")}
    h, new_state_core = slstm_scan(gates, r, initial=state)
    h = h.reshape(B, S, width)
    h = _group_rms(h, params["norm"], heads)
    out = jnp.einsum("bsw,wd->bsd", h.astype(compute_dtype),
                     params["w_down"].astype(compute_dtype))
    return out.astype(x.dtype), new_state_core
