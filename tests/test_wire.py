"""The wire codecs (repro.cluster.wire), in isolation.

Property tests over the zero-copy ndarray codec (codec 2): dtypes, 0-d,
empty, non-contiguous and Fortran-order arrays, arrays nested inside
msgpack payloads (ExtType), and the fallback ladder (object arrays and
tuples -> pickle).  Runs under real hypothesis when installed, else under
the deterministic fallback installed by conftest.py.

Also the regression for the deep-nesting guard: a payload too deep for any
codec must raise a clear ValueError, not a RecursionError from inside a
serializer.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import wire
from repro.cluster.wire import (
    DEFAULT_HEARTBEAT_S,
    Frame,
    FrameType,
    _CodecId,
    encode_payload,
    pack_frame,
    unpack_frame,
)

DTYPES = ["float32", "float64", "int32", "uint8", "bool"]


def _roundtrip(payload):
    return unpack_frame(pack_frame(Frame(FrameType.RESULT, payload))).payload


def _codec_of(payload) -> int:
    return encode_payload(payload)[0]


# ---------------------------------------------------------------------------
# ndarray codec properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    dtype=st.sampled_from(DTYPES),
    shape=st.lists(st.integers(0, 5), min_size=0, max_size=3),
)
def test_ndarray_roundtrip_dtypes_and_shapes(dtype, shape):
    """Any dtype x shape (including 0-d and empty) round-trips exactly on
    the ndarray codec — values, dtype, and shape all preserved."""
    rng = np.random.default_rng(0)
    a = np.asarray(rng.random(tuple(shape)) * 100, dtype=dtype)
    assert _codec_of(a) == _CodecId.NDARRAY
    b = _roundtrip(a)
    assert b.dtype == a.dtype
    assert b.shape == a.shape
    assert np.array_equal(b, a)


@settings(max_examples=20, deadline=None)
@given(dtype=st.sampled_from(DTYPES), rows=st.integers(1, 6),
       cols=st.integers(1, 6))
def test_ndarray_roundtrip_fortran_and_noncontiguous(dtype, rows, cols):
    base = (np.arange(rows * cols * 4) % 7).astype(dtype).reshape(
        rows * 2, cols * 2
    )

    fortran = np.asfortranarray(base)
    assert fortran.flags.f_contiguous
    b = _roundtrip(fortran)
    assert np.array_equal(b, fortran) and b.shape == fortran.shape

    sliced = base[::2, ::2]  # non-contiguous view: pays one compaction copy
    assert not sliced.flags.c_contiguous
    b = _roundtrip(sliced)
    assert np.array_equal(b, sliced) and b.dtype == sliced.dtype


def test_ndarray_zero_copy_encode_for_contiguous():
    """The payload buffer of a contiguous array is a view of the array's own
    memory, not a copy."""
    a = np.arange(32, dtype=np.float32)
    codec, bufs = encode_payload(a)
    assert codec == _CodecId.NDARRAY
    raw = bufs[-1]
    assert isinstance(raw, memoryview)
    assert raw.obj is a or getattr(raw.obj, "base", None) is a


def test_ndarray_nested_in_msgpack_payload():
    """Arrays inside protocol dicts ride the msgpack ExtType, keeping the
    enclosing payload on the cheap codec."""
    a = np.linspace(0.0, 1.0, 7, dtype=np.float64)
    payload = {"id": 3, "value": a, "node_id": "node0"}
    assert _codec_of(payload) == _CodecId.MSGPACK
    back = _roundtrip(payload)
    assert back["id"] == 3 and back["node_id"] == "node0"
    assert np.array_equal(back["value"], a)


def test_empty_array_nested_in_msgpack_payload():
    """Regression: a size-0 array inside a dict used to crash the ExtType
    hook (bytes has no .tobytes) instead of encoding."""
    payload = {"id": 1, "value": np.empty(0, dtype=np.float32)}
    back = _roundtrip(payload)
    assert back["value"].shape == (0,) and back["value"].dtype == np.float32


def test_structured_and_datetime_dtypes_fall_back_to_pickle():
    """Regression: dtype.str cannot express record fields ('|V8' would
    silently drop names) and datetime64 refuses buffer export — both must
    ride pickle, preserving exact round-trips."""
    rec = np.zeros(3, dtype=[("x", "<f4"), ("y", "<i4")])
    rec["x"] = [1.0, 2.0, 3.0]
    assert _codec_of(rec) == _CodecId.PICKLE
    back = _roundtrip({"value": rec})["value"]
    assert back.dtype == rec.dtype
    assert np.array_equal(back["x"], rec["x"])

    dt = np.array(["2026-08-02", "2026-08-03"], dtype="datetime64[D]")
    assert _codec_of(dt) == _CodecId.PICKLE
    assert np.array_equal(_roundtrip(dt), dt)


def test_object_array_falls_back_to_pickle():
    o = np.array([{"a": 1}, None, (2, 3)], dtype=object)
    assert _codec_of(o) == _CodecId.PICKLE
    back = _roundtrip(o)
    assert back.dtype == object and list(back) == list(o)


def test_jax_array_ships_on_ndarray_codec():
    jnp = pytest.importorskip("jax.numpy")
    a = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    assert _codec_of(a) == _CodecId.NDARRAY
    back = _roundtrip(a)
    assert np.array_equal(back, np.asarray(a))


# ---------------------------------------------------------------------------
# single-pass encoder fallback ladder
# ---------------------------------------------------------------------------


def test_tuples_keep_exactness_via_pickle():
    payload = {"id": 1, "obj": (1, 2, [3, (4,)])}
    assert _codec_of(payload) == _CodecId.PICKLE
    back = _roundtrip(payload)
    assert back["obj"] == (1, 2, [3, (4,)])
    assert isinstance(back["obj"], tuple)


def test_plain_payloads_stay_on_msgpack():
    payload = {"node_id": "node0", "credits": 4,
               "results": [{"id": 0, "value": 1.5}]}
    assert _codec_of(payload) == _CodecId.MSGPACK
    assert _roundtrip(payload) == payload


def test_big_int_and_int_keys_roundtrip():
    assert _roundtrip({"value": 2**70})["value"] == 2**70
    assert _roundtrip({1: "a", "b": 2}) == {1: "a", "b": 2}


def test_deeply_nested_payload_raises_clear_error():
    """Regression: unbounded recursion in payload encoding used to surface
    as a RecursionError masquerading as a wire failure."""
    deep = []
    for _ in range(100_000):
        deep = [deep]
    with pytest.raises(ValueError, match="nested too deeply"):
        pack_frame(Frame(FrameType.WORK, deep))


# ---------------------------------------------------------------------------
# batched frame types + shared heartbeat constant
# ---------------------------------------------------------------------------


def test_batch_frames_roundtrip():
    items = [{"id": i, "obj": i * i} for i in range(5)]
    g = unpack_frame(pack_frame(
        Frame(FrameType.WORK_BATCH, {"items": items})
    ))
    assert g.ftype is FrameType.WORK_BATCH and g.payload["items"] == items

    results = {"node_id": "n0", "credits": 2,
               "results": [{"id": 0, "value": 9}, {"id": 1, "value": 16}]}
    g = unpack_frame(pack_frame(Frame(FrameType.RESULT_BATCH, results)))
    assert g.ftype is FrameType.RESULT_BATCH and g.payload == results


def test_heartbeat_interval_shared_between_sides():
    """Satellite regression: the node beacon's pre-LOAD interval and the
    host monitor default must be the same constant."""
    from repro.runtime.failures import HeartbeatMonitor

    assert HeartbeatMonitor().interval_s == DEFAULT_HEARTBEAT_S


# ---------------------------------------------------------------------------
# job_id header field (wire v2, multi-job multiplexing)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    job_id=st.integers(0, 2**32 - 1),
    ftype=st.sampled_from([FrameType.WORK_BATCH, FrameType.RESULT_BATCH,
                           FrameType.LOAD, FrameType.JOB_CLOSE,
                           FrameType.WORK_REQUEST, FrameType.UT]),
)
def test_job_id_roundtrips_on_every_frame_type(job_id, ftype):
    """The v2 header's job tag survives pack/unpack for the full 32-bit
    range on every frame type, independent of payload codec."""
    f = Frame(ftype, {"node_id": "n0"}, wire.APP_WIRE_CHANNEL, job_id=job_id)
    g = unpack_frame(pack_frame(f))
    assert g.job_id == job_id
    assert g.ftype is ftype and g.channel == wire.APP_WIRE_CHANNEL


def test_job_id_defaults_to_zero():
    """job_id 0 = "no job": bootstrap and pool-control frames need no tag,
    and pre-service callers never mention it."""
    g = unpack_frame(pack_frame(Frame(FrameType.REGISTER, {"node_id": "n"})))
    assert g.job_id == 0


@settings(max_examples=20, deadline=None)
@given(
    job_id=st.integers(1, 2**32 - 1),
    dtype=st.sampled_from(DTYPES),
    n=st.integers(0, 16),
)
def test_job_id_roundtrips_with_ndarray_batches(job_id, dtype, n):
    """A codec-2 (zero-copy ndarray) result batch keeps its job tag — the
    header field and the multi-buffer payload path must not interfere."""
    a = (np.arange(n * 3) % 11).astype(dtype).reshape(n, 3)
    f = Frame(FrameType.RESULT_BATCH, a, wire.APP_WIRE_CHANNEL,
              job_id=job_id)
    g = unpack_frame(pack_frame(f))
    assert g.job_id == job_id
    assert np.array_equal(g.payload, a) and g.payload.dtype == a.dtype

    nested = {"node_id": "n0", "credits": 1,
              "results": [{"id": 0, "s": 0, "value": a}]}
    g = unpack_frame(pack_frame(
        Frame(FrameType.RESULT_BATCH, nested, wire.APP_WIRE_CHANNEL,
              job_id=job_id)
    ))
    assert g.job_id == job_id
    assert np.array_equal(g.payload["results"][0]["value"], a)


def test_wire_counters_track_traffic():
    import socket

    from repro.cluster.wire import FrameConnection

    a, b = socket.socketpair()
    left, right = FrameConnection(a), FrameConnection(b)
    try:
        f = Frame(FrameType.HEARTBEAT, {"node_id": "n"}, wire.LOAD_WIRE_CHANNEL)
        left.send(f)
        got = right.recv()
        assert got.payload == {"node_id": "n"}
        assert left.counters.frames_sent == 1
        assert right.counters.frames_recv == 1
        assert left.counters.bytes_sent == right.counters.bytes_recv > 0
    finally:
        left.close()
        right.close()
