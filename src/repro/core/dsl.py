"""The ClusterBuilder DSL.

The paper's DSL (Listing 1) is a Groovy source file with three cluster
annotations::

    01. ... constants used in definition
    02. //@emit host-ip
    03. ... emit process definition
    04. //@cluster Nclusters
    05. ... cluster process definition
    06. //@collect
    07. ... collect process definition

We keep the textual front end *faithful* — a ``.cgpp`` file with the same
``//@emit`` / ``//@cluster`` / ``//@collect`` annotations, whose sections are
Python instead of Groovy — and we additionally expose the same structure as a
plain Python API (:class:`ClusterSpec`).  Both produce identical specs; the
builder (``core.builder``) consumes a spec and derives the entire deployment
(requirements 3, 4 and 6: minimal user code, automatic network construction,
no knowledge of the interconnect).

Beyond the paper, the spec layer generalises the single
emit → cluster → collect topology to an ordered *pipeline* of stages
(:class:`PipelineSpec`): one emit, N chained cluster stages, one collect.
Three front ends produce it:

* the extended grammar — ``//@stage <name> <N>`` sections, repeatable,
  in place of the single ``//@cluster N`` (which still parses, as the
  one-stage special case);
* the fluent API —
  ``Pipeline(host=...).emit(d).stage(f, nodes=2, workers=4).stage(g)
  .collect(r).build()``;
* :meth:`PipelineSpec.simple` from a list of :class:`Stage` records.

:class:`ClusterSpec` is unchanged and remains the one-stage special case;
``ClusterSpec.as_pipeline()`` is the thin bridge every runtime consumes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

from repro.core.processes import (
    AnyFanOne,
    AnyGroupAny,
    Collect,
    Emit,
    EmitDetails,
    HostNetwork,
    NodeNetwork,
    NodeRequestingFanAny,
    OneNodeRequestedList,
    ProcessRecord,
    ResultDetails,
    StageNetwork,
)

_EMIT_RE = re.compile(r"^//@emit\s+(?P<host>\S+)\s*$")
_CLUSTER_RE = re.compile(r"^//@cluster\s+(?P<n>\S+)\s*$")
_STAGE_RE = re.compile(r"^//@stage\s+(?P<name>[A-Za-z_]\w*)\s+(?P<n>\S+)\s*$")
_COLLECT_RE = re.compile(r"^//@collect\s*$")


@dataclass
class ClusterSpec:
    """A parsed/constructed ClusterBuilder application specification.

    Attributes:
      host: IP (or symbolic name) of the host node — the only piece of
        network knowledge the user must supply (requirement 6).
      nclusters: number of cluster nodes (``//@cluster N``).
      workers_per_node: worker processes per node ("cores" in Listing 2).
      host_net / node_net: the declarative process records.
      constants: the constants section of the DSL file, for provenance.
    """

    host: str
    nclusters: int
    host_net: HostNetwork
    node_net: NodeNetwork
    constants: dict[str, Any] = field(default_factory=dict)

    @property
    def workers_per_node(self) -> int:
        return self.node_net.group.workers

    @property
    def total_workers(self) -> int:
        return self.nclusters * self.workers_per_node

    def validate(self) -> None:
        """Static validation of the canonical emit->cluster->collect topology.

        The paper's builder only accepts well-formed specs; violations are
        caught *before* deployment (this mirrors gppBuilder's checks).
        """
        if self.nclusters < 1:
            raise ValueError(f"nclusters must be >= 1, got {self.nclusters}")
        if self.workers_per_node < 1:
            raise ValueError(
                f"workers per node must be >= 1, got {self.workers_per_node}"
            )
        if self.host_net.afo.sources != self.nclusters:
            raise ValueError(
                "host AnyFanOne.sources must equal nclusters "
                f"({self.host_net.afo.sources} != {self.nclusters}); the "
                "result-merge process reads one stream per node"
            )
        # NodeNetwork.__post_init__ already enforced intra-node consistency.
        if not callable(self.node_net.group.function):
            raise TypeError("cluster group function must be callable")

    def as_pipeline(self) -> "PipelineSpec":
        """View this spec as the one-stage special case of a pipeline.

        Every runtime consumes a :class:`PipelineSpec`; this bridge is what
        keeps the paper-faithful ClusterSpec API working unchanged on top of
        the generalised machinery.
        """
        return PipelineSpec(
            host=self.host,
            emit=self.host_net.emit,
            stages=[
                StageNetwork(
                    name="cluster",
                    nclusters=self.nclusters,
                    node_net=self.node_net,
                    onrl=self.host_net.onrl,
                    afo=self.host_net.afo,
                )
            ],
            collector=self.host_net.collector,
            constants=dict(self.constants),
        )

    # -- convenience constructor -------------------------------------------

    @staticmethod
    def simple(
        *,
        host: str,
        nclusters: int,
        workers_per_node: int,
        emit_details: EmitDetails,
        work_function: Callable[[Any], Any],
        result_details: ResultDetails,
        constants: Mapping[str, Any] | None = None,
    ) -> "ClusterSpec":
        """Build the canonical network of Figure 2 from user callables only."""
        host_net = HostNetwork(
            emit=Emit(e_details=emit_details),
            onrl=OneNodeRequestedList(),
            afo=AnyFanOne(sources=nclusters),
            collector=Collect(r_details=result_details),
        )
        node_net = NodeNetwork(
            nrfa=NodeRequestingFanAny(destinations=workers_per_node),
            group=AnyGroupAny(workers=workers_per_node, function=work_function),
            afoc=AnyFanOne(sources=workers_per_node),
        )
        spec = ClusterSpec(
            host=host,
            nclusters=nclusters,
            host_net=host_net,
            node_net=node_net,
            constants=dict(constants or {}),
        )
        spec.validate()
        return spec


# ---------------------------------------------------------------------------
# The generalised spec: an ordered pipeline of stages.
# ---------------------------------------------------------------------------


@dataclass
class Stage:
    """User-facing stage descriptor for the fluent / ``simple`` APIs.

    A stage is ``nclusters`` nodes, each running ``workers_per_node``
    workers that apply ``fn`` to every item the stage receives.  The process
    records (nrfa/group/afoc + host-side onrl/afo) are derived, exactly as
    ``ClusterSpec.simple`` derives the Figure-2 network.
    """

    name: str
    fn: Callable[[Any], Any]
    nclusters: int = 1
    workers_per_node: int = 1
    # Per-stage data-plane overrides; None inherits the cluster-wide values
    # given to the runtime (HostLoader prefetch / flush_interval).
    prefetch: int | None = None
    flush_ms: float | None = None
    # How this stage receives its input hop: None/"host" relays through the
    # host, "peer" ships node-to-node (key_fn turns the hop into a keyed
    # shuffle).  Only meaningful on the cluster/service backends; the
    # threads backend ignores routing (it has no wire).
    route: str | None = None
    key_fn: Callable[[Any], Any] | None = None

    def to_network(self) -> StageNetwork:
        w = self.workers_per_node
        return StageNetwork(
            name=self.name,
            nclusters=self.nclusters,
            node_net=NodeNetwork(
                nrfa=NodeRequestingFanAny(destinations=w),
                group=AnyGroupAny(workers=w, function=self.fn),
                afoc=AnyFanOne(sources=w),
            ),
            prefetch=self.prefetch,
            flush_ms=self.flush_ms,
            route=self.route,
            key_fn=self.key_fn,
        )


@dataclass
class PipelineSpec:
    """A multi-stage ClusterBuilder specification.

    One emit, an ordered list of cluster stages, one collect.  Each result
    of stage *s* becomes one work item of stage *s+1* (the final stage's
    results are folded by the collector), so the single-stage case is
    byte-for-byte the paper's topology — :class:`ClusterSpec` converts via
    ``as_pipeline()`` and all three backends consume only this form.
    """

    host: str
    emit: Emit
    stages: list[StageNetwork]
    collector: Collect
    constants: dict[str, Any] = field(default_factory=dict)

    # -- shape ---------------------------------------------------------------

    @property
    def nstages(self) -> int:
        return len(self.stages)

    @property
    def total_nodes(self) -> int:
        return sum(st.nclusters for st in self.stages)

    @property
    def total_workers(self) -> int:
        return sum(st.nclusters * st.workers_per_node for st in self.stages)

    def node_assignments(self) -> list[tuple[str, int]]:
        """Flat ``(node_id, stage_index)`` assignment, stage order.

        Node ids stay ``node0..node{K-1}`` so the one-stage case reproduces
        the historical naming exactly (timing records, tests, logs).
        """
        out: list[tuple[str, int]] = []
        i = 0
        for s, st in enumerate(self.stages):
            for _ in range(st.nclusters):
                out.append((f"node{i}", s))
                i += 1
        return out

    def stage_of(self, node_id: str) -> int:
        """Stage index a node id belongs to.

        Respawn replacements (``node3r1``) map to their base id; unknown
        ids (elastic late joiners) default to stage 0.
        """
        mapping = dict(self.node_assignments())
        if node_id in mapping:
            return mapping[node_id]
        base = node_id.split("r", 1)[0]
        return mapping.get(base, 0)

    # -- one-stage compatibility views ---------------------------------------

    def _single(self) -> StageNetwork:
        if len(self.stages) != 1:
            raise ValueError(
                f"pipeline has {len(self.stages)} stages; the one-stage "
                "accessors (nclusters/workers_per_node/node_net) do not "
                "apply — iterate .stages"
            )
        return self.stages[0]

    @property
    def nclusters(self) -> int:
        return self._single().nclusters

    @property
    def workers_per_node(self) -> int:
        return self._single().workers_per_node

    @property
    def node_net(self) -> NodeNetwork:
        return self._single().node_net

    @property
    def host_net(self) -> HostNetwork:
        """The host-side record group (first stage's server feeds it, last
        stage's merge drains into the collector)."""
        return HostNetwork(
            emit=self.emit,
            onrl=self.stages[0].onrl,
            afo=self.stages[-1].afo,
            collector=self.collector,
        )

    def as_pipeline(self) -> "PipelineSpec":
        return self

    def as_cluster_spec(self) -> ClusterSpec:
        """Collapse a one-stage pipeline back to the paper's ClusterSpec."""
        st = self._single()
        return ClusterSpec(
            host=self.host,
            nclusters=st.nclusters,
            host_net=self.host_net,
            node_net=st.node_net,
            constants=dict(self.constants),
        )

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        if not self.stages:
            raise ValueError("pipeline must have at least one stage")
        names = [st.name for st in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        for st in self.stages:
            if st.nclusters < 1:
                raise ValueError(
                    f"stage {st.name!r}: nclusters must be >= 1"
                )
            if st.workers_per_node < 1:
                raise ValueError(
                    f"stage {st.name!r}: workers per node must be >= 1"
                )
            if st.afo.sources != st.nclusters:
                raise ValueError(
                    f"stage {st.name!r}: AnyFanOne.sources must equal "
                    f"nclusters ({st.afo.sources} != {st.nclusters}); the "
                    "merge reads one stream per node"
                )
            if not callable(st.node_net.group.function):
                raise TypeError(
                    f"stage {st.name!r}: group function must be callable"
                )
        for s, st in enumerate(self.stages):
            route = getattr(st, "route", None)
            if route not in (None, "host", "peer"):
                raise ValueError(
                    f"stage {st.name!r}: route must be None, 'host' or "
                    f"'peer', got {route!r}"
                )
            key_fn = getattr(st, "key_fn", None)
            if key_fn is not None and route != "peer":
                raise ValueError(
                    f"stage {st.name!r}: key_fn only applies to "
                    "route='peer' hops"
                )
            if key_fn is not None and not callable(key_fn):
                raise TypeError(
                    f"stage {st.name!r}: key_fn must be callable"
                )
            if route == "peer" and s == 0:
                raise ValueError(
                    f"stage {st.name!r}: the first stage cannot use "
                    "route='peer' — its input comes from the host-side "
                    "emit, which has no peer edge"
                )

    def peer_routed_hops(self) -> dict[int, dict]:
        """Source stage -> hop descriptor for every ``route='peer'`` hop.

        Keyed by the *sending* stage ``s`` (the hop ``s -> s+1``); the
        ``route`` knob itself sits on the receiving stage.  The runtime
        builds routing tables from this, ``verify_spec`` the peer-channel
        model.
        """
        hops: dict[int, dict] = {}
        for s1, st in enumerate(self.stages):
            if getattr(st, "route", None) == "peer":
                hops[s1 - 1] = {"key_fn": getattr(st, "key_fn", None)}
        return hops

    # -- convenience constructor ---------------------------------------------

    @staticmethod
    def simple(
        *,
        host: str,
        emit_details: EmitDetails,
        stages: Sequence[Stage],
        result_details: ResultDetails,
        constants: Mapping[str, Any] | None = None,
    ) -> "PipelineSpec":
        spec = PipelineSpec(
            host=host,
            emit=Emit(e_details=emit_details),
            stages=[s.to_network() for s in stages],
            collector=Collect(r_details=result_details),
            constants=dict(constants or {}),
        )
        spec.validate()
        return spec


class Pipeline:
    """Fluent builder for :class:`PipelineSpec`.

    ::

        spec = (Pipeline(host="192.168.1.176")
                .emit(EmitDetails(...))
                .stage(render, nodes=2, workers=4)
                .stage(reduce_line)
                .collect(ResultDetails(...))
                .build())

    Each call returns the builder; ``build()`` validates completeness and
    produces the spec.  The one-stage form is exactly
    ``ClusterSpec.simple`` with different spelling.
    """

    def __init__(self, host: str, constants: Mapping[str, Any] | None = None):
        self._host = host
        self._constants = dict(constants or {})
        self._emit: EmitDetails | None = None
        self._stages: list[Stage] = []
        self._collect: ResultDetails | None = None

    def emit(self, details: EmitDetails) -> "Pipeline":
        if self._emit is not None:
            raise ValueError("emit() already called; a pipeline has one emit")
        if not isinstance(details, EmitDetails):
            raise TypeError(f"emit() takes EmitDetails, got {type(details)}")
        self._emit = details
        return self

    def stage(
        self,
        fn: Callable[[Any], Any],
        *,
        nodes: int = 1,
        workers: int = 1,
        name: str | None = None,
        prefetch: int | None = None,
        flush_ms: float | None = None,
        route: str | None = None,
        key_fn: Callable[[Any], Any] | None = None,
    ) -> "Pipeline":
        if self._collect is not None:
            raise ValueError("stage() must precede collect()")
        if self._emit is None:
            raise ValueError("emit() must precede the first stage()")
        name = name or f"stage{len(self._stages)}"
        if any(s.name == name for s in self._stages):
            raise ValueError(f"duplicate stage name {name!r}")
        if prefetch is not None and prefetch < 0:
            raise ValueError(f"stage {name!r}: prefetch must be >= 0")
        if flush_ms is not None and flush_ms < 0:
            raise ValueError(f"stage {name!r}: flush_ms must be >= 0")
        if route not in (None, "host", "peer"):
            raise ValueError(
                f"stage {name!r}: route must be None, 'host' or 'peer', "
                f"got {route!r}"
            )
        if key_fn is not None and route != "peer":
            raise ValueError(
                f"stage {name!r}: key_fn only applies to route='peer' hops"
            )
        if route == "peer" and not self._stages:
            raise ValueError(
                f"stage {name!r}: the first stage cannot use route='peer' — "
                "its input comes from the host-side emit"
            )
        self._stages.append(
            Stage(name=name, fn=fn, nclusters=nodes, workers_per_node=workers,
                  prefetch=prefetch, flush_ms=flush_ms, route=route,
                  key_fn=key_fn)
        )
        return self

    def collect(self, details: ResultDetails) -> "Pipeline":
        if self._collect is not None:
            raise ValueError("collect() already called; a pipeline has one "
                             "collect")
        if not isinstance(details, ResultDetails):
            raise TypeError(
                f"collect() takes ResultDetails, got {type(details)}"
            )
        self._collect = details
        return self

    def build(self) -> PipelineSpec:
        if self._emit is None:
            raise ValueError("pipeline is missing emit(...)")
        if not self._stages:
            raise ValueError("pipeline is missing at least one stage(...)")
        if self._collect is None:
            raise ValueError("pipeline is missing collect(...)")
        return PipelineSpec.simple(
            host=self._host,
            emit_details=self._emit,
            stages=self._stages,
            result_details=self._collect,
            constants=self._constants,
        )


def parse_cgpp(
    text: str, namespace: Mapping[str, Any] | None = None
) -> ClusterSpec | PipelineSpec:
    """Parse a ``.cgpp`` DSL file into a :class:`ClusterSpec`.

    The file has four sections delimited by the three annotations, exactly as
    Listing 1.  Section bodies are executed as Python with the process record
    classes pre-bound (the paper binds the Groovy GPP classes the same way via
    the ``cgpp`` file association, §6.1).  ``namespace`` supplies the user's
    data classes (e.g. ``Mdata``/``Mcollect`` equivalents).

    Two grammars share the frame:

    * **legacy** (Listing 1): one ``//@cluster N`` section → a
      :class:`ClusterSpec`, exactly as before;
    * **staged**: one or more ``//@stage <name> <N>`` sections in place of
      ``//@cluster`` → a :class:`PipelineSpec`.  Each stage section defines
      its ``AnyGroupAny`` (the nrfa/afoc records may be spelled out or are
      synthesised from ``group.workers``); the host-side per-stage server
      and merge are always synthesised, so the collect section needs only
      the ``Collect`` record.  The two forms cannot be mixed.
    """
    sections: dict[str, list[str]] = {
        "constants": [],
        "emit": [],
        "cluster": [],
        "collect": [],
    }
    # (name, n_expr, lineno, body lines) per //@stage section, in order.
    stage_sections: list[tuple[str, str, int, list[str]]] = []
    host: str | None = None
    ncluster_expr: str | None = None
    current = "constants"
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        stripped = line.strip()
        m = _EMIT_RE.match(stripped)
        if m:
            if current != "constants":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — "
                    + ("duplicate //@emit annotation" if host is not None
                       else "//@emit must appear before //@cluster and //@collect")
                )
            host = m.group("host")
            current = "emit"
            continue
        m = _CLUSTER_RE.match(stripped)
        if m:
            if stage_sections:
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — cannot mix //@cluster "
                    "with //@stage sections; use one grammar"
                )
            if current != "emit":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — "
                    + ("duplicate //@cluster annotation"
                       if ncluster_expr is not None
                       else "//@cluster must follow the emit section")
                )
            ncluster_expr = m.group("n")
            current = "cluster"
            continue
        m = _STAGE_RE.match(stripped)
        if m:
            if ncluster_expr is not None:
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — cannot mix //@stage "
                    "with a //@cluster section; use one grammar"
                )
            if current == "collect":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — //@stage must precede "
                    "//@collect"
                )
            if current not in ("emit", "stage"):
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — //@stage must follow "
                    "the emit section"
                )
            name = m.group("name")
            if any(name == s[0] for s in stage_sections):
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — duplicate //@stage "
                    f"{name!r} annotation"
                )
            stage_sections.append((name, m.group("n"), lineno, []))
            current = "stage"
            continue
        if _COLLECT_RE.match(stripped):
            if current == "collect":
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — duplicate //@collect "
                    "annotation"
                )
            if current not in ("cluster", "stage"):
                raise SyntaxError(
                    f"line {lineno}: {stripped!r} — //@collect must follow "
                    "the cluster (or final stage) section"
                )
            current = "collect"
            continue
        if stripped.startswith("//@"):
            # An annotation-looking line that matched none of the known
            # forms: report it rather than silently treating it as code.
            raise SyntaxError(
                f"line {lineno}: malformed annotation {stripped!r} — "
                "expected '//@emit <host-ip>', '//@cluster <N>', "
                "'//@stage <name> <N>' or '//@collect'"
            )
        if current == "stage":
            stage_sections[-1][3].append(line)
        else:
            sections[current].append(line)

    if host is None:
        raise SyntaxError("missing //@emit <host-ip> annotation")
    if ncluster_expr is None and not stage_sections:
        raise SyntaxError(
            "missing //@cluster <N> (or //@stage <name> <N>) annotation"
        )
    if current != "collect":
        raise SyntaxError("missing //@collect annotation")

    env: dict[str, Any] = {
        # Process records, bound like the GPP classes in the paper's IDE setup.
        "Emit": Emit,
        "OneNodeRequestedList": OneNodeRequestedList,
        "NodeRequestingFanAny": NodeRequestingFanAny,
        "AnyGroupAny": AnyGroupAny,
        "AnyFanOne": AnyFanOne,
        "Collect": Collect,
        "EmitDetails": EmitDetails,
        "DataDetails": EmitDetails,  # paper's name for the emit-side details
        "ResultDetails": ResultDetails,
    }
    env.update(namespace or {})

    exec("\n".join(sections["constants"]), env)  # noqa: S102 - DSL execution
    constants = {
        k: v
        for k, v in env.items()
        if isinstance(v, (int, float, str, bool)) and not k.startswith("_")
    }

    if stage_sections:
        return _build_pipeline_from_sections(
            host, env, constants, sections, stage_sections
        )

    # nclusters may reference a constant (Listing 2 uses `clusters`).
    nclusters = int(eval(ncluster_expr, env))  # noqa: S307 - DSL expression

    exec("\n".join(sections["emit"]), env)  # noqa: S102
    exec("\n".join(sections["cluster"]), env)  # noqa: S102
    exec("\n".join(sections["collect"]), env)  # noqa: S102

    records = {k: v for k, v in env.items() if isinstance(v, ProcessRecord)}

    def _one(cls: type) -> Any:
        found = [v for v in records.values() if type(v) is cls]
        if len(found) != 1 and cls is not AnyFanOne:
            raise SyntaxError(
                f"specification must define exactly one {cls.__name__}, "
                f"found {len(found)}"
            )
        return found[0] if found else None

    emit = _one(Emit)
    onrl = _one(OneNodeRequestedList)
    nrfa = _one(NodeRequestingFanAny)
    group = _one(AnyGroupAny)
    collector = _one(Collect)
    fans = [v for v in records.values() if type(v) is AnyFanOne]
    if len(fans) != 2:
        raise SyntaxError(
            f"specification must define exactly two AnyFanOne processes "
            f"(afoc per node + afo at host), found {len(fans)}"
        )
    # Disambiguate by sources: afoc merges the node's workers, afo the nodes.
    afoc = next((f for f in fans if f.sources == group.workers), None)
    afo = next((f for f in fans if f is not afoc), None)
    if afoc is None or afo is None:
        raise SyntaxError(
            "cannot identify afoc (sources == workers) among AnyFanOne records"
        )

    spec = ClusterSpec(
        host=host,
        nclusters=nclusters,
        host_net=HostNetwork(emit=emit, onrl=onrl, afo=afo, collector=collector),
        node_net=NodeNetwork(nrfa=nrfa, group=group, afoc=afoc),
        constants=constants,
    )
    spec.validate()
    return spec


def _build_pipeline_from_sections(
    host: str,
    env: dict[str, Any],
    constants: dict[str, Any],
    sections: dict[str, list[str]],
    stage_sections: list[tuple[str, str, int, list[str]]],
) -> PipelineSpec:
    """Execute the staged-grammar sections and assemble a PipelineSpec.

    Records are harvested *per section*: a section owns the records its
    body binds (assigns to a name), so two stages may reuse the natural
    names ``group``/``nrfa``/``afoc`` without colliding, and a prebuilt
    record supplied via ``namespace=`` counts for the section that binds
    it (``group = G``), not for whichever section ran first.
    """

    def _exec_section(body: list[str]) -> list[ProcessRecord]:
        before = dict(env)
        exec("\n".join(body), env)  # noqa: S102 - DSL execution
        out: list[ProcessRecord] = []
        ids: set[int] = set()
        for k, v in env.items():
            if (isinstance(v, ProcessRecord) and before.get(k) is not v
                    and id(v) not in ids):
                out.append(v)
                ids.add(id(v))
        return out

    emit_records = _exec_section(sections["emit"])
    emits = [v for v in emit_records if type(v) is Emit]
    if len(emits) != 1:
        raise SyntaxError(
            f"emit section must define exactly one Emit, found {len(emits)}"
        )
    onrls = [v for v in emit_records if type(v) is OneNodeRequestedList]
    first_onrl = onrls[0] if len(onrls) == 1 else None

    stage_nets: list[StageNetwork] = []
    for idx, (name, n_expr, lineno, body) in enumerate(stage_sections):
        try:
            nclusters = int(eval(n_expr, env))  # noqa: S307 - DSL expression
        except Exception as exc:
            raise SyntaxError(
                f"line {lineno}: //@stage {name}: cannot evaluate node "
                f"count {n_expr!r}: {exc}"
            ) from exc
        recs = _exec_section(body)
        groups = [v for v in recs if type(v) is AnyGroupAny]
        if len(groups) != 1:
            raise SyntaxError(
                f"line {lineno}: stage {name!r} must define exactly one "
                f"AnyGroupAny, found {len(groups)}"
            )
        group = groups[0]
        nrfas = [v for v in recs if type(v) is NodeRequestingFanAny]
        if len(nrfas) > 1:
            raise SyntaxError(
                f"line {lineno}: stage {name!r} defines {len(nrfas)} "
                "NodeRequestingFanAny records; at most one is allowed"
            )
        nrfa = nrfas[0] if nrfas else NodeRequestingFanAny(
            destinations=group.workers
        )
        fans = [v for v in recs if type(v) is AnyFanOne]
        if len(fans) > 1:
            raise SyntaxError(
                f"line {lineno}: stage {name!r} defines {len(fans)} "
                "AnyFanOne records; at most one (the per-node afoc) is "
                "allowed — the host-side merge is synthesised"
            )
        afoc = fans[0] if fans else AnyFanOne(sources=group.workers)
        onrl = (first_onrl if idx == 0 and first_onrl is not None
                else OneNodeRequestedList())
        stage_nets.append(
            StageNetwork(
                name=name,
                nclusters=nclusters,
                node_net=NodeNetwork(nrfa=nrfa, group=group, afoc=afoc),
                onrl=onrl,
            )
        )

    collect_records = _exec_section(sections["collect"])
    collectors = [v for v in collect_records if type(v) is Collect]
    if len(collectors) != 1:
        raise SyntaxError(
            "collect section must define exactly one Collect, found "
            f"{len(collectors)}"
        )

    spec = PipelineSpec(
        host=host,
        emit=emits[0],
        stages=stage_nets,
        collector=collectors[0],
        constants=constants,
    )
    spec.validate()
    return spec


def load_cgpp(
    path: str, namespace: Mapping[str, Any] | None = None
) -> ClusterSpec | PipelineSpec:
    with open(path, "r", encoding="utf-8") as fh:
        return parse_cgpp(fh.read(), namespace)
