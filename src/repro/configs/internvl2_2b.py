"""internvl2-2b [vlm] — InternViT frontend + InternLM2 backbone
(arXiv:2404.16821; hf).  Backbone only per the assignment: 24L d_model=2048
16H (GQA kv=8) d_ff=8192 vocab=92553; the ViT is a stub supplying 256
precomputed patch embeddings as the sequence prefix.  vocab 92553 is padded
to the TP degree by the builder (92560 at tp=16)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    frontend="vit",
    frontend_len=256,
)
