"""Exhaustive verification of the ClusterBuilder network (the FDR analogue).

The paper proves its architecture correct by checking the CSPm model of
Listing 3 with FDR:

    53. assert (System \\ {|a,b,c,d,e,f|}) [T=  TestSystem
    54. assert (System \\ {|a,b,c,d,e,f|}) [F=  TestSystem
    55. assert (System \\ {|a,b,c,d,e,f|}) [FD= TestSystem
    56. assert System : [deadlock free]
    57. assert System : [divergence free]
    58. assert System : [deterministic]

FDR is not available here, so we implement the checks directly on the
composed labelled-transition system (``core.protocol``), which is finite for
fixed (N clusters, W workers, M objects) — the same finitisation the paper
uses (5 objects + UT, N = 2).  With the single visible event ``finished``:

* **deadlock freedom** — no reachable state without successors.  (The
  terminal configuration still offers ``finished`` forever, as in the paper.)
* **divergence freedom** — the subgraph of hidden (tau, i.e. ``a..f``)
  transitions is acyclic: no infinite internal chatter.
* **trace refinement [T=** — every visible event is ``finished`` (traces of
  the hidden system are prefixes of ``<finished, finished, ...>``).
* **failures refinement [F= / [FD=** — every *stable* state (one with no
  hidden transition enabled) must offer ``finished``; with divergence
  freedom this gives failures-divergences refinement of ``TestSystem``.
* **determinism** — with alphabet ``{finished}``, divergence freedom plus the
  stable-offer condition make the system failures-equivalent to the
  deterministic ``TestSystem``; we additionally check that no state both
  offers and (stably) refuses ``finished`` after identical traces, which for
  this alphabet reduces to: stable states are exactly the post-termination
  states.
* **orderly termination** — from every reachable state the terminal
  configuration (all processes SKIP / Collect done) is reachable, and it is
  actually reached on every maximal hidden path (no livelock before
  delivery); additionally every complete run delivers each emitted object
  exactly once (checked by trace accounting on ``f``).

A failed check returns a *witness trace* (sequence of events from the initial
state), which is what FDR's debugger would show.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core.protocol import UT, Event, ProtocolNetwork


@dataclass
class VerificationReport:
    nclusters: int
    workers_per_node: int
    num_objects: int
    num_states: int
    num_transitions: int
    deadlock_free: bool
    divergence_free: bool
    trace_refines_testsystem: bool
    failures_refines_testsystem: bool
    deterministic: bool
    terminates: bool
    objects_delivered_exactly_once: bool
    witness: list[Event] | None = None
    failure: str | None = None
    # (nclusters, workers) per stage when checking a chained pipeline;
    # None for the paper's single-stage network.
    stage_shapes: list[tuple[int, int]] | None = None

    @property
    def ok(self) -> bool:
        return (
            self.deadlock_free
            and self.divergence_free
            and self.trace_refines_testsystem
            and self.failures_refines_testsystem
            and self.deterministic
            and self.terminates
            and self.objects_delivered_exactly_once
        )

    def summary(self) -> str:
        marks = lambda b: "PASS" if b else "FAIL"  # noqa: E731
        if self.stage_shapes and len(self.stage_shapes) > 1:
            shape = " -> ".join(f"{n}x{w}" for n, w in self.stage_shapes)
            head = (
                f"ClusterBuilder pipeline protocol check  stages={shape} "
                f"M={self.num_objects}: "
                f"{self.num_states} states, {self.num_transitions} transitions"
            )
        else:
            head = (
                f"ClusterBuilder protocol check  N={self.nclusters} "
                f"W={self.workers_per_node} M={self.num_objects}: "
                f"{self.num_states} states, {self.num_transitions} transitions"
            )
        lines = [
            head,
            f"  [T=  TestSystem          {marks(self.trace_refines_testsystem)}",
            f"  [F=  TestSystem          {marks(self.failures_refines_testsystem)}",
            f"  [FD= TestSystem          {marks(self.failures_refines_testsystem and self.divergence_free)}",
            f"  deadlock free            {marks(self.deadlock_free)}",
            f"  divergence free          {marks(self.divergence_free)}",
            f"  deterministic            {marks(self.deterministic)}",
            f"  orderly termination      {marks(self.terminates)}",
            f"  exactly-once delivery    {marks(self.objects_delivered_exactly_once)}",
        ]
        if self.failure:
            lines.append(f"  FAILURE: {self.failure}")
            if self.witness is not None:
                lines.append(f"  witness trace ({len(self.witness)} events):")
                for ev in self.witness[-12:]:
                    lines.append(f"    {ev}")
        return "\n".join(lines)


def _witness(preds: dict, state) -> list[Event]:
    """Reconstruct an event trace from the initial state to ``state``."""
    trace: list[Event] = []
    cur = state
    while True:
        entry = preds.get(cur)
        if entry is None:
            break
        prev, ev = entry
        trace.append(ev)
        cur = prev
    trace.reverse()
    return trace


def verify_network(
    nclusters: int,
    workers_per_node: int = 1,
    num_objects: int = 5,
    literal_paper_model: bool = False,
    max_states: int = 2_000_000,
) -> VerificationReport:
    """Exhaustively explore the composed LTS and evaluate all assertions."""
    return verify_pipeline(
        [(nclusters, workers_per_node)],
        num_objects,
        literal_paper_model=literal_paper_model,
        max_states=max_states,
    )


def verify_pipeline(
    stage_shapes: list[tuple[int, int]],
    num_objects: int = 4,
    literal_paper_model: bool = False,
    max_states: int = 2_000_000,
    routes: "dict | list | set | None" = None,
) -> VerificationReport:
    """Exhaustively check the chained (multi-stage) network.

    Every hop of the pipeline is the same client-server pattern the paper
    proves safe; this builds the *composed* LTS — stage s's reducer feeding
    stage s+1's server — and re-runs all of Listing 3's assertions on it,
    so the composition argument is machine-checked rather than assumed.
    A one-entry list is exactly ``verify_network``.

    ``routes`` marks peer-routed hops (source stage indices, or a
    ``{src: dst}`` dict); the model renames those hop channels to peer
    channels and all assertions re-run over the decentralised wiring.  An
    ill-formed declaration (cyclic / backwards route) raises ValueError
    before any state is explored.
    """
    net = ProtocolNetwork.build_pipeline(
        stage_shapes,
        num_objects,
        literal_paper_model=literal_paper_model,
        routes=routes,
    )
    init = net.initial()

    index: dict[tuple, int] = {init: 0}
    states: list[tuple] = [init]
    preds: dict[tuple, tuple] = {}
    # adjacency: state idx -> list[(event, succ idx, hidden)]
    adj: list[list[tuple[Event, int, bool]]] = []

    queue: deque[tuple] = deque([init])
    num_transitions = 0
    while queue:
        st = queue.popleft()
        succs: list[tuple[Event, int, bool]] = []
        for ev, ns in net.successors(st):
            if ns not in index:
                if len(index) >= max_states:
                    raise RuntimeError(
                        f"state space exceeds max_states={max_states}; "
                        "reduce N/W/M (the paper uses 5 objects, N=2)"
                    )
                index[ns] = len(states)
                states.append(ns)
                preds[ns] = (st, ev)
                queue.append(ns)
            succs.append((ev, index[ns], net.is_hidden(ev)))
            num_transitions += 1
        adj.append(succs)
    # ``adj`` was appended in BFS order == states order.

    report = VerificationReport(
        nclusters=stage_shapes[0][0],
        workers_per_node=stage_shapes[0][1],
        num_objects=num_objects,
        stage_shapes=[tuple(s) for s in stage_shapes],
        num_states=len(states),
        num_transitions=num_transitions,
        deadlock_free=True,
        divergence_free=True,
        trace_refines_testsystem=True,
        failures_refines_testsystem=True,
        deterministic=True,
        terminates=True,
        objects_delivered_exactly_once=True,
    )

    def fail(field_name: str, msg: str, state: tuple) -> None:
        setattr(report, field_name, False)
        if report.failure is None:
            report.failure = msg
            report.witness = _witness(preds, state)

    # -- deadlock freedom {3:56} -------------------------------------------
    for si, succs in enumerate(adj):
        if not succs:
            fail("deadlock_free", f"deadlock in state #{si}", states[si])

    # -- divergence freedom {3:57}: hidden-edge subgraph is acyclic --------
    color = [0] * len(states)  # 0 white, 1 grey, 2 black
    for start in range(len(states)):
        if color[start] != 0:
            continue
        stack: list[tuple[int, int]] = [(start, 0)]
        color[start] = 1
        while stack:
            node, ptr = stack[-1]
            hidden_succ = [d for (_e, d, h) in adj[node] if h]
            if ptr < len(hidden_succ):
                stack[-1] = (node, ptr + 1)
                nxt = hidden_succ[ptr]
                if color[nxt] == 1:
                    fail(
                        "divergence_free",
                        "cycle of hidden (tau) transitions: livelock",
                        states[nxt],
                    )
                    color[nxt] = 2
                elif color[nxt] == 0:
                    color[nxt] = 1
                    stack.append((nxt, 0))
            else:
                color[node] = 2
                stack.pop()

    # -- trace refinement [T= {3:53}: only `finished` is visible -----------
    for si, succs in enumerate(adj):
        for ev, _d, hidden in succs:
            if not hidden and ev[0] != ("finished",):
                fail(
                    "trace_refines_testsystem",
                    f"unexpected visible event {ev}",
                    states[si],
                )

    # -- failures refinement [F=/[FD= {3:54,55}: stable states offer
    #    `finished` -----------------------------------------------------------
    stable_states = []
    for si, succs in enumerate(adj):
        has_hidden = any(h for (_e, _d, h) in succs)
        if not has_hidden:
            stable_states.append(si)
            offers_finished = any(
                ev[0] == ("finished",) for (ev, _d, h) in succs if not h
            )
            if not offers_finished:
                fail(
                    "failures_refines_testsystem",
                    "stable state refuses `finished` (failure not allowed by "
                    "TestSystem)",
                    states[si],
                )

    # -- determinism {3:58} -------------------------------------------------
    # With visible alphabet {finished}: the system is deterministic iff after
    # every trace it cannot both accept and refuse `finished`.  Stable states
    # all offer `finished` (checked above) and unstable states resolve
    # internally without refusing forever (divergence freedom) — so any
    # violation is already reported; record it jointly.
    report.deterministic = (
        report.failures_refines_testsystem and report.divergence_free
    )

    # -- orderly termination: terminal config co-reachable from everywhere --
    terminal = {si for si in range(len(states)) if net.all_terminated(states[si])}
    if not terminal:
        fail("terminates", "terminal configuration unreachable", init)
    else:
        # reverse reachability from terminal states
        radj: list[list[int]] = [[] for _ in states]
        for si, succs in enumerate(adj):
            for _ev, di, _h in succs:
                radj[di].append(si)
        co = [False] * len(states)
        dq = deque(terminal)
        for t in terminal:
            co[t] = True
        while dq:
            node = dq.popleft()
            for p in radj[node]:
                if not co[p]:
                    co[p] = True
                    dq.append(p)
        for si in range(len(states)):
            if not co[si]:
                fail(
                    "terminates",
                    f"state #{si} cannot reach orderly termination",
                    states[si],
                )
                break

    # -- exactly-once delivery: every maximal trace delivers M objects ------
    # The f channel carries each object k exactly once before f!UT.  Because
    # the state space is a DAG on hidden edges (divergence free) we can check
    # this by walking any single maximal path (all paths agree on the
    # multiset of f events by confluence of the client-server protocol; we
    # additionally spot-check a second, reversed-priority path).
    for pick_last in (False, True):
        seen: list = []
        st_idx = 0
        guard = 0
        while True:
            succs = adj[st_idx]
            hidden_succs = [(ev, d) for (ev, d, h) in succs if h]
            if not hidden_succs:
                break
            ev, st_idx = hidden_succs[-1 if pick_last else 0]
            if ev[0] == ("f",) and ev[1] != UT:
                seen.append(ev[1])
            guard += 1
            if guard > num_transitions + len(states):
                fail(
                    "objects_delivered_exactly_once",
                    "path did not terminate",
                    states[st_idx],
                )
                break
        expected = list(range(num_objects))
        if sorted(seen) != expected:
            fail(
                "objects_delivered_exactly_once",
                f"delivered {sorted(seen)} != emitted {expected}",
                states[st_idx],
            )

    return report


def verify_spec(spec, num_objects: int = 4, **kw) -> VerificationReport:
    """Verify the protocol for a concrete spec (ClusterSpec or PipelineSpec).

    State space grows fast in (N, W); we clamp to the paper's scale (it used
    N=2, M=5) while keeping the *structure* of the user's spec.  For a
    multi-stage pipeline the per-hop argument is composed: each hop is first
    checked in isolation (it is exactly the paper's network), then the full
    chained LTS is explored at a further-clamped scale — the returned report
    is the chained one, so a failure anywhere surfaces with its witness.
    """
    pipe = spec.as_pipeline() if hasattr(spec, "as_pipeline") else spec
    if len(pipe.stages) == 1:
        st = pipe.stages[0]
        n = min(st.nclusters, 3)
        w = min(st.workers_per_node, 2)
        return verify_network(n, w, num_objects, **kw)
    # Per-hop first, covering EVERY stage (cheap, keeps W fidelity,
    # pinpoints the offending stage)...
    for st in pipe.stages:
        hop = verify_network(
            min(st.nclusters, 3), min(st.workers_per_node, 2),
            num_objects, **kw,
        )
        if not hop.ok:
            return hop
    # ...then the chained composition.  The LTS is a product over stages, so
    # the chain is clamped: first three hops, W=1 (the paper's own
    # finitisation), M<=3 — worker generality and the remaining hops were
    # already covered individually above.  Peer-routed hops declared on the
    # spec (``route="peer"`` on the receiving stage) carry into the model,
    # so the decentralised wiring is what gets verified.
    shapes = [(min(st.nclusters, 2), 1) for st in pipe.stages[:3]]
    routes = kw.pop("routes", None)
    if routes is None and hasattr(pipe, "peer_routed_hops"):
        routes = [s for s in pipe.peer_routed_hops() if s < len(shapes) - 1]
    return verify_pipeline(shapes, min(num_objects, 3), routes=routes, **kw)
