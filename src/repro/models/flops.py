"""Analytic FLOP/byte accounting per (architecture x input shape).

Used by the roofline pipeline as the MODEL_FLOPS term (useful compute) and as
a cross-check on the HLO-derived totals:

    ratio = MODEL_FLOPS / HLO_FLOPS

catches remat recompute, head/vocab padding waste and redundant (replicated)
compute.  Conventions:

* matmul [m,k]x[k,n] = 2*m*k*n FLOPs;
* causal attention halves the score/PV terms;
* backward pass = 2x forward (train kind => total 3x forward);
* MODEL_FLOPS follows the 6*N*D rule (N = *active, unpadded* parameters
  excluding embeddings; D = tokens) for train, 2*N*D for inference.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import head_plan


@dataclass
class FlopsReport:
    forward: float  # per-step forward FLOPs (global, padded/as-compiled)
    total: float  # incl. backward for train
    model_flops: float  # 6*N_active*D (train) / 2*N_active*D (inference)
    params_total: int
    params_active: int
    by_component: dict

    def summary(self) -> str:
        parts = ", ".join(f"{k}={v:.3e}" for k, v in self.by_component.items())
        return (
            f"total={self.total:.4e} fwd={self.forward:.4e} "
            f"model={self.model_flops:.4e} ({parts})"
        )


def _attn_layer_flops(cfg: ModelConfig, B: int, S: int, ctx: int, tp: int,
                      causal: bool = True) -> float:
    """One attention block forward (padded heads — as compiled)."""
    hp = head_plan(cfg, tp)
    Hp, Kp, hd, D = hp["Hp"], hp["Kp"], cfg.head_dim, cfg.d_model
    proj = 2 * B * S * D * (Hp + 2 * Kp) * hd + 2 * B * S * Hp * hd * D
    score_ctx = ctx / 2 if (causal and ctx == S) else ctx
    scores = 2 * B * S * score_ctx * Hp * hd
    pv = 2 * B * S * score_ctx * Hp * hd
    return proj + scores + pv


def _mlp_flops(cfg: ModelConfig, B: int, S: int) -> float:
    return 6 * B * S * cfg.d_model * cfg.d_ff if cfg.d_ff else 0.0


def _moe_layer_flops(cfg: ModelConfig, B: int, S: int, tp: int) -> float:
    T = B * S
    router = 2 * T * cfg.d_model * cfg.num_experts
    expert_tokens = T * cfg.experts_per_token
    experts = 6 * expert_tokens * cfg.d_model * cfg.moe_d_ff
    shared = 6 * T * cfg.d_model * cfg.moe_d_ff if cfg.num_shared_experts else 0
    return router + experts + shared


def _rec_layer_flops(cfg: ModelConfig, B: int, S: int) -> float:
    W = cfg.rnn_width or cfg.d_model
    proj = 2 * B * S * cfg.d_model * W * 3  # two in-branches + out
    conv = 2 * B * S * cfg.conv1d_width * W
    gates = 12 * B * S * W  # elementwise recurrence
    return proj + conv + gates


def _mlstm_layer_flops(cfg: ModelConfig, B: int, S: int, chunk: int = 64) -> float:
    W = cfg.num_heads * cfg.head_dim
    hd = cfg.head_dim
    up = 2 * B * S * cfg.d_model * 2 * W
    qkv = 3 * 2 * B * S * W * W
    core_intra = 2 * 2 * B * S * min(chunk, S) * cfg.num_heads * hd
    core_state = 2 * 2 * B * S * cfg.num_heads * hd * hd / max(chunk, 1)
    down = 2 * B * S * W * cfg.d_model
    return up + qkv + core_intra + core_state + down


def _slstm_layer_flops(cfg: ModelConfig, B: int, S: int) -> float:
    W = cfg.num_heads * cfg.head_dim
    hd = cfg.head_dim
    inp = 2 * B * S * cfg.d_model * 4 * W
    recur = 4 * 2 * B * S * cfg.num_heads * hd * hd
    down = 2 * B * S * W * cfg.d_model
    return inp + recur + down


def _layer_flops(cfg: ModelConfig, kind: str, B: int, S: int, ctx: int,
                 tp: int) -> float:
    if kind in ("attn", "global"):
        return _attn_layer_flops(cfg, B, S, ctx, tp) + _mlp_flops(cfg, B, S)
    if kind == "local":
        w_ctx = min(cfg.window_size, ctx)
        return _attn_layer_flops(cfg, B, S, w_ctx, tp, causal=False) + \
            _mlp_flops(cfg, B, S)
    if kind == "moe":
        return _attn_layer_flops(cfg, B, S, ctx, tp) + _moe_layer_flops(cfg, B, S, tp)
    if kind == "rec":
        return _rec_layer_flops(cfg, B, S)
    if kind == "mlstm":
        return _mlstm_layer_flops(cfg, B, S)
    if kind == "slstm":
        return _slstm_layer_flops(cfg, B, S)
    raise ValueError(kind)


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) *unpadded* non-embedding parameter counts."""
    D, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    total = active = 0

    def attn_params() -> int:
        return D * (H + 2 * KV) * hd + H * hd * D

    mlp = 3 * D * cfg.d_ff if cfg.d_ff else 0
    for kind in cfg.pattern_for_layers:
        if kind in ("attn", "local", "global"):
            p = attn_params() + mlp
            total += p
            active += p
        elif kind == "moe":
            a = attn_params()
            router = D * cfg.num_experts
            experts = cfg.num_experts * 3 * D * cfg.moe_d_ff
            shared = (3 * D * cfg.moe_d_ff) if cfg.num_shared_experts else 0
            total += a + router + experts + shared
            active += a + router + cfg.experts_per_token * 3 * D * cfg.moe_d_ff + shared
        elif kind == "rec":
            W = cfg.rnn_width or D
            p = 3 * D * W + cfg.conv1d_width * W + 5 * W + mlp
            total += p
            active += p
        elif kind == "mlstm":
            W = H * hd
            p = 2 * D * W + 3 * W * W + W * 2 * H + W * D
            total += p
            active += p
        elif kind == "slstm":
            W = H * hd
            p = 4 * D * W + 4 * H * hd * hd + W * D
            total += p
            active += p
    if cfg.encoder_layers:
        enc = cfg.encoder_layers * (attn_params() + mlp)
        dec_cross = cfg.num_layers * attn_params()  # cross-attention extra
        total += enc + dec_cross
        active += enc + dec_cross
    return total, active


def step_flops(cfg: ModelConfig, shape: ShapeConfig, tp: int = 1) -> FlopsReport:
    B = shape.global_batch
    comp: dict[str, float] = {}
    Vp = cfg.padded_vocab(tp)

    if shape.kind in ("train", "prefill"):
        S, ctx = shape.seq_len, shape.seq_len
        tokens = B * S
    elif shape.kind in ("decode", "long"):
        S, ctx = 1, shape.seq_len
        tokens = B
    else:
        raise ValueError(shape.kind)

    body = 0.0
    for kind in cfg.pattern_for_layers:
        body += _layer_flops(cfg, kind, B, S, ctx, tp)
    comp["body"] = body
    if cfg.encoder_layers:
        # encoder runs the full source sequence even in decode shapes (once;
        # amortised — we charge it only on train/prefill).
        if shape.kind in ("train", "prefill"):
            enc = cfg.encoder_layers * (
                _attn_layer_flops(cfg, B, S, ctx, tp, causal=False)
                + _mlp_flops(cfg, B, S)
            )
            cross = cfg.num_layers * _attn_layer_flops(cfg, B, S, ctx, tp,
                                                       causal=False)
        else:
            enc = 0.0
            cross = cfg.num_layers * _attn_layer_flops(
                cfg, B, 1, min(ctx, 4096), tp, causal=False)
        comp["encoder"] = enc
        comp["cross"] = cross
        body += enc + cross
    head = 2 * B * S * cfg.d_model * Vp
    comp["lm_head"] = head
    fwd = body + head

    if shape.kind == "train":
        total = 3.0 * fwd
    else:
        total = fwd

    n_total, n_active = param_counts(cfg)
    if shape.kind == "train":
        model = 6.0 * n_active * tokens
    else:
        model = 2.0 * n_active * tokens
        if shape.kind in ("decode", "long"):
            # decode also reads the KV cache: attention context work is real
            # useful work not captured by 2*N*D; add the score/PV terms.
            hp_ctx = 0.0
            for kind in cfg.pattern_for_layers:
                if kind in ("attn", "global", "moe"):
                    hp_ctx += 4 * B * ctx * cfg.num_heads * cfg.head_dim
                elif kind == "local":
                    hp_ctx += 4 * B * min(cfg.window_size, ctx) * \
                        cfg.num_heads * cfg.head_dim
            model += hp_ctx

    return FlopsReport(
        forward=fwd,
        total=total,
        model_flops=model,
        params_total=n_total,
        params_active=n_active,
        by_component=comp,
    )
