"""The Host-Node-Loader (HNL): paper §4 / Figure 1, over real sockets.

Bootstrap sequence (the load network):

1. HNL listens on the configurable "port 2000" and waits for one REGISTER
   frame per expected node (many-to-one input channel — input end created
   before any output end exists, §4's ordering rule).
2. HNL broadcasts the serialized deployment to every node on the LOAD frame —
   the JCSP *code-loading channel* analogue (§4.1): the work function (and
   any AOT-serialized executables) travel by value, so the host is the single
   source of code.
3. The application network (WORK_REQUEST/WORK/RESULT/UT) then runs the
   demand-driven onrl/nrfa client-server protocol model-checked in
   ``core.verify``: the host answers each node's request in finite time with
   the next work object, or with UT once the emit stream is exhausted and
   nothing is in flight.
4. On UT each node returns its (load_ms, run_ms, items) timing record
   (requirement 7) and the HNL folds results via the user's ResultDetails.

Beyond the paper: heartbeat liveness (``membership``) — a node-loader that
dies mid-job is detected by missed beats, its in-flight items re-queued and
re-dispatched to surviving nodes, with result-id dedup guaranteeing no item
is lost or double-collected.

Single-threaded protocol core: per-connection reader threads and a ticker
only *enqueue* events; one dispatcher consumes them.  That makes the state
machine deterministic and trivially deadlock-free (no locks around protocol
state).
"""

from __future__ import annotations

import collections
import queue
import socket
import threading
import time
from dataclasses import dataclass
from typing import Any

from repro.cluster.membership import Membership
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    LOAD_WIRE_CHANNEL,
    Frame,
    FrameConnection,
    FrameType,
)
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor


@dataclass
class HostStats:
    items_total: int = 0
    duplicates_dropped: int = 0
    redispatched: int = 0
    deaths_detected: int = 0


class WorkFunctionError(RuntimeError):
    """The user's work function raised on a node; the job fails fast."""


class HostLoader:
    """Runs the host side of one emit/cluster/collect deployment."""

    def __init__(
        self,
        spec,
        timing: TimingCollector | None = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat: HeartbeatMonitor | None = None,
        register_timeout: float = 30.0,
        job_timeout: float | None = None,
        slowdown: dict[str, float] | None = None,
        artifacts: dict[str, bytes] | None = None,
    ):
        spec.validate()
        self.spec = spec
        self.timing = timing or TimingCollector()
        self.host = host
        self.membership = Membership(heartbeat or HeartbeatMonitor())
        self.register_timeout = register_timeout
        self.job_timeout = job_timeout
        self.slowdown = dict(slowdown or {})
        self.artifacts = dict(artifacts or {})
        self.stats = HostStats()
        self.result: Any = None

        self._events: queue.Queue = queue.Queue()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(spec.nclusters + 4)
        self.port = self._listener.getsockname()[1]
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    # -- bootstrap ----------------------------------------------------------

    def start(self) -> None:
        """Open the load network (accept + ticker threads)."""
        for fn, name in ((self._accept_loop, "hnl-accept"),
                         (self._tick_loop, "hnl-ticker")):
            t = threading.Thread(target=fn, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            t = threading.Thread(
                target=self._conn_reader, args=(conn, f"{addr[0]}:{addr[1]}"),
                name=f"hnl-reader-{addr[1]}", daemon=True,
            )
            t.start()
            self._threads.append(t)

    def _conn_reader(self, conn: FrameConnection, addr: str) -> None:
        node_id = None
        try:
            first = conn.recv()
            if first.ftype is not FrameType.REGISTER:
                conn.close()
                return
            node_id = first.payload["node_id"]
            self._events.put(("register", node_id, addr, conn, first.payload))
            while True:
                frame = conn.recv()
                self._events.put(("frame", node_id, frame))
        except (ConnectionError, OSError, ValueError):
            if node_id is not None:
                self._events.put(("disconnect", node_id))

    def _tick_loop(self) -> None:
        interval = self.membership.monitor.interval_s / 2
        while not self._stop.wait(interval):
            self._events.put(("tick",))

    # -- the dispatcher -----------------------------------------------------

    def run(self) -> Any:
        """Bootstrap, run the farm to termination, return the final result."""
        spec = self.spec
        deadline = (
            time.monotonic() + self.job_timeout if self.job_timeout else None
        )

        with self.timing.phase("host", "load"):
            self._await_registrations()
            self._broadcast_load()

        details = spec.host_net.emit.e_details
        emit_state = details.initial_state()
        emit_done = False
        next_id = 0
        pending: collections.deque = collections.deque()  # requeued (id, obj)
        inflight: dict[int, tuple[str, Any]] = {}
        done_ids: set[int] = set()
        waiting: collections.deque = collections.deque()  # parked requests
        r_details = spec.host_net.collector.r_details
        acc = r_details.init()

        def next_item():
            nonlocal emit_state, emit_done, next_id
            if pending:
                return pending.popleft()
            if emit_done:
                return None
            obj, emit_state = details.create(emit_state)
            if obj is None:
                emit_done = True
                return None
            item = (next_id, obj)
            next_id += 1
            return item

        def send_work(node_id: str, item) -> bool:
            rec = self.membership.nodes[node_id]
            item_id, obj = item
            try:
                rec.conn.send(Frame(
                    FrameType.WORK, {"id": item_id, "obj": obj},
                    APP_WIRE_CHANNEL,
                ))
            except (OSError, ValueError):
                pending.appendleft(item)  # never lose an item on a dead pipe
                return False
            inflight[item_id] = (node_id, obj)
            return True

        def send_ut(node_id: str) -> None:
            rec = self.membership.nodes[node_id]
            try:
                rec.conn.send(Frame(FrameType.UT, None, APP_WIRE_CHANNEL))
            except (OSError, ValueError):
                pass

        def answer(node_id: str) -> None:
            """Answer one WORK_REQUEST (the onrl server obligation)."""
            rec = self.membership.nodes.get(node_id)
            if rec is None or not rec.alive:
                return
            item = next_item()
            if item is not None:
                if not send_work(node_id, item):
                    waiting.append(node_id)  # retried once the node is reaped
                return
            if emit_done and not inflight:
                send_ut(node_id)
            else:
                waiting.append(node_id)  # emit drained but items in flight

        def flush_waiting() -> None:
            for _ in range(len(waiting)):
                answer(waiting.popleft())

        def reap(now: float | None = None) -> None:
            newly_dead = self.membership.reap(now, at_item=len(done_ids))
            for rec in newly_dead:
                self.stats.deaths_detected += 1
                lost = [iid for iid, (nid, _) in inflight.items()
                        if nid == rec.node_id]
                for iid in lost:
                    _, obj = inflight.pop(iid)
                    pending.append((iid, obj))
                    self.stats.redispatched += 1
                # A parked request from a dead node can never be answered.
                while rec.node_id in waiting:
                    waiting.remove(rec.node_id)
            if newly_dead:
                flush_waiting()

        with self.timing.phase("host", "run"):
            while True:
                if (emit_done and not inflight and not pending
                        and self.membership.finished()):
                    break
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"cluster job exceeded {self.job_timeout}s "
                        f"(done={len(done_ids)}, inflight={len(inflight)}, "
                        f"membership:\n{self.membership.describe()})"
                    )
                try:
                    event = self._events.get(
                        timeout=self.membership.monitor.interval_s
                    )
                except queue.Empty:
                    continue
                kind = event[0]
                if kind == "frame":
                    _, node_id, frame = event
                    if frame.ftype is FrameType.WORK_REQUEST:
                        answer(node_id)
                    elif frame.ftype is FrameType.RESULT:
                        p = frame.payload
                        if "error" in p:
                            raise WorkFunctionError(
                                f"work function raised on {node_id} for item "
                                f"{p['id']}: {p['error']}\n"
                                f"{p.get('traceback', '')}"
                            )
                        # Always clear inflight — a redispatched item can
                        # complete twice (zombie result + survivor result)
                        # and both entries must go or termination stalls.
                        inflight.pop(p["id"], None)
                        if p["id"] in done_ids:
                            self.stats.duplicates_dropped += 1
                        else:
                            done_ids.add(p["id"])
                            acc = r_details.collect(acc, p["value"])
                            self.stats.items_total += 1
                            rec = self.membership.nodes[node_id]
                            rec.items_done += 1
                            self.timing.count_item(node_id)
                        if emit_done and not inflight and not pending:
                            flush_waiting()
                    elif frame.ftype is FrameType.HEARTBEAT:
                        self.membership.beat(node_id)
                    elif frame.ftype is FrameType.UT:
                        self._node_finished(node_id, frame.payload)
                elif kind == "tick":
                    reap()
                elif kind == "disconnect":
                    # The socket died; death itself is declared by the
                    # heartbeat threshold (reap), keeping one detection path.
                    pass
                elif kind == "register":
                    # Late joiner after bootstrap: not part of this job.
                    _, _, _, conn, _ = event
                    conn.close()
                if not self.membership.alive_nodes() and (
                        inflight or pending or not emit_done):
                    raise RuntimeError(
                        "all node-loaders died with work outstanding "
                        f"({len(inflight)} in flight, {len(pending)} queued)"
                    )

        self.result = r_details.finalise(acc)
        return self.result

    # -- bootstrap helpers --------------------------------------------------

    def _await_registrations(self) -> None:
        deadline = time.monotonic() + self.register_timeout
        expected = self.spec.nclusters
        while len(self.membership.nodes) < expected:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"only {len(self.membership.nodes)}/{expected} node-loaders "
                    f"registered within {self.register_timeout}s"
                )
            try:
                event = self._events.get(timeout=remaining)
            except queue.Empty:
                continue
            if event[0] == "frame":
                # Early heartbeats (nodes beat from REGISTER onwards) must
                # count, or a node registering early could be declared dead
                # while the stragglers are still connecting.
                _, node_id, frame = event
                if frame.ftype is FrameType.HEARTBEAT:
                    self.membership.beat(node_id)
                continue
            if event[0] != "register":
                continue  # pre-bootstrap noise
            _, node_id, addr, conn, payload = event
            try:
                self.membership.register(
                    node_id, addr,
                    cores=int(payload.get("cores", 1)),
                    pid=int(payload.get("pid", 0)),
                    conn=conn,
                )
            except ValueError:
                conn.close()  # duplicate node_id: reject it, keep waiting

    def _broadcast_load(self) -> None:
        for rec in self.membership.alive_nodes():
            try:
                rec.conn.send(Frame(
                    FrameType.LOAD,
                    {
                        "node_id": rec.node_id,
                        "workers": self.spec.workers_per_node,
                        "function": self.spec.node_net.group.function,
                        "heartbeat_interval": self.membership.monitor.interval_s,
                        "slowdown": float(self.slowdown.get(rec.node_id, 0.0)),
                        "artifacts": self.artifacts,
                    },
                    LOAD_WIRE_CHANNEL,
                ))
            except (OSError, ValueError):
                # Died between REGISTER and LOAD: a bootstrap-time node
                # loss, handled like any other — survivors run the job.
                self.membership.mark_dead(rec.node_id)
                self.stats.deaths_detected += 1
                continue
            self.membership.mark_loaded(rec.node_id)

    def _node_finished(self, node_id: str, payload: Any) -> None:
        timing = payload or {}
        self.membership.mark_done(node_id, timing)
        self.timing.add(node_id, "load", float(timing.get("load_ms", 0.0)))
        self.timing.add(node_id, "run", float(timing.get("run_ms", 0.0)))

    # -- teardown -----------------------------------------------------------

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for rec in self.membership.nodes.values():
            if rec.conn is not None:
                rec.conn.close()
