"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward/train step on CPU, asserting
output shapes and no NaNs; decoder archs additionally roundtrip
prefill+decode against the full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import encdec, lm
from repro.models.common import init_params
from repro.optim.adamw import AdamWConfig
from repro.runtime import steps as steps_mod

ARCH_NAMES = sorted(ARCHS)


def _smoke(name):
    return dataclasses.replace(get_config(name).smoke(),
                               compute_dtype="float32")


def _batch(cfg, B=2, S=32):
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model))
    elif cfg.frontend:
        batch["extra_embeds"] = jax.random.normal(
            rng, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_runs_and_is_finite(name):
    cfg = _smoke(name)
    specs = steps_mod.model_param_specs(cfg, 1)
    params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    opt_cfg = AdamWConfig()
    from repro.optim import adamw

    opt_state = adamw.init_state(params, opt_cfg)
    step = steps_mod.make_train_step(cfg, opt_cfg, tp=1, rules=None,
                                     warmup_steps=1, total_steps=4)
    batch = _batch(cfg)
    params, opt_state, metrics = step(params, opt_state, batch, jnp.int32(0))
    assert jnp.isfinite(metrics["loss"]), name
    assert 2.0 < float(metrics["ce_loss"]) < 12.0  # ~ln(vocab) at init
    assert jnp.isfinite(metrics["grad_norm"])
    # one more step: params actually changed
    p0 = jax.tree.leaves(params)[0].copy()
    params, opt_state, m2 = step(params, opt_state, batch, jnp.int32(1))
    assert not jnp.allclose(jax.tree.leaves(params)[0], p0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes(name):
    cfg = _smoke(name)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    specs = steps_mod.model_param_specs(cfg, 1)
    params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    prefill = steps_mod.make_prefill_step(cfg, tp=1, rules=None)
    logits = prefill(params, {k: v for k, v in batch.items() if k != "targets"})
    assert logits.shape == (B, cfg.padded_vocab(1))
    assert jnp.isfinite(logits).all(), name


@pytest.mark.parametrize(
    "name", [n for n in ARCH_NAMES if ARCHS[n].has_decoder
             and not ARCHS[n].encoder_layers]
)
def test_decode_matches_forward(name):
    cfg = _smoke(name)
    B, S = 2, 33
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    specs = lm.lm_param_specs(cfg, 1)
    params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    x, _ = lm.forward_hidden(cfg, params, toks)
    full_logits = lm.logits_from_hidden(cfg, params, x)
    lp, cache = lm.prefill(cfg, params, toks[:, : S - 1], max_seq=S + 8)
    assert jnp.abs(lp[:, 0] - full_logits[:, S - 2]).max() < 2e-4, name
    ld, _ = lm.decode_step(cfg, params, cache, toks[:, S - 1 : S],
                           jnp.int32(S - 1))
    assert jnp.abs(ld[:, 0] - full_logits[:, S - 1]).max() < 2e-4, name


def test_encdec_decode_matches_forward():
    cfg = _smoke("seamless-m4t-large-v2")
    B, Se, Sd = 2, 16, 12
    rng = jax.random.PRNGKey(3)
    frames = jax.random.normal(rng, (B, Se, cfg.d_model))
    toks = jax.random.randint(rng, (B, Sd), 0, cfg.vocab_size)
    params = init_params(encdec.encdec_param_specs(cfg, 1),
                         jax.random.PRNGKey(0), jnp.float32)
    enc_out = encdec.encode(cfg, params, frames)
    x = encdec.decode_train(cfg, params, toks, enc_out)
    full_logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    cache = encdec.init_encdec_cache(cfg, params, enc_out, max_seq=Sd + 4)
    for t in range(Sd):
        logits, cache = encdec.encdec_decode_step(
            cfg, params, cache, toks[:, t : t + 1], jnp.int32(t))
    assert jnp.abs(logits[:, 0] - full_logits[:, Sd - 1]).max() < 2e-4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_shape_assignments(name):
    """Every arch declares its runnable shapes + documented skips = 4."""
    cfg = ARCHS[name]
    runnable = {s.name for s in cfg.shapes()}
    skipped = {s for s, _why in cfg.skipped_shapes()}
    assert runnable | skipped == {"train_4k", "prefill_32k", "decode_32k",
                                  "long_500k"}
    assert not (runnable & skipped)


def test_full_cell_count():
    from repro.configs.registry import all_cells

    cells = all_cells()
    assert len(cells) == 40
    assert sum(1 for *_x, r in cells if r) == 33  # 7 documented long/decode skips


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_padding_preserves_outputs(tp):
    """Head/vocab padding at TP>1 must not change logits (zero-padded)."""
    cfg = dataclasses.replace(
        _smoke("phi3-medium-14b"), num_heads=6, num_kv_heads=2,
        vocab_size=250,
    )
    specs1 = lm.lm_param_specs(cfg, tp=1)
    specsN = lm.lm_param_specs(cfg, tp=tp)
    p1 = init_params(specs1, jax.random.PRNGKey(0), jnp.float32)
    pN = init_params(specsN, jax.random.PRNGKey(0), jnp.float32)
    hd = cfg.head_dim
    H = cfg.num_heads
    b1, bN = p1["blocks"]["attn"], dict(pN["blocks"]["attn"])
    bN["ln1"], bN["ln2"], bN["mlp"] = b1["ln1"], b1["ln2"], b1["mlp"]
    bN["wq"] = pN["blocks"]["attn"]["wq"].at[:, :, : H * hd].set(
        b1["wq"]).at[:, :, H * hd:].set(0)
    bN["wo"] = pN["blocks"]["attn"]["wo"].at[:, : H * hd].set(
        b1["wo"]).at[:, H * hd:].set(0)
    kvdim = b1["wk"].shape[-1]
    bN["wk"] = pN["blocks"]["attn"]["wk"].at[:, :, :kvdim].set(
        b1["wk"]).at[:, :, kvdim:].set(0)
    bN["wv"] = pN["blocks"]["attn"]["wv"].at[:, :, :kvdim].set(
        b1["wv"]).at[:, :, kvdim:].set(0)
    pN = dict(pN)
    pN["blocks"] = {"attn": bN}
    pN["embed"] = pN["embed"].at[: cfg.vocab_size].set(p1["embed"]).at[
        cfg.vocab_size:].set(0)
    pN["lm_head"] = pN["lm_head"].at[:, : cfg.vocab_size].set(
        p1["lm_head"]).at[:, cfg.vocab_size:].set(0)
    pN["final_norm"] = p1["final_norm"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 250)
    x1, _ = lm.forward_hidden(cfg, p1, toks, tp=1)
    l1 = lm.logits_from_hidden(cfg, p1, x1)
    xN, _ = lm.forward_hidden(cfg, pN, toks, tp=tp)
    lN = lm.logits_from_hidden(cfg, pN, xN)
    assert jnp.abs(l1 - lN[..., :250]).max() < 2e-4
