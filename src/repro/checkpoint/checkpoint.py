"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json      # step, config name/hash, mesh shape, leaf index
        arrays.npz         # flattened path -> host-local full array

Properties required for the 1000+-node story:

* **atomic** — written to ``step_X.tmp`` then ``os.rename``d; a crashed save
  can never be mistaken for a valid checkpoint;
* **async** — ``save_async`` hands the (host-synced) arrays to a background
  thread so the step loop is not blocked (fault-tolerance requirement);
* **elastic restore** — arrays are re-``device_put`` against the *current*
  mesh/rules shardings, so a checkpoint taken on N nodes restores onto M;
* **self-describing** — the manifest records enough to refuse a mismatched
  config (changed layer counts etc.) instead of silently mis-restoring.

On a real multi-host pod each host writes only its addressable shards; the
single-host container exercises the same code path with full arrays (the
shard indexing below is per-host-addressable, not per-device).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
        return out
    return {prefix: tree}


def _unflatten(flat: dict[str, Any]) -> Any:
    root: dict = {}
    for path, v in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def config_hash(cfg) -> str:
    payload = repr(cfg).encode()
    return hashlib.sha256(payload).hexdigest()[:16]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._last_error: Exception | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: dict, meta: dict | None = None) -> str:
        """Blocking save of a pytree-of-arrays ``state``."""
        flat = _flatten(state)
        host_flat = {k: np.asarray(v) for k, v in flat.items()}
        return self._write(step, host_flat, meta or {})

    def save_async(self, step: int, state: dict, meta: dict | None = None) -> None:
        """Non-blocking save: device->host copy now, file IO in background."""
        self.wait()  # one in-flight save at a time (bounded memory)
        flat = _flatten(state)
        host_flat = {k: np.asarray(v) for k, v in flat.items()}
        meta = dict(meta or {})

        def work() -> None:
            try:
                self._write(step, host_flat, meta)
            except Exception as e:  # pragma: no cover - surfaced via wait()
                self._last_error = e

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _write(self, step: int, host_flat: dict, meta: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": sorted(host_flat),
            **meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int | None = None,
        shardings: Any | None = None,
        expect_meta: dict | None = None,
    ) -> tuple[int, dict, dict]:
        """Load (step, state, manifest); re-shard onto ``shardings`` if given.

        ``shardings`` is a pytree of NamedShardings congruent with the state
        tree — built against the *current* mesh, which may differ from the
        save-time mesh (elastic restore).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        for key, expected in (expect_meta or {}).items():
            if manifest.get(key) != expected:
                raise ValueError(
                    f"checkpoint meta mismatch for {key!r}: "
                    f"saved {manifest.get(key)!r} != expected {expected!r}"
                )
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings
            )
        return step, state, manifest
