"""The self-contained live dashboard served at ``GET /``.

One HTML string, zero external assets (the status endpoint must work on an
air-gapped cluster host): inline CSS, inline JS subscribing to the
``/events/stream`` Server-Sent Events feed — ``snapshot`` frames re-render
the page, ``bus`` frames append to the event log — so the page updates on
change instead of hammering the endpoint once a second.  When EventSource
is unavailable or the stream drops, it degrades to the classic
``/metrics`` + ``/events?since=`` 1 s poll.  Layout is stat tiles (the
headline numbers an operator scans first), a nodes table, a jobs table,
and the rolling event log — in the spirit of bndl's dash status panels,
minus the framework.

Design notes: values wear text ink, never a series colour; node/job state
is a coloured dot *plus* the state word (never colour alone); numbers are
tabular-figure monospace so columns don't wobble between refreshes; the
palette holds up in light and dark via ``prefers-color-scheme``.
"""

DASHBOARD_HTML = """<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>cluster telemetry</title>
<style>
  :root {
    --bg: #faf9f5; --surface: #ffffff; --ink: #1f1e1d; --ink-2: #5e5d59;
    --ink-3: #8a8984; --line: #e8e6e0; --accent: #2f6cc4;
    --ok: #2e7d43; --warn: #b97d12; --bad: #c03b33;
  }
  @media (prefers-color-scheme: dark) {
    :root {
      --bg: #16151a; --surface: #201f26; --ink: #edecea; --ink-2: #b4b2ac;
      --ink-3: #817f79; --line: #36343d; --accent: #7aa7e8;
      --ok: #6fbf85; --warn: #d9a45b; --bad: #e07a72;
    }
  }
  * { box-sizing: border-box; }
  body { margin: 0; padding: 20px; background: var(--bg); color: var(--ink);
         font: 14px/1.45 system-ui, sans-serif; }
  h1 { font-size: 16px; font-weight: 600; margin: 0 0 4px; }
  .sub { color: var(--ink-3); font-size: 12px; margin-bottom: 16px; }
  .tiles { display: flex; flex-wrap: wrap; gap: 10px; margin-bottom: 18px; }
  .tile { background: var(--surface); border: 1px solid var(--line);
          border-radius: 8px; padding: 10px 14px; min-width: 130px; }
  .tile .v { font: 600 22px/1.2 ui-monospace, monospace;
             font-variant-numeric: tabular-nums; }
  .tile .k { color: var(--ink-2); font-size: 11px; text-transform: uppercase;
             letter-spacing: .04em; margin-top: 2px; }
  h2 { font-size: 12px; font-weight: 600; color: var(--ink-2);
       text-transform: uppercase; letter-spacing: .05em; margin: 18px 0 6px; }
  table { border-collapse: collapse; width: 100%; background: var(--surface);
          border: 1px solid var(--line); border-radius: 8px; overflow: hidden; }
  th, td { text-align: left; padding: 5px 10px; border-top: 1px solid var(--line);
           font-variant-numeric: tabular-nums; }
  th { border-top: 0; color: var(--ink-3); font-size: 11px; font-weight: 600;
       text-transform: uppercase; letter-spacing: .04em; }
  td.num { font-family: ui-monospace, monospace; text-align: right; }
  th.num { text-align: right; }
  .dot { display: inline-block; width: 8px; height: 8px; border-radius: 50%;
         margin-right: 6px; vertical-align: baseline; }
  .st-loaded .dot, .st-registered .dot { background: var(--ok); }
  .st-launching .dot, .st-degraded .dot { background: var(--warn); }
  .st-dead .dot, .st-failed .dot { background: var(--bad); }
  .st-done .dot, .st-replaced .dot { background: var(--ink-3); }
  #events { font: 12px/1.5 ui-monospace, monospace; background: var(--surface);
            border: 1px solid var(--line); border-radius: 8px; padding: 8px 12px;
            max-height: 320px; overflow-y: auto; white-space: pre-wrap; }
  #events .t { color: var(--ink-3); }
  #err { color: var(--bad); font-size: 12px; min-height: 1em; }
</style>
</head>
<body>
<h1>cluster telemetry</h1>
<div class="sub" id="meta">connecting&hellip;</div>
<div id="err"></div>
<div class="tiles" id="tiles"></div>
<h2>nodes</h2>
<div id="nodes"></div>
<h2>jobs</h2>
<div id="jobs"></div>
<h2>histograms</h2>
<div id="hists"></div>
<h2>events</h2>
<div id="events"></div>
<script>
"use strict";
let cursor = 0;
const log = [];
const fmt = n => typeof n === "number"
  ? (Number.isInteger(n) ? n.toLocaleString("en-US") : n.toFixed(1)) : (n ?? "-");
const bytes = n => {
  if (typeof n !== "number") return "-";
  const u = ["B", "KB", "MB", "GB"]; let i = 0;
  while (n >= 1024 && i < u.length - 1) { n /= 1024; i++; }
  return (i ? n.toFixed(1) : n) + " " + u[i];
};
const esc = s => String(s).replace(/[&<>"]/g,
  c => ({"&":"&amp;","<":"&lt;",">":"&gt;",'"':"&quot;"}[c]));
const state = s =>
  `<span class="st-${esc(s)}"><span class="dot"></span>${esc(s)}</span>`;
function tile(v, k) {
  return `<div class="tile"><div class="v">${v}</div><div class="k">${esc(k)}</div></div>`;
}
function table(headers, rows) {
  if (!rows.length) return '<table><tr><td style="color:var(--ink-3)">none</td></tr></table>';
  const h = headers.map(([t, c]) => `<th class="${c || ""}">${esc(t)}</th>`).join("");
  return `<table><tr>${h}</tr>` + rows.map(cells =>
    "<tr>" + cells.map(([v, c]) => `<td class="${c || ""}">${v}</td>`).join("") +
    "</tr>").join("") + "</table>";
}
function render(snap, how) {
  const c = snap.cluster || {};
  const g = snap.gateway;
  document.getElementById("meta").textContent =
    `up ${fmt(Math.round(snap.uptime_s))}s · ${how} ${new Date().toLocaleTimeString()}`;
  document.getElementById("tiles").innerHTML =
    tile(`${fmt(c.nodes_alive ?? 0)}/${fmt(c.nodes_total ?? 0)}`, "nodes alive") +
    tile(fmt(c.jobs_active ?? 0), "jobs active") +
    tile(fmt(c.jobs_completed ?? 0), "jobs completed") +
    tile(fmt(c.items_total ?? 0), "items collected") +
    tile(bytes((c.wire_bytes_sent ?? 0) + (c.wire_bytes_recv ?? 0)), "bytes moved") +
    tile(fmt(c.peer_forwarded ?? 0), "peer forwarded") +
    tile(bytes(c.host_relay_bytes ?? 0), "host relay bytes") +
    tile(fmt(c.redispatched ?? 0), "redispatched") +
    (g ? tile(fmt(g.queued ?? 0), "tickets queued") +
         tile(fmt(g.active ?? 0), "tickets active") +
         tile(`${fmt(c.scale_up_events ?? 0)}/${fmt(c.scale_down_events ?? 0)}`,
              "scale up/down") : "");
  const nodes = Object.entries(snap.nodes || {}).sort();
  document.getElementById("nodes").innerHTML = table(
    [["node"], ["state"], ["items", "num"], ["credits", "num"],
     ["sent", "num"], ["recv", "num"], ["peer out/in", "num"],
     ["blocks p/h", "num"], ["boot ms", "num"], ["cache h/m", "num"]],
    nodes.map(([id, n]) => {
      const w = n.wire || {}, r = n.report || {};
      return [[esc(id)], [state(n.state || "?")], [fmt(n.items), "num"],
        [fmt(n.credits), "num"], [bytes(w.bytes_sent), "num"],
        [bytes(w.bytes_recv), "num"],
        [`${bytes(r.peer_bytes_sent ?? 0)}/${bytes(r.peer_bytes_recv ?? 0)}`, "num"],
        [`${fmt(r.blocks_fetched_from_peers ?? 0)}/${fmt(r.blocks_fetched_from_host ?? 0)}`, "num"],
        [fmt(r.boot_ms), "num"],
        [`${fmt(r.cache_hits ?? 0)}/${fmt(r.cache_misses ?? 0)}`, "num"]];
    }));
  const jobs = Object.entries(snap.jobs || {}).sort((a, b) => a[0] - b[0]);
  document.getElementById("jobs").innerHTML = table(
    [["job"], ["state"], ["prio", "num"], ["pending", "num"],
     ["in flight", "num"], ["collected", "num"], ["dup drops", "num"],
     ["code ship/hit", "num"]],
    jobs.map(([id, j]) => {
      const sum = a => Array.isArray(a) ? a.reduce((x, y) => x + y, 0) : a;
      const st = j.error ? "failed" : (j.done ? "done" : "registered");
      return [[esc(id)], [state(st)], [fmt(j.priority), "num"],
        [fmt(sum(j.pending)), "num"], [fmt(sum(j.inflight)), "num"],
        [fmt(j.items_collected), "num"], [fmt(j.duplicates_dropped), "num"],
        [`${fmt(j.code_shipped ?? 0)}/${fmt(j.code_cached ?? 0)}`, "num"]];
    }));
  const hists = Object.entries(snap.histograms || {}).sort();
  document.getElementById("hists").innerHTML = table(
    [["metric"], ["count", "num"], ["mean", "num"], ["distribution (≤bound: n)"]],
    hists.map(([name, h]) => {
      const mean = h.count ? h.sum / h.count : 0;
      const dist = (h.buckets || [])
        .map(([le, n]) => `≤${le}: ${n}`).join("   ");
      return [[esc(name)], [fmt(h.count), "num"], [fmt(mean), "num"],
        [`<span style="color:var(--ink-2)">${esc(dist)}</span>`]];
    }));
}
function appendEvents(evts) {
  if (!evts.length) return;
  for (const e of evts) {
    cursor = Math.max(cursor, e.seq);
    const extra = Object.entries(e)
      .filter(([k]) => !["seq", "ts", "kind"].includes(k))
      .map(([k, v]) => `${k}=${JSON.stringify(v)}`).join(" ");
    log.push(`<span class="t">${new Date(e.ts * 1000).toLocaleTimeString()}` +
             `</span> ${esc(e.kind)} ${esc(extra)}`);
  }
  while (log.length > 200) log.shift();
  const el = document.getElementById("events");
  el.innerHTML = log.join("\\n");
  el.scrollTop = el.scrollHeight;
}
// Primary transport: the SSE feed pushes snapshots + bus events as they
// happen.  Fallback: the 1 s poll loop, for clients without EventSource
// or when the stream dies and cannot be re-opened.
let pollTimer = null;
async function poll() {
  let snap;
  try {
    snap = await (await fetch("metrics")).json();
    document.getElementById("err").textContent = "";
  } catch (e) {
    document.getElementById("err").textContent = "endpoint unreachable: " + e;
    return;
  }
  render(snap, "polled");
  try {
    const ev = await (await fetch(`events?since=${cursor}`)).json();
    appendEvents(ev.events);
  } catch (e) { /* metrics succeeded; keep the page alive */ }
}
function startPolling() {
  if (pollTimer) return;
  poll();
  pollTimer = setInterval(poll, 1000);
}
function startStream() {
  if (typeof EventSource === "undefined") { startPolling(); return; }
  const es = new EventSource(`events/stream?since=${cursor}`);
  es.addEventListener("snapshot", ev => {
    document.getElementById("err").textContent = "";
    render(JSON.parse(ev.data), "streamed");
  });
  es.addEventListener("bus", ev => appendEvents([JSON.parse(ev.data)]));
  es.onerror = () => {
    es.close();
    document.getElementById("err").textContent =
      "event stream dropped; falling back to polling";
    startPolling();
  };
}
startStream();
</script>
</body>
</html>
"""
