"""SSHLauncher: fan the identical node-loader command out over ssh.

This is the paper's deployment made literal — the arXiv:1708.05264 cluster
report boots its Raspberry-Pi farm by running one identical command per host
over ssh, and our node-loader was designed for exactly that shape: it needs
nothing but ``--host <ip> --port 2000``.  The launcher runs

    ssh <workstation> 'cd <dir> && env PYTHONPATH=... python -m
        repro.cluster.node_loader --host <hnl-ip> --port <p> --node-id <id>'

once per node, round-robining over ``hosts``; a respawn avoids the machine
that already swallowed a launch (``avoid``).  The local ssh client process
*is* the node handle — killing it tears down the remote session (the
default opts force a pty with ``-tt`` precisely so sshd HUPs the remote
command), and its stdout/stderr are the remote node's logs.

**Code sync.**  Work functions shipped by value (cloudpickle) need only
their libraries; code shipped *by reference* (plain-pickle fallback, user
modules, the shared ``compile_cache_dir`` story) needs this repo's ``src``
tree on the remote filesystem.  Three modes via ``remote_dir``:

* ``None`` (default) — assume a shared or identical filesystem (NFS'd home
  directories, the classic idle-workstation pool; also exactly right for
  ssh-to-localhost): the remote ``PYTHONPATH`` replicates this process's
  ``sys.path``.
* a path + ``sync="rsync"|"tar"|"auto"`` — push ``src`` to
  ``<host>:<remote_dir>/src`` before the first launch: ``rsync -az`` when
  available, else a ``tar -cf - | ssh tar -xf -`` pipeline (``auto`` picks).

Node-loaders started remotely race the host's listener, so launches always
pass ``--connect-timeout`` and the node-loader retries its dial with
backoff — start ordering is uncontrolled on a real network.
"""

from __future__ import annotations

import os
import shlex
import shutil
import subprocess
from typing import Mapping, Sequence

from repro.cluster.deploy.base import Launcher
from repro.cluster.deploy.local import (
    PopenNodeHandle,
    jax_node_env,
    node_loader_argv,
)

# The tree that holds ``src``: ssh.py -> deploy -> cluster -> repro -> src
# -> checkout root.  Syncs ship ``<source_root>/src`` to the remote side.
_SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))))))

_DEFAULT_SSH_OPTS = (
    "-o", "BatchMode=yes",
    "-o", "StrictHostKeyChecking=accept-new",
    # Force a pty: without one sshd does NOT signal the remote command when
    # the client dies, so kill()ing the local ssh process would leave a
    # live node-loader on the workstation.  With a pty the hangup reaches
    # the remote process group — kill() means what NodeHandle says it
    # means.  (Cost: remote stderr merges into stdout in the logs.)
    "-tt",
)


class SSHLauncher(Launcher):
    """Starts node-loaders on remote workstations over ssh."""

    def __init__(
        self,
        hosts: Sequence[str],
        *,
        connect_host: str | None = None,
        python: str = "python3",
        remote_dir: str | None = None,
        sync: str = "auto",
        ssh_cmd: Sequence[str] = ("ssh",),
        ssh_opts: Sequence[str] | None = None,
        env: Mapping[str, str] | None = None,
        preload: Sequence[str] = (),
        compile_cache_dir: str | None = None,
        connect_timeout: float = 60.0,
        source_root: str = _SRC_ROOT,
    ):
        if not hosts:
            raise ValueError("SSHLauncher needs at least one host")
        if sync not in ("auto", "rsync", "tar", "none"):
            raise ValueError(f"unknown sync mode {sync!r}")
        self.hosts = list(hosts)
        self.connect_host = connect_host
        self.python = python
        self.remote_dir = remote_dir
        self.sync = sync
        self.ssh_cmd = tuple(ssh_cmd)
        self.ssh_opts = tuple(
            _DEFAULT_SSH_OPTS if ssh_opts is None else ssh_opts
        )
        self.env = dict(env or {})
        self.preload = tuple(preload)
        self.compile_cache_dir = compile_cache_dir
        self.connect_timeout = connect_timeout
        self.source_root = source_root
        self.port = 0
        self._next_host = 0
        self.synced_hosts: list[str] = []

    # -- preparation --------------------------------------------------------

    def prepare(self, connect_host: str, port: int) -> None:
        # An explicitly configured LAN-reachable connect_host always wins:
        # the application's bind address ("0.0.0.0", or a loopback default)
        # is generally not what a *remote* machine can dial.  Without one,
        # fall back to the bind address — correct for ssh-to-localhost.
        if self.connect_host is None:
            self.connect_host = (
                "127.0.0.1" if connect_host in ("0.0.0.0", "")
                else connect_host
            )
        self.port = port
        if self.remote_dir is not None and self.sync != "none":
            for host in dict.fromkeys(self.hosts):  # unique, ordered
                self.sync_code(host)

    def sync_code(self, host: str) -> None:
        """Push the ``src`` tree to ``host:remote_dir/src``."""
        method = self.sync
        if method == "auto":
            method = "rsync" if shutil.which("rsync") else "tar"
        if method == "rsync":
            self._sync_rsync(host)
        else:
            self._sync_tar(host)
        self.synced_hosts.append(host)

    def _ssh_argv(self, host: str, command: str) -> list[str]:
        return [*self.ssh_cmd, *self.ssh_opts, host, command]

    @staticmethod
    def _sh_expr(path: str) -> str:
        """Quote a remote path for sh, keeping a leading ``~`` expandable.

        ``shlex.quote("~/x")`` would make the remote shell look for a
        literal ``./~`` directory; home-relative paths (the natural way to
        name a per-user deploy dir) must go through ``$HOME`` instead.
        """
        if path == "~":
            return '"$HOME"'
        if path.startswith("~/"):
            return '"$HOME"/' + shlex.quote(path[2:])
        return shlex.quote(path)

    def _sync_rsync(self, host: str) -> None:
        self._run_checked(self._ssh_argv(
            host, f"mkdir -p {self._sh_expr(self.remote_dir)}"
        ))
        rsh = " ".join(shlex.quote(a) for a in (*self.ssh_cmd, *self.ssh_opts))
        self._run_checked([
            "rsync", "-az", "--delete", "--exclude", "__pycache__",
            "-e", rsh,
            os.path.join(self.source_root, "src") + "/",
            f"{host}:{self.remote_dir}/src/",
        ])

    def _sync_tar(self, host: str) -> None:
        """``tar -cf - src | ssh host 'mkdir -p dir && tar -xf - -C dir'`` —
        the no-rsync fallback (one round, no deletion of stale files)."""
        tar = subprocess.Popen(
            ["tar", "-C", self.source_root, "--exclude", "__pycache__",
             "-cf", "-", "src"],
            stdout=subprocess.PIPE,
        )
        remote = (f"mkdir -p {self._sh_expr(self.remote_dir)} && "
                  f"tar -xf - -C {self._sh_expr(self.remote_dir)}")
        try:
            untar = subprocess.run(
                self._ssh_argv(host, remote),
                stdin=tar.stdout, capture_output=True, text=True,
                timeout=120,
            )
        finally:
            tar.stdout.close()
            tar_rc = tar.wait()
        if tar_rc != 0 or untar.returncode != 0:
            raise RuntimeError(
                f"code sync to {host} failed (tar rc={tar_rc}, "
                f"ssh rc={untar.returncode}): {untar.stderr.strip()}"
            )

    @staticmethod
    def _run_checked(argv: list[str]) -> None:
        proc = subprocess.run(argv, capture_output=True, text=True,
                              timeout=120)
        if proc.returncode != 0:
            raise RuntimeError(
                f"{argv[0]} failed (rc={proc.returncode}): "
                f"{proc.stderr.strip()}"
            )

    # -- launching ----------------------------------------------------------

    def _pick_host(self, avoid: Sequence[str]) -> str:
        avoided = {a.removeprefix("ssh:") for a in avoid}
        for _ in range(len(self.hosts)):
            host = self.hosts[self._next_host % len(self.hosts)]
            self._next_host += 1
            if host not in avoided:
                return host
        # Every host already failed a launch: reuse the rotation anyway —
        # a retry on a flaky machine beats not retrying at all.
        host = self.hosts[self._next_host % len(self.hosts)]
        self._next_host += 1
        return host

    def _remote_env(self) -> dict[str, str]:
        if self.remote_dir is not None:
            pythonpath = f"{self.remote_dir}/src"
        else:  # shared/identical filesystem: replicate this process's path
            import sys

            pythonpath = os.pathsep.join(p for p in sys.path if p)
        env = {"PYTHONPATH": pythonpath,
               **jax_node_env(self.compile_cache_dir)}
        env.update(self.env)
        return env

    def remote_command(self, node_id: str) -> str:
        argv = node_loader_argv(
            self.connect_host, self.port, node_id,
            python=self.python, preload=self.preload,
            connect_timeout=self.connect_timeout,
        )
        # Env values quote through _sh_expr so a home-relative remote_dir
        # lands in PYTHONPATH as "$HOME"/... rather than a literal tilde.
        exports = " ".join(
            f"{k}={self._sh_expr(v)}" for k, v in self._remote_env().items()
        )
        cmd = f"env {exports} " + " ".join(shlex.quote(a) for a in argv)
        if self.remote_dir is not None:
            cmd = f"cd {self._sh_expr(self.remote_dir)} && {cmd}"
        return cmd

    def launch(self, node_id: str, *,
               avoid: Sequence[str] = ()) -> PopenNodeHandle:
        host = self._pick_host(avoid)
        proc = subprocess.Popen(
            self._ssh_argv(host, self.remote_command(node_id)),
            stdin=subprocess.DEVNULL,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        )
        return PopenNodeHandle(node_id, proc, where=f"ssh:{host}")
