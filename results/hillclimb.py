"""Hillclimb measurement driver: compile a 1-period probe of a config
variant and report (flops, bytes, collective link bytes) per device."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, json, sys, time
import jax
from repro.configs.registry import get_config, get_shape
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.core.builder import ClusterBuilder
from repro.core.channels import ShardingRules, training_rules, _common_weight_rules

def measure(arch, shape_name, variant_name, cfg_overrides=None, seq_sp=True, layers=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    p = len(cfg.layer_pattern)
    cfg = dataclasses.replace(cfg, num_layers=layers or p, scan_layers=False,
                              unroll_scans=True, **(cfg_overrides or {}))
    mesh = make_production_mesh()
    fn, args, donate, rules, tp = build_cell(cfg, shape, mesh)
    if not seq_sp:
        rules = ShardingRules(mesh, [
            ("batch", ("pod", "data")), ("batch", ("data",)),
            ("seq_sp", None), ("seq", None), ("d_model", None),
        ] + _common_weight_rules())
        fn, args, donate, _r, tp = build_cell(cfg, shape, mesh)
        # rebuild with substituted rules
        from repro.runtime import steps as steps_mod
        from repro.optim.adamw import AdamWConfig
        opt_cfg = AdamWConfig()
        fn = steps_mod.make_train_step(cfg, opt_cfg, tp=tp, rules=rules)
        pst, ost = steps_mod.train_state_structs(cfg, rules, tp, opt_cfg)
        b = steps_mod.batch_structs(cfg, shape, rules)
        import jax.numpy as jnp
        args = (pst, ost, b, jax.ShapeDtypeStruct((), jnp.int32))
    t0 = time.time()
    art = ClusterBuilder(mesh=mesh, rules=rules).build_step(fn, args, donate_argnums=donate)
    c = art.cost(); colls = art.collectives()
    ma = art.memory()
    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    print(f"{variant_name:<42} flops {c['flops_per_device']:.3e}  "
          f"bytes {c['bytes_per_device']:.3e}  "
          f"coll {colls.total_link_bytes/2**30:6.2f} GiB  "
          f"mem {live/2**30:6.2f} GiB  ({time.time()-t0:.0f}s)", flush=True)
    return c, colls

if __name__ == "__main__":
    for spec in json.loads(sys.argv[1]):
        measure(**spec)
