"""Jitted wrapper for the RG-LRU scan kernel (padding + backend dispatch)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.channels import padded_size
from repro.kernels.rglru.kernel import BLOCK_W, rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_scan_reference


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "block_w"))
def rglru_scan(
    a: jax.Array,  # [B, S, W] per-step decay in (0, 1]
    b: jax.Array,  # [B, S, W] gated input
    h0: jax.Array | None = None,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
    block_w: int = BLOCK_W,
):
    if not use_pallas:
        return rglru_scan_reference(a, b, h0)
    B, S, W = a.shape
    bw = min(block_w, padded_size(W, 128))
    Wp = padded_size(W, bw)
    if Wp != W:
        pad = ((0, 0), (0, 0), (0, Wp - W))
        a = jnp.pad(a, pad)
        b = jnp.pad(b, pad)
        if h0 is not None:
            h0 = jnp.pad(h0, ((0, 0), (0, Wp - W)))
    h, hlast = rglru_scan_pallas(a, b, h0, block_w=bw, interpret=interpret)
    return h[..., :W], hlast[..., :W]
