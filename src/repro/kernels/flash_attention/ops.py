"""Jitted wrapper for the flash-attention kernel (padding + GQA expansion)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.channels import padded_size
from repro.kernels.flash_attention.kernel import (
    BLOCK_K,
    BLOCK_Q,
    flash_attention_pallas,
)
from repro.kernels.flash_attention.ref import attention_reference


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KV, Skv, D] (KV divides H: GQA broadcast)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool = True,
    interpret: bool = True,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    if H != KV:
        if H % KV:
            raise ValueError(f"H={H} not a multiple of KV={KV}")
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if not use_pallas:
        return attention_reference(q, k, v, causal=causal, window=window)
    Skv = k.shape[2]
    bq = min(block_q, padded_size(Sq, 8))
    bk = min(block_k, padded_size(Skv, 8))
    Sqp, Skvp = padded_size(Sq, bq), padded_size(Skv, bk)
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skvp != Skv:
        # Padded keys are masked inside the kernel via the skv guard; pass
        # the padded arrays but keep the true length through the mask by
        # padding K with a large negative-free value (zeros are fine: the
        # in-kernel `k_pos < skv` guard uses the padded skv, so instead we
        # mask by causality — pad conservatively with zeros and rely on
        # q_pos < Sq rows being dropped below).
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0)))
        if not causal:
            raise ValueError("non-causal ragged Skv unsupported; pad upstream")
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :, :Sq]
