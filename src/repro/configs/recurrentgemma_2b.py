"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attention:recurrent
(Griffin, arXiv:2402.19427; hf).  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  Sub-quadratic (recurrent state + 2048-token window), so it
runs the long_500k shape."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("rec", "rec", "local"),
    window_size=2048,
    rnn_width=2560,
    conv1d_width=4,
    logit_softcap=30.0,
    supports_long_context=True,
)
