"""AdamW with global-norm clipping — functional, shard-transparent.

Optimizer state inherits parameter shardings leaf-by-leaf (FSDP: the ZeRO-3
partitioning of m/v comes for free from the builder's param shardings).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Optimizer-state dtype: float32 (default) or bfloat16 (memory-lean mode,
    # a distributed-optimization knob surfaced to the hillclimb).
    state_dtype: str = "float32"


def init_state(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params: Any,
    grads: Any,
    state: dict,
    cfg: AdamWConfig,
    lr: jax.Array,
) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m1 / b1c
        vhat = v1 / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), \
            m1.astype(sdt), v1.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
