"""Data pipeline, optimizer, compression, checkpoint substrates."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataPipeline, SyntheticLM, emit_details_for
from repro.optim import adamw, compression
from repro.optim.schedule import warmup_cosine


# -- data --------------------------------------------------------------------


def test_synthetic_stream_deterministic_and_seekable():
    src = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4, seed=3)
    b5 = src.batch(5)
    again = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4, seed=3).batch(5)
    np.testing.assert_array_equal(b5["tokens"], again["tokens"])
    assert b5["tokens"].shape == (4, 16)
    assert (b5["tokens"] < 1000).all()
    # next-token structure
    np.testing.assert_array_equal(b5["targets"][:, :-1], b5["tokens"][:, 1:])
    # different steps differ
    assert not np.array_equal(b5["tokens"], src.batch(6)["tokens"])


def test_pipeline_prefetch_consistent():
    src = SyntheticLM(vocab_size=100, seq_len=8, global_batch=2)
    pipe = DataPipeline(src, rules=None)
    pipe.prefetch(0)
    b0 = pipe.get(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]), src.batch(0)["tokens"])


def test_emit_adapter_terminates():
    src = SyntheticLM(vocab_size=10, seq_len=4, global_batch=1)
    details = emit_details_for(src, num_steps=3)
    state = details.initial_state()
    seen = []
    while True:
        item, state = details.create(state)
        if item is None:
            break
        seen.append(item[0])
    assert seen == [0, 1, 2]


# -- optimizer ------------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    cfg = adamw.AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _m = adamw.apply_updates(params, grads, state, cfg,
                                                jnp.float32(0.05))
    assert float(loss(params)) < 1e-2


def test_grad_clipping_bounds_update():
    cfg = adamw.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params, cfg)
    grads = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    _p, _s, metrics = adamw.apply_updates(params, grads, state, cfg,
                                          jnp.float32(0.1))
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < 0.2
    assert abs(max(lrs) - 1.0) < 1e-6
    assert lrs[-1] < 0.2
    assert np.argmax(lrs) <= 11


# -- gradient compression ----------------------------------------------------------


@given(mode=st.sampled_from(["bf16", "int8"]), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_converges(mode, seed):
    """Sum of (decompressed + carried error) over steps == sum of true grads:
    error feedback guarantees no systematic bias."""
    rng = np.random.default_rng(seed)
    g_true = [rng.standard_normal((4, 8)).astype(np.float32) for _ in range(20)]
    grads_template = {"w": jnp.zeros((4, 8))}
    err = compression.init_error_feedback(grads_template)
    applied = np.zeros((4, 8), np.float32)
    for g in g_true:
        wire, meta, err = compression.compress({"w": jnp.asarray(g)}, err, mode)
        deq = compression.decompress(wire, meta, mode)
        applied += np.asarray(deq["w"])
    total_true = np.sum(g_true, axis=0)
    resid = np.asarray(jax.tree.leaves(err)[0])
    np.testing.assert_allclose(applied + resid, total_true, atol=1e-2)


def test_compression_wire_size():
    g = {"w": jnp.zeros((64, 128), jnp.float32)}
    err = compression.init_error_feedback(g)
    wire_b, _, _ = compression.compress(g, err, "bf16")
    assert compression.wire_bytes(wire_b, "bf16") == 64 * 128 * 2
    wire_i, _, _ = compression.compress(g, err, "int8")
    assert compression.wire_bytes(wire_i, "int8") <= 64 * 128 * 1 + 64 * 4


# -- checkpoint --------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "opt": {"count": jnp.int32(7)}}
        for step in (1, 2, 3, 4):
            mgr.save(step, state, {"config_hash": "abc"})
        assert mgr.all_steps() == [3, 4]  # gc kept last 2
        step, restored, manifest = mgr.restore()
        assert step == 4
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.arange(6.0).reshape(2, 3))
        assert manifest["config_hash"] == "abc"


def test_checkpoint_meta_mismatch_refused():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.zeros(2)}, {"config_hash": "A"})
        with pytest.raises(ValueError, match="mismatch"):
            mgr.restore(expect_meta={"config_hash": "B"})


def test_checkpoint_async_and_atomic():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save_async(5, {"w": jnp.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 5
        assert not any(n.endswith(".tmp") for n in os.listdir(d))
