"""Quickstart — the paper's own example, end to end.

Builds the Mandelbrot application from a textual ``.cgpp`` specification
(Listing 2 of the paper), verifies the deployment formally (section 7),
prints the generated deployment plan (section 4 / figure 1), runs it on the
local cluster runtime (section 6.1 single-host mode) and reports the paper's
counts + per-node timing (requirement 7).

Run:  PYTHONPATH=src python examples/quickstart.py
      PYTHONPATH=src python examples/quickstart.py cluster   # real subprocesses
"""

import sys

import jax.numpy as jnp

from repro.core.builder import ClusterBuilder
from repro.core.dsl import parse_cgpp
from repro.core.verify import verify_spec
from repro.kernels.mandelbrot.ops import mandelbrot
from repro.kernels.mandelbrot.ref import line_coords

WIDTH = 700          # paper: 5600
LINES = 400          # paper: 3200
MAX_ITERATIONS = 250  # paper: 1000

SPEC = """
# Mandelbrot DSL specification (paper Listing 2), python-flavoured .cgpp
cores = 4
clusters = 2
max_iterations = %(iters)d
width = %(width)d

//@emit 192.168.1.176
emit_details = DataDetails(
    name="Mdata",
    init=lambda width, iters: (0, %(lines)d),
    init_data=(width, max_iterations),
    create=lambda s: (None, s) if s[0] >= s[1] else (s[0], (s[0] + 1, s[1])),
)
emit = Emit(e_details=emit_details)
onrl = OneNodeRequestedList()

//@cluster clusters
nrfa = NodeRequestingFanAny(destinations=cores)
group = AnyGroupAny(workers=cores, function=CALCULATE)
afoc = AnyFanOne(sources=cores)

//@collect
result_details = ResultDetails(
    name="Mcollect",
    init=lambda: dict(points=0, white=0, black=0, total_iters=0),
    collect=COLLECTOR,
    finalise=lambda acc: acc,
)
afo = AnyFanOne(sources=clusters)
collector = Collect(r_details=result_details)
"""


def calculate(line_y: int):
    """The user's sequential data method (paper Mdata.calculateColour)."""
    x0, y0 = line_coords(WIDTH, line_y)
    iters, colour = mandelbrot(x0[None], y0[None], max_iters=MAX_ITERATIONS)
    return {
        "points": WIDTH,
        "white": int(jnp.sum(colour)),
        "total_iters": int(jnp.sum(iters)),
    }


def collector(acc, item):
    acc["points"] += item["points"]
    acc["white"] += item["white"]
    acc["black"] += item["points"] - item["white"]
    acc["total_iters"] += item["total_iters"]
    return acc


def main() -> None:
    spec = parse_cgpp(
        SPEC % {"iters": MAX_ITERATIONS, "width": WIDTH, "lines": LINES},
        namespace={"CALCULATE": calculate, "COLLECTOR": collector},
    )
    print(f"parsed spec: {spec.nclusters} nodes x {spec.workers_per_node} workers\n")

    report = verify_spec(spec, num_objects=4)
    print(report.summary(), "\n")
    assert report.ok, "deployment must be provably deadlock/livelock free"

    builder = ClusterBuilder()
    print(builder.deployment_plan(spec).describe(), "\n")

    # "cluster" runs the identical spec over real node-loader subprocesses
    # connected by TCP (repro.cluster, paper §4) instead of threads.
    backend = sys.argv[1] if len(sys.argv) > 1 else "threads"
    app = builder.build_application(spec, backend=backend)
    result = app.run()
    # paper prints: points, whiteCount, blackCount, totalIters
    print(f"{result['points']}, {result['white']}, {result['black']}, "
          f"{result['total_iters']}")
    print()
    print(builder.timing.report())


if __name__ == "__main__":
    main()
