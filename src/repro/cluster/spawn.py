"""Single-machine launcher: N real node-loader subprocesses + in-process HNL.

The paper's §6.1 workflow — "operation and testing of a system can be
conducted on a single host node before using multiple nodes" — with true
process isolation: each Node-Loader is a fresh ``python -m
repro.cluster.node_loader`` OS process talking TCP on localhost, so there is
no GIL coupling and killing one is a *real* node death, not an injected one.
Moving to many hosts later is only a matter of starting the same command on
other machines (the node-loader needs nothing but the host address).

The launcher exports the host's ``sys.path`` to the children so code shipped
by reference (plain-pickle fallback, user modules) resolves; code shipped by
value (cloudpickle closures) needs only the libraries it imports.
"""

from __future__ import annotations

import collections
import os
import subprocess
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.cluster.host_loader import HostLoader
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor


def _child_env(compile_cache_dir: str | None = None) -> dict[str, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
    # Node-loaders are bootstrap processes: keep their (transitive) jax happy
    # on CPU-only machines and their thread pools small.
    env.setdefault("JAX_PLATFORMS", "cpu")
    if compile_cache_dir:
        # Cluster-wide XLA compilation cache: the host's warm-up compile
        # lands on disk and every node-loader loads the binary instead of
        # recompiling — the paper's single-source code-shipping idea applied
        # to executables.
        env["JAX_COMPILATION_CACHE_DIR"] = compile_cache_dir
        env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    return env


def spawn_node_loader(host: str, port: int, node_id: str,
                      *, python: str = sys.executable,
                      preload: tuple[str, ...] = (),
                      compile_cache_dir: str | None = None
                      ) -> subprocess.Popen:
    """Start one Node-Loader subprocess (the §4 'identical executable').

    ``preload`` names modules the child imports concurrently with its
    registration (e.g. ``("jax.numpy",)``), so heavy environment boot
    overlaps the load-network handshake instead of serializing after it.
    """
    cmd = [python, "-m", "repro.cluster.node_loader",
           "--host", host, "--port", str(port), "--node-id", node_id]
    if preload:
        cmd += ["--preload", ",".join(preload)]
    return subprocess.Popen(
        cmd,
        env=_child_env(compile_cache_dir),
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )


@dataclass
class ProcessClusterApplication:
    """Runnable returned by ``build_application(spec, backend="cluster")``.

    Same contract as ``runtime.local.LocalClusterApplication`` — ``run()``
    blocks to completion and returns the finalised result — but the workers
    are real subprocesses.  ``slowdown`` maps node ids to an artificial
    seconds-per-item delay (straggler injection for §6.1-style testing);
    ``kill_node`` turns a live subprocess into a real mid-job node death.
    """

    spec: Any
    plan: Any
    timing: TimingCollector
    port: int = 0  # 0 = ephemeral; the paper's deployment would fix 2000
    # Defaults tolerate multi-second GC/compile stalls in work functions;
    # tests override with much tighter settings.
    heartbeat_interval: float = 0.5
    heartbeat_misses: int = 10
    job_timeout: float = 300.0
    shutdown_grace: float = 10.0
    slowdown: dict[str, float] = field(default_factory=dict)
    artifacts: dict[str, bytes] = field(default_factory=dict)
    # Data-plane knobs (see ARCHITECTURE.md "Data plane"): modules each
    # node pre-imports during boot; extra items beyond `workers` the node
    # keeps buffered (None = one per worker); and the node-side result
    # coalescing threshold/interval.
    preload: tuple[str, ...] = ()
    prefetch: int | None = None
    flush_items: int = 8
    flush_interval: float = 0.005
    # Directory for a shared XLA compilation cache (host warms it, nodes
    # load instead of recompiling).  None = no persistent cache.
    compile_cache_dir: str | None = None

    host_loader: HostLoader | None = None
    processes: dict[str, subprocess.Popen] = field(default_factory=dict)
    # Last lines of each node-loader's stdout+stderr (drained continuously so
    # a chatty child never blocks on a full pipe; kept for diagnostics).
    node_logs: dict[str, "collections.deque[str]"] = field(default_factory=dict)
    result: Any = None
    error: BaseException | None = None  # set by run_async on failure
    _ran: bool = False
    _drainers: list[threading.Thread] = field(default_factory=list)

    def node_ids(self) -> list[str]:
        return [f"node{i}" for i in range(self.spec.nclusters)]

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Bootstrap the load network and fork the node-loaders."""
        self.host_loader = HostLoader(
            self.spec,
            self.timing,
            port=self.port,
            heartbeat=HeartbeatMonitor(
                interval_s=self.heartbeat_interval,
                misses=self.heartbeat_misses,
            ),
            job_timeout=self.job_timeout,
            slowdown=self.slowdown,
            artifacts=self.artifacts,
            prefetch=self.prefetch,
            flush_items=self.flush_items,
            flush_interval=self.flush_interval,
        )
        self.host_loader.start()
        for node_id in self.node_ids():
            proc = spawn_node_loader(
                "127.0.0.1", self.host_loader.port, node_id,
                preload=tuple(self.preload),
                compile_cache_dir=self.compile_cache_dir,
            )
            self.processes[node_id] = proc
            self.node_logs[node_id] = collections.deque(maxlen=200)
            for stream in (proc.stdout, proc.stderr):
                t = threading.Thread(
                    target=self._drain, args=(node_id, stream),
                    name=f"drain-{node_id}", daemon=True,
                )
                t.start()
                self._drainers.append(t)

    def _drain(self, node_id: str, stream) -> None:
        for line in stream:
            self.node_logs[node_id].append(line.rstrip("\n"))
        stream.close()

    def run(self) -> Any:
        if self._ran:
            raise RuntimeError("application already ran; build a fresh one")
        self._ran = True
        if self.host_loader is None:
            self.start()
        try:
            self.result = self.host_loader.run()
        finally:
            self._shutdown()
        return self.result

    def run_async(self) -> threading.Thread:
        """Start and run in a background thread (lets callers kill nodes
        mid-job); join the returned thread, then read ``result``/``error``."""

        def target() -> None:
            try:
                self.run()
            except BaseException as exc:  # surfaced via .error, not stderr
                self.error = exc

        t = threading.Thread(target=target, name="cluster-app", daemon=True)
        t.start()
        return t

    def kill_node(self, node_id: str) -> None:
        """SIGKILL a node-loader: a real workstation loss, detected only by
        its heartbeats going silent."""
        self.processes[node_id].kill()

    # -- teardown -----------------------------------------------------------

    def _shutdown(self) -> None:
        # Close the host's sockets first: surviving node-loaders blocked on
        # the application channel see ChannelClosed and exit promptly
        # (milliseconds, exit 0) instead of burning the grace period.
        if self.host_loader is not None:
            self.host_loader.close()
        deadline = time.monotonic() + self.shutdown_grace
        for node_id, proc in self.processes.items():
            remaining = max(0.0, deadline - time.monotonic())
            try:
                proc.wait(timeout=remaining)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
        for t in self._drainers:  # EOF arrives once the child exits
            t.join(timeout=5.0)

    def orphaned(self) -> list[str]:
        """Node-loaders still running after shutdown (must be empty)."""
        return [nid for nid, p in self.processes.items()
                if p.returncode is None]
