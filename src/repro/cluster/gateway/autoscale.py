"""Queue-driven pool autoscaling: grow on backlog, retire when idle.

The pool's geometry was fixed at boot; the gateway makes demand visible
(queue depth, oldest wait) — this control loop closes the loop through the
deployment layer's two existing elasticity paths:

* **up** — ``ClusterService.grow()`` launches fresh node-loaders that take
  the mid-run *late-join* path (REGISTER after the barrier → pool LOAD +
  every active job's LOAD + peer-directory broadcast);
* **down** — ``ClusterService.shrink()`` sends one node the *graceful
  retirement* UT: it drains its queued items, flushes, returns its timing
  record and exits; anything still in flight host-side is requeued exactly
  as a death would be, minus the death.

Scaling is bounded by ``min_nodes``/``max_nodes``, rate-limited by a
cooldown (a grow decision must not repeat while the launch it triggered is
still booting), and shrink only fires after the gateway has been fully
idle for ``idle_shrink_s``.  Every decision is a telemetry event plus the
``scale_up_events``/``scale_down_events`` counters CI gates on.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

__all__ = ["AutoscalePolicy", "Autoscaler"]


@dataclass
class AutoscalePolicy:
    """Bounds and thresholds for the control loop."""

    min_nodes: int = 1
    max_nodes: int = 4
    #: scale up when the oldest queued ticket waited this long...
    scale_up_wait_s: float = 1.0
    #: ...or when (queued + running) demand exceeds this per pool node.
    backlog_per_node: float = 4.0
    #: nodes launched per scale-up decision.
    step: int = 1
    #: no queued or running work for this long before retiring a node.
    idle_shrink_s: float = 10.0
    #: minimum seconds between scaling decisions (covers launch boot).
    cooldown_s: float = 3.0
    #: control loop period.
    interval_s: float = 0.25

    def validate(self) -> None:
        if self.min_nodes < 1:
            raise ValueError("min_nodes must be >= 1")
        if self.max_nodes < self.min_nodes:
            raise ValueError("max_nodes must be >= min_nodes")
        if self.step < 1:
            raise ValueError("step must be >= 1")


class Autoscaler:
    """The control thread; owned (started/stopped) by a JobGateway."""

    def __init__(self, gateway, policy: AutoscalePolicy):
        policy.validate()
        self.gateway = gateway
        self.policy = policy
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._last_scale = 0.0
        self._idle_since: float | None = None

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._loop,
                                        name="gateway-autoscale",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # -- the control loop ----------------------------------------------------

    def _loop(self) -> None:
        pol = self.policy
        while not self._stop.wait(pol.interval_s):
            try:
                self._step(time.monotonic())
            except Exception:
                # The pool may be mid-teardown under us; scaling is an
                # optimisation and must never take the gateway down.
                continue

    def _step(self, now: float) -> None:
        pol = self.policy
        gw = self.gateway
        service = gw.service
        queued = gw.queued_count()
        running = gw.active_count()
        wait_s = gw.oldest_queued_wait()
        alive, launching = service.pool_span()
        span = alive + launching  # capacity present or already on its way
        demand = queued + running
        if demand > 0:
            self._idle_since = None
        elif self._idle_since is None:
            self._idle_since = now
        if now - self._last_scale < pol.cooldown_s:
            return
        if queued > 0 and span < pol.max_nodes and (
                wait_s >= pol.scale_up_wait_s
                or span == 0
                or demand > span * pol.backlog_per_node):
            n = min(pol.step, pol.max_nodes - span)
            service.grow(n, reason="queue_backlog")
            self._last_scale = now
            return
        if (demand == 0 and alive > pol.min_nodes
                and self._idle_since is not None
                and now - self._idle_since >= pol.idle_shrink_s):
            if service.shrink(reason="pool_idle") is not None:
                self._last_scale = now
