"""Load-time vs run-time accounting (paper requirement 7).

ClusterBuilder collects, per node, the time spent *loading* the application
(code distribution, channel construction, synchronisation barriers) separately
from the time spent *running* it.  On termination every node returns its
timings to the host, which combines them with its own and prints the table
(paper §4, §8.2: load time was linear in the node count, 132.5 +/- 2.5 ms per
node, and under 1% of total run time).

Beyond the paper we account a third phase, *boot*: the cost of standing up a
node's environment (interpreter start, heavy-dependency imports) before any
code distribution happens.  The paper's workstations pre-exist with a warm
JVM, so §8.2's ~132 ms/node load figure excludes it; splitting boot out keeps
our load numbers comparable.

The collector also aggregates *wire counters* — bytes/frames/round-trips the
cluster transport moved per run — fed by the host loader and reported by
``benchmarks/run.py`` so data-plane regressions are visible as counts, not
just seconds.

This module is runtime-agnostic: the local threaded runtime, the SPMD
executor and the dry-run all record into the same structure.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass


_PHASES = ("boot", "load", "run")


@dataclass
class NodeTiming:
    """Timing record for a single (logical) node."""

    node_id: str
    boot_ms: float = 0.0
    load_ms: float = 0.0
    run_ms: float = 0.0
    items: int = 0

    def as_dict(self) -> dict:
        return {
            "node_id": self.node_id,
            "boot_ms": round(self.boot_ms, 3),
            "load_ms": round(self.load_ms, 3),
            "run_ms": round(self.run_ms, 3),
            "items": self.items,
        }


class TimingCollector:
    """Thread-safe collector of per-node boot/load/run timings.

    Usage::

        tc = TimingCollector()
        with tc.phase("node0", "load"):
            ...  # channel construction, code transfer
        with tc.phase("node0", "run"):
            ...  # application processing
        print(tc.report())
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._nodes: dict[str, NodeTiming] = {}
        self._wire: dict[str, float] = {}

    def node(self, node_id: str) -> NodeTiming:
        with self._lock:
            if node_id not in self._nodes:
                self._nodes[node_id] = NodeTiming(node_id=node_id)
            return self._nodes[node_id]

    def phase(self, node_id: str, kind: str) -> "_PhaseTimer":
        if kind not in _PHASES:
            raise ValueError(
                f"phase kind must be one of {_PHASES}, got {kind!r}"
            )
        return _PhaseTimer(self, node_id, kind)

    def add(self, node_id: str, kind: str, ms: float) -> None:
        if kind not in _PHASES:
            raise ValueError(
                f"phase kind must be one of {_PHASES}, got {kind!r}"
            )
        rec = self.node(node_id)
        with self._lock:
            setattr(rec, f"{kind}_ms", getattr(rec, f"{kind}_ms") + ms)

    def count_item(self, node_id: str, n: int = 1) -> None:
        rec = self.node(node_id)
        with self._lock:
            rec.items += n

    # -- wire counters ------------------------------------------------------

    def add_wire(self, **counts: float) -> None:
        """Accumulate wire-level counters (bytes/frames/round-trips)."""
        with self._lock:
            for key, val in counts.items():
                self._wire[key] = self._wire.get(key, 0) + val

    @property
    def wire(self) -> dict[str, float]:
        with self._lock:
            return dict(self._wire)

    # -- reporting ---------------------------------------------------------

    @property
    def nodes(self) -> list[NodeTiming]:
        with self._lock:
            return sorted(self._nodes.values(), key=lambda r: r.node_id)

    def total_boot_ms(self) -> float:
        return sum(n.boot_ms for n in self.nodes)

    def total_load_ms(self) -> float:
        return sum(n.load_ms for n in self.nodes)

    def total_run_ms(self) -> float:
        return max((n.run_ms for n in self.nodes), default=0.0)

    def load_fraction(self) -> float:
        """Load time as a fraction of total wall time (paper reports <1%)."""
        run = self.total_run_ms()
        load = self.total_load_ms()
        denom = run + load
        return load / denom if denom > 0 else 0.0

    def report(self) -> str:
        lines = [
            f"{'node':<16}{'boot_ms':>12}{'load_ms':>12}{'run_ms':>14}"
            f"{'items':>8}"
        ]
        for rec in self.nodes:
            lines.append(
                f"{rec.node_id:<16}{rec.boot_ms:>12.3f}{rec.load_ms:>12.3f}"
                f"{rec.run_ms:>14.3f}{rec.items:>8d}"
            )
        lines.append(
            f"load fraction of total: {100.0 * self.load_fraction():.3f}%"
        )
        wire = self.wire
        if wire:
            lines.append(
                "wire: " + " ".join(f"{k}={wire[k]:.0f}" for k in sorted(wire))
            )
        return "\n".join(lines)

    def as_json(self) -> str:
        return json.dumps([n.as_dict() for n in self.nodes], indent=2)

    def summary(self) -> dict:
        """One JSON-able dict of everything: per-node phases, phase totals,
        load fraction, and wire counters.  This is what the telemetry
        endpoint exports as its ``timing`` section."""
        return {
            "nodes": {n.node_id: n.as_dict() for n in self.nodes},
            "total_boot_ms": round(self.total_boot_ms(), 3),
            "total_load_ms": round(self.total_load_ms(), 3),
            "total_run_ms": round(self.total_run_ms(), 3),
            "load_fraction": round(self.load_fraction(), 6),
            "wire": self.wire,
        }


class _PhaseTimer:
    def __init__(self, collector: TimingCollector, node_id: str, kind: str):
        self._collector = collector
        self._node_id = node_id
        self._kind = kind
        self._t0 = 0.0

    def __enter__(self) -> "_PhaseTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        dt_ms = (time.perf_counter() - self._t0) * 1e3
        self._collector.add(self._node_id, self._kind, dt_ms)
