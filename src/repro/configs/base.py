"""Model & shape configuration dataclasses.

Every assigned architecture is expressed as one :class:`ModelConfig`; the
four assigned input shapes are :class:`ShapeConfig` instances.  Reduced
("smoke") variants are derived mechanically so per-arch CPU tests exercise
the exact same code paths as the full dry-run configs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Sequence

import jax.numpy as jnp

from repro.core.channels import padded_size


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) column of the assignment table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long

    @property
    def tokens_per_step(self) -> int:
        if self.kind in ("train", "prefill"):
            return self.seq_len * self.global_batch
        return self.global_batch  # one new token per sequence


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "long")
ALL_SHAPES: tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # Layer pattern: one *period*, cycled over num_layers (remainder = prefix).
    #   "attn"   full causal attention block
    #   "local"  sliding-window attention block (window_size)
    #   "moe"    attention + mixture-of-experts FFN
    #   "rec"    RG-LRU recurrent block (Griffin)
    #   "mlstm"/"slstm"  xLSTM blocks
    layer_pattern: tuple[str, ...] = ("attn",)
    window_size: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # "onehot": GShard-literal [T*k, E] cumsum dispatch (baseline);
    # "sort": O(T*k) stable-argsort dispatch, identical assignment (perf).
    moe_dispatch: str = "onehot" 

    # Recurrent (Griffin RG-LRU)
    rnn_width: int = 0
    conv1d_width: int = 4

    # Encoder-decoder (audio family)
    encoder_layers: int = 0  # >0 => enc-dec; num_layers == decoder layers

    # Modality frontend stub: "vit" | "audio" | None.  Frontend embeddings
    # are *inputs* (precomputed), occupying the first frontend_len positions.
    frontend: str | None = None
    frontend_len: int = 0

    # Misc architectural knobs
    rope_theta: float = 10000.0
    use_qk_norm: bool = False
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # Compute policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_q_chunk: int = 512  # blockwise-attention q-chunk (memory bound)
    loss_seq_chunk: int = 512  # chunked cross-entropy block
    remat: bool = True
    scan_layers: bool = True  # scan over layer periods (False: unrolled probe)
    # Unroll inner lax.scans (attention chunks, CE chunks, mLSTM chunks) so
    # XLA cost_analysis counts every iteration — roofline probes only.
    unroll_scans: bool = False
    # Explicit sharding constraints on attention q/out activations (True) or
    # let GSPMD propagate head sharding from the weights alone (False).
    constrain_attn: bool = True
    # Remat policy: "nothing" (recompute all; lowest memory — the default:
    # "dots" saves every projection/FFN output and blows HBM at these batch
    # sizes) or "dots" (hillclimb option trading memory for collectives).
    remat_policy: str = "nothing"

    # Which shapes are supported (long_500k only for sub-quadratic archs).
    supports_long_context: bool = False
    has_decoder: bool = True

    def __post_init__(self):
        if self.num_heads % self.num_kv_heads != 0 and self.num_kv_heads > 0:
            raise ValueError(
                f"{self.name}: num_heads {self.num_heads} not a multiple of "
                f"num_kv_heads {self.num_kv_heads}"
            )

    # -- derived -------------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def padded_heads(self, tp: int) -> int:
        """Q heads padded to the TP degree (zero extra output columns)."""
        if tp <= 1 or self.num_heads % tp == 0:
            return self.num_heads
        return padded_size(self.num_heads, tp)

    def padded_kv_heads(self, tp: int) -> int:
        # KV heads are never padded: KV projections are cheap; when kv %% tp
        # != 0 the sharding rules fall back to sequence-sharding the cache.
        return self.num_kv_heads

    def padded_vocab(self, tp: int) -> int:
        return padded_size(self.vocab_size, max(tp, 1))

    @property
    def pattern_for_layers(self) -> tuple[str, ...]:
        """The full per-layer kind list (period cycled, prefix remainder)."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def layer_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for k in self.pattern_for_layers:
            counts[k] = counts.get(k, 0) + 1
        return counts

    def shapes(self) -> tuple[ShapeConfig, ...]:
        """The assigned shapes this arch runs (skips recorded in DESIGN.md)."""
        out = [TRAIN_4K, PREFILL_32K]
        if self.has_decoder:
            out.append(DECODE_32K)
            if self.supports_long_context:
                out.append(LONG_500K)
        return tuple(out)

    def skipped_shapes(self) -> tuple[tuple[str, str], ...]:
        skips = []
        if not self.has_decoder:
            skips.append(("decode_32k", "encoder-only architecture"))
            skips.append(("long_500k", "encoder-only architecture"))
        elif not self.supports_long_context:
            skips.append(
                (
                    "long_500k",
                    "pure full-attention arch: 512k dense KV decode skipped "
                    "per assignment; sub-quadratic archs run it",
                )
            )
        return tuple(skips)

    # -- reduced config for CPU smoke tests ----------------------------------

    def smoke(self) -> "ModelConfig":
        period = len(self.layer_pattern)
        n_layers = max(2, min(period + 1, 4)) if period > 1 else 2
        return replace(
            self,
            name=f"{self.name}-smoke",
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            d_ff=128 if self.d_ff else 0,
            head_dim=16,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            rnn_width=64 if self.rnn_width else 0,
            window_size=min(self.window_size, 32) if self.window_size else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_len=8 if self.frontend_len else 0,
            attn_q_chunk=16,
            loss_seq_chunk=16,
            # droppless MoE at smoke scale: decode batches are tiny, and the
            # exactness tests compare decode vs full forward.
            capacity_factor=float(max(self.num_experts, 4)),
        )


def bytes_of(dtype_name: str) -> int:
    return jnp.dtype(dtype_name).itemsize
