"""The HTTP status endpoint: stdlib ``http.server``, zero new deps.

A :class:`TelemetryServer` wraps one :class:`~.registry.Telemetry` and
serves, on a daemon thread:

* ``GET /``                    — the self-contained live dashboard (HTML);
* ``GET /metrics``             — full JSON snapshot;
* ``GET /metrics?format=prom`` — Prometheus text exposition;
* ``GET /jobs`` / ``GET /nodes`` — the snapshot's job/node sections;
* ``GET /events?since=N``      — ring events after cursor ``N`` (JSON,
  with ``next`` = the cursor to pass on the following poll);
* ``GET /events/stream``       — Server-Sent Events: pushes each new bus
  event (``event: bus``) as it lands plus periodic full snapshots
  (``event: snapshot``), so the dashboard renders on change instead of
  polling; ``?since=N`` resumes from a cursor;
* anything else                — 404; a malformed query (``since=x``) — 400.

Read-only by construction: every route is a snapshot read, no handler
mutates cluster state, so exposing it beside a live dispatcher is safe.
``ThreadingHTTPServer`` keeps a slow scraper from blocking the dashboard
poll; handlers touch only the thread-safe registry.
"""

from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.cluster.telemetry.dashboard import DASHBOARD_HTML
from repro.cluster.telemetry.registry import Telemetry

__all__ = ["TelemetryServer"]


class TelemetryServer:
    """Serve one registry over HTTP (see module docstring).

    ``port=0`` binds an ephemeral port (tests); the chosen one is in
    ``.port`` / ``.url`` after construction.  ``close()`` is idempotent
    and joins the serving thread.
    """

    def __init__(self, telemetry: Telemetry, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.telemetry = telemetry
        # Set on close(): open /events/stream loops watch it so shutdown
        # is not held hostage by long-lived SSE connections.
        self._stop = threading.Event()
        handler = _make_handler(telemetry, self._stop)
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="telemetry-http",
            kwargs={"poll_interval": 0.2}, daemon=True,
        )
        self._thread.start()
        self._closed = False

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()


def _make_handler(telemetry: Telemetry, stop: threading.Event) -> type:
    # SSE pacing: how often the stream loop wakes to check for new bus
    # events, and how long between unconditional full-snapshot frames
    # (gauges move without emitting events — pool sizes, queue depth).
    SSE_POLL_S = 0.25
    SSE_SNAPSHOT_EVERY_S = 3.0

    class Handler(BaseHTTPRequestHandler):
        # The endpoint must never spam the host process's stderr.
        def log_message(self, fmt: str, *args) -> None:  # pragma: no cover
            pass

        def _reply(self, status: int, body: bytes,
                   content_type: str) -> None:
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(body)))
            self.send_header("Cache-Control", "no-store")
            self.end_headers()
            self.wfile.write(body)

        def _json(self, obj, status: int = 200) -> None:
            body = json.dumps(obj, default=str, indent=1).encode("utf-8")
            self._reply(status, body, "application/json; charset=utf-8")

        def _sse_frame(self, event: str, obj) -> None:
            body = json.dumps(obj, default=str, separators=(",", ":"))
            self.wfile.write(
                f"event: {event}\ndata: {body}\n\n".encode("utf-8"))
            self.wfile.flush()

        def _stream(self, since: int) -> None:
            """Server-Sent Events loop: one ``snapshot`` frame up front,
            then ``bus`` frames as ring events land, with a fresh
            ``snapshot`` on activity or at least every few seconds (gauges
            move without emitting events).  Runs on this connection's
            thread until the client disconnects or the server closes.
            """
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-store")
            self.send_header("Connection", "close")
            self.end_headers()
            cursor = since
            self._sse_frame("snapshot", telemetry.snapshot())
            last_snap = time.monotonic()
            while not stop.is_set():
                events = telemetry.events_since(cursor)
                for ev in events:
                    self._sse_frame("bus", ev)
                    cursor = ev["seq"]
                now = time.monotonic()
                if events or now - last_snap >= SSE_SNAPSHOT_EVERY_S:
                    self._sse_frame("snapshot", telemetry.snapshot())
                    last_snap = now
                stop.wait(SSE_POLL_S)

        def do_GET(self) -> None:  # noqa: N802 (stdlib naming)
            try:
                split = urlsplit(self.path)
                path = split.path.rstrip("/") or "/"
                query = parse_qs(split.query)
                if path == "/":
                    self._reply(200, DASHBOARD_HTML.encode("utf-8"),
                                "text/html; charset=utf-8")
                elif path == "/metrics":
                    fmt = (query.get("format") or ["json"])[0]
                    if fmt == "prom":
                        self._reply(
                            200, telemetry.prometheus().encode("utf-8"),
                            "text/plain; version=0.0.4; charset=utf-8",
                        )
                    elif fmt == "json":
                        self._json(telemetry.snapshot())
                    else:
                        self._json(
                            {"error": f"unknown format {fmt!r} "
                                      "(expected json or prom)"},
                            status=400,
                        )
                elif path == "/jobs":
                    self._json({"jobs": telemetry.snapshot()["jobs"]})
                elif path == "/nodes":
                    self._json({"nodes": telemetry.snapshot()["nodes"]})
                elif path == "/events/stream":
                    try:
                        since = int((query.get("since") or ["0"])[0])
                    except ValueError:
                        self._json({"error": "since must be an integer"},
                                   status=400)
                        return
                    self._stream(since)
                elif path == "/events":
                    try:
                        since = int((query.get("since") or ["0"])[0])
                        limit = int((query.get("limit") or ["500"])[0])
                    except ValueError:
                        self._json(
                            {"error": "since/limit must be integers"},
                            status=400,
                        )
                        return
                    events = telemetry.events_since(since, limit)
                    next_cursor = events[-1]["seq"] if events else since
                    self._json({"events": events, "next": next_cursor})
                else:
                    self._json({"error": f"no such route {path!r}"},
                               status=404)
            except (BrokenPipeError, ConnectionResetError):
                pass  # scraper went away mid-reply; nothing to clean up

    return Handler
