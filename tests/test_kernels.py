"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.mandelbrot.ops import mandelbrot
from repro.kernels.mandelbrot.ref import grid_coords, mandelbrot_reference
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_reference
from repro.kernels.rmsnorm.ops import rms_norm
from repro.kernels.rmsnorm.ref import rms_norm_reference


def keys(n):
    return [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(n)]


# -- mandelbrot ---------------------------------------------------------------


@pytest.mark.parametrize("h,w,iters", [(16, 128, 50), (32, 300, 100), (9, 77, 30)])
def test_mandelbrot_matches_reference(h, w, iters):
    x0, y0 = grid_coords(h, w)
    it_k, col_k = mandelbrot(x0, y0, max_iters=iters)
    it_r, col_r = mandelbrot_reference(x0, y0, iters)
    np.testing.assert_array_equal(np.asarray(it_k), np.asarray(it_r))
    np.testing.assert_array_equal(np.asarray(col_k), np.asarray(col_r))


def test_mandelbrot_paper_counts():
    """Paper section 8: on the full 3200x5600 grid ~14M of 17.92M points are
    white.  On a 1/8-scale grid the white fraction must be comparable."""
    x0, y0 = grid_coords(400, 700)
    _iters, col = mandelbrot(x0, y0, max_iters=200)
    white_frac = float(jnp.mean(col.astype(jnp.float32)))
    assert 0.70 < white_frac < 0.90  # paper: 14.06/17.92 = 0.785


# -- flash attention -----------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,d,causal,window",
    [
        (2, 4, 4, 256, 64, True, 0),
        (1, 8, 2, 256, 32, True, 64),
        (2, 2, 2, 128, 128, False, 0),
        (1, 4, 1, 384, 64, True, 128),
        (1, 4, 4, 200, 64, True, 0),  # ragged
    ],
)
def test_flash_attention_sweep(b, h, kv, s, d, causal, window, dtype):
    ks = keys(3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    kr = jnp.repeat(k, h // kv, axis=1)
    vr = jnp.repeat(v, h // kv, axis=1)
    ref = attention_reference(q, kr, vr, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_matches_model_attention():
    """The Pallas kernel and the model's XLA blockwise path agree."""
    from repro.models.attention import attention_blockwise

    ks = keys(3)
    b, h, s, d = 1, 4, 256, 32
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    out_kernel = flash_attention(q, k, v, causal=True)
    out_xla = attention_blockwise(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, q_chunk=64,
    )
    np.testing.assert_allclose(
        np.asarray(out_kernel), np.asarray(jnp.moveaxis(out_xla, 2, 1)),
        atol=3e-6,
    )


# -- rglru ----------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,w", [(2, 64, 128), (1, 128, 200), (3, 32, 64)])
def test_rglru_sweep(b, s, w, dtype):
    ks = keys(3)
    a = jax.random.uniform(ks[0], (b, s, w), dtype, 0.5, 0.999)
    bb = jax.random.normal(ks[1], (b, s, w), dtype)
    h0 = jax.random.normal(ks[2], (b, w), dtype)
    h, hl = rglru_scan(a, bb, h0)
    hr, hlr = rglru_scan_reference(a, bb, h0)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hl, np.float32),
                               np.asarray(hlr, np.float32), atol=tol)


def test_rglru_state_chaining():
    """Scanning two halves with carried state == scanning the whole."""
    ks = keys(2)
    a = jax.random.uniform(ks[0], (1, 64, 128), minval=0.5, maxval=0.99)
    b = jax.random.normal(ks[1], (1, 64, 128))
    h_full, hl_full = rglru_scan(a, b)
    h1, hl1 = rglru_scan(a[:, :32], b[:, :32])
    h2, hl2 = rglru_scan(a[:, 32:], b[:, 32:], hl1)
    np.testing.assert_allclose(np.asarray(hl2), np.asarray(hl_full), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=1)),
        np.asarray(h_full), atol=1e-5,
    )


# -- rmsnorm ---------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,d", [((8, 512), 512), ((3, 100, 256), 256),
                                     ((1000,), 1000)])
def test_rmsnorm_sweep(shape, d, dtype):
    ks = keys(2)
    x = jax.random.normal(ks[0], shape[:-1] + (d,) if len(shape) > 1 else (1, d),
                          dtype)
    if len(shape) == 1:
        x = jax.random.normal(ks[0], (4, d), dtype)
    s = jax.random.normal(ks[1], (d,)) * 0.2
    out = rms_norm(x, s)
    ref = rms_norm_reference(x.reshape(-1, d), s).reshape(x.shape)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
