"""The Launcher contract: *how* node-loaders come into existence.

The paper's deployment story (§4) deliberately makes the node side trivial —
every workstation runs the *identical* executable knowing only the host's
load address ("ip:2000/1").  Everything that varies between deployments is
therefore concentrated in one question: *who starts that executable, where?*
This module answers it with a small pluggable surface:

* :class:`Launcher` — ``launch(node_id) -> NodeHandle`` plus a one-time
  :meth:`Launcher.prepare` (told the host's connect address once the load
  port is bound) and :meth:`Launcher.close`.
* :class:`NodeHandle` — ``poll``/``wait``/``kill``/``logs`` over one launched
  node-loader, however it is incarnated (subprocess, ssh session, thread).
* :class:`PlacementPolicy` — what the host does when launches misbehave:
  respawn a node that never registers (``max_respawns``), admit the job with
  survivors (``min_nodes``), and let stragglers join after the run started
  (``allow_late_join``).

Concrete launchers: :class:`~repro.cluster.deploy.local.LocalLauncher`
(subprocesses on this machine), :class:`~repro.cluster.deploy.ssh.SSHLauncher`
(the same command fanned out over ssh), and
:class:`~repro.cluster.deploy.inprocess.InProcessLauncher` (threads, for fast
launcher-logic tests).  No module here may import jax — launchers run on the
bare bootstrap side of the code-shipping boundary.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Sequence


class NodeHandle(abc.ABC):
    """One launched node-loader, however it runs (process, ssh, thread)."""

    node_id: str
    where: str  # human-readable placement, e.g. "local", "ssh:ws07", "thread"

    @abc.abstractmethod
    def poll(self) -> int | None:
        """Exit code, or None while the node-loader is still running."""

    @abc.abstractmethod
    def wait(self, timeout: float | None = None) -> int | None:
        """Block up to ``timeout`` for exit; returns the code or None."""

    @abc.abstractmethod
    def kill(self) -> None:
        """Hard-stop the node-loader (a real node loss, not a clean UT)."""

    @abc.abstractmethod
    def logs(self) -> list[str]:
        """Most recent stdout+stderr lines, for diagnostics."""

    @property
    def returncode(self) -> int | None:
        """Popen-compatible accessor (tests and callers poll this)."""
        return self.poll()


class Launcher(abc.ABC):
    """Starts node-loaders somewhere; the host neither knows nor cares where.

    Lifecycle: ``prepare(connect_host, port)`` once (after the host bound its
    load port — launchers that ship code do it here), then ``launch`` per
    node (and per respawn), then ``close`` at teardown.
    """

    def prepare(self, connect_host: str, port: int) -> None:
        """Told the load-network address nodes must dial; sync code if the
        target machines don't already share this filesystem.

        A host bound to the wildcard address is unroutable as a dial
        target; launchers whose nodes live on this machine substitute
        loopback (launchers that span machines must be configured with a
        reachable ``connect_host`` and keep it).
        """
        self.connect_host = (
            "127.0.0.1" if connect_host in ("0.0.0.0", "") else connect_host
        )
        self.port = port

    @abc.abstractmethod
    def launch(self, node_id: str, *,
               avoid: Sequence[str] = ()) -> NodeHandle:
        """Start one node-loader.  ``avoid`` names placements (``where``
        values) a respawn should steer clear of — the machine that already
        swallowed one launch silently."""

    def close(self) -> None:  # pragma: no cover - trivial default
        """Release launcher-held resources (nothing by default)."""


@dataclass
class PlacementPolicy:
    """What the host's registration barrier does about imperfect clusters.

    The paper assumes every workstation it was pointed at shows up; real
    idle-workstation pools (the arXiv:0708.0605 model) don't.  Three relaxes:

    * ``max_respawns`` — a node silent for ``respawn_after`` seconds is
      relaunched elsewhere (its first launch marked *replaced*), up to this
      many times cluster-wide.
    * ``min_nodes`` — at ``register_timeout`` the job is admitted with the
      survivors if at least this many registered (*degraded start*) instead
      of raising.  ``None`` means all expected nodes (the strict barrier).
    * ``allow_late_join`` — a node registering after the run started is
      given LOAD and answered credits immediately (the per-registration
      LOAD path always supported this; the barrier was what blocked it).
    * ``max_heals`` — a node that dies *during* a run is relaunched through
      the same ``_relaunch`` path (mid-run pool healing: dead → launching →
      registered, warm code re-shipped, credits re-armed), up to this many
      times cluster-wide.  0 keeps the historical behaviour of shrinking
      to survivors.

    ``respawn_after=None`` spreads the respawn budget evenly across the
    registration window (``register_timeout / (max_respawns + 1)``).
    """

    min_nodes: int | None = None
    max_respawns: int = 0
    respawn_after: float | None = None
    allow_late_join: bool = True
    max_heals: int = 0

    def validate(self, nclusters: int) -> None:
        if self.min_nodes is not None and not (
                1 <= self.min_nodes <= nclusters):
            raise ValueError(
                f"min_nodes must be in [1, {nclusters}], got {self.min_nodes}"
            )
        if self.max_respawns < 0:
            raise ValueError(f"max_respawns must be >= 0, got {self.max_respawns}")
        if self.max_heals < 0:
            raise ValueError(f"max_heals must be >= 0, got {self.max_heals}")
