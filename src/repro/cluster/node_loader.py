"""The Node-Loader (NL): the identical executable every worker machine runs.

Paper §4: the user starts *one* NodeLoader per node — it knows only the
host's load address ("ip:2000/1"); everything else (code, topology, worker
count) arrives over the load network.  Mirroring that:

    python -m repro.cluster.node_loader --host 127.0.0.1 --port <p>

Lifecycle (timed per requirement 7 — load vs run accounted separately):

1. connect + REGISTER (node id, cores, pid) on the load channel;
2. receive LOAD: the deployment payload (work function shipped by value —
   the code-loading channel; optional AOT-serialized executables land in
   :data:`ARTIFACTS` for work functions that want them);
3. start the heartbeat beacon and the node-local Figure-2 fragment:
   the nrfa client (one-place buffer: request only after the previous object
   was handed to an idle worker) + ``workers`` worker threads + result
   delivery (the afoc merge is the shared, locked socket);
4. on UT: flood workers with UT, join them, return (load_ms, run_ms, items)
   to the host in a final UT frame, exit 0.

This module must import without jax — a node-loader on a fresh workstation
is a bare bootstrap; the shipped code pulls in its own dependencies when
deserialized.
"""

from __future__ import annotations

import argparse
import os
import queue
import socket
import threading
import time
import traceback
from typing import Any

from repro.cluster.netchannels import ChannelClosed, ChannelMux
from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    LOAD_WIRE_CHANNEL,
    UT,
    Frame,
    FrameConnection,
    FrameType,
)

# AOT-serialized executables shipped in the LOAD payload, keyed by name.
# Work functions may read these (e.g. deserialize_and_load a compiled step).
ARTIFACTS: dict[str, bytes] = {}


def run_node(
    host: str,
    port: int,
    *,
    node_id: str | None = None,
    connect_timeout: float = 30.0,
) -> dict[str, Any]:
    """Run one Node-Loader to completion; returns its timing record."""
    node_id = node_id or f"{socket.gethostname()}-{os.getpid()}"
    t_load0 = time.perf_counter()

    sock = socket.create_connection((host, port), timeout=connect_timeout)
    sock.settimeout(None)
    conn = FrameConnection(sock)
    mux = ChannelMux(conn)
    load_ch = mux.open(LOAD_WIRE_CHANNEL, FrameType.LOAD, maxsize=4)
    app_ch = mux.open(APP_WIRE_CHANNEL, FrameType.WORK, maxsize=1)
    mux.start()  # input ends exist before we announce ourselves (§4 ordering)

    conn.send(Frame(
        FrameType.REGISTER,
        {"node_id": node_id, "cores": os.cpu_count() or 1, "pid": os.getpid()},
        LOAD_WIRE_CHANNEL,
    ))

    # The beacon starts *before* the LOAD payload is deserialized: shipped
    # code may drag in heavy imports (jax), and the host must not mistake
    # that load phase for death.  The interval is refined once the plan says
    # what the host expects.
    stop_beat = threading.Event()
    beat_interval = [0.1]

    def heartbeat() -> None:
        while not stop_beat.wait(beat_interval[0]):
            try:
                conn.send(Frame(
                    FrameType.HEARTBEAT, {"node_id": node_id},
                    LOAD_WIRE_CHANNEL,
                ))
            except OSError:
                return

    beat_thread = threading.Thread(target=heartbeat, name="nl-heartbeat",
                                   daemon=True)
    beat_thread.start()

    try:
        plan = load_ch.get(timeout=connect_timeout)
    except queue.Empty:
        stop_beat.set()
        conn.close()
        raise ConnectionError(
            f"no LOAD received from the host within {connect_timeout}s "
            "(are all expected node-loaders up?)"
        ) from None
    if plan is UT:  # host aborted during bootstrap
        stop_beat.set()
        conn.close()
        return {"node_id": node_id, "load_ms": 0.0, "run_ms": 0.0, "items": 0}
    fn = plan["function"]
    workers = int(plan["workers"])
    slowdown = float(plan.get("slowdown", 0.0))
    beat_interval[0] = float(plan.get("heartbeat_interval", 0.2))
    ARTIFACTS.clear()
    ARTIFACTS.update(plan.get("artifacts") or {})
    load_ms = (time.perf_counter() - t_load0) * 1e3

    # -- the node-local Figure-2 fragment -----------------------------------
    work_q: queue.Queue = queue.Queue(maxsize=1)  # the nrfa one-place buffer
    items_done = 0
    items_lock = threading.Lock()

    def worker() -> None:
        nonlocal items_done
        while True:
            item = work_q.get()
            if item is UT:
                return
            try:
                value = fn(item["obj"])
                if slowdown > 0.0:
                    time.sleep(slowdown)  # injected straggler (§6.1 testing)
                # Inside the try: an unserialisable result must be reported
                # too, not silently kill the thread.
                conn.send(Frame(
                    FrameType.RESULT,
                    {"id": item["id"], "value": value, "node_id": node_id},
                    APP_WIRE_CHANNEL,
                ))
            except BaseException as exc:
                # Report instead of dying silently: a dead worker thread
                # would stall the node (heartbeats keep flowing, so the
                # host would never re-dispatch).  The host fails the job.
                try:
                    conn.send(Frame(
                        FrameType.RESULT,
                        {"id": item["id"], "node_id": node_id,
                         "error": f"{type(exc).__name__}: {exc}",
                         "traceback": traceback.format_exc()},
                        APP_WIRE_CHANNEL,
                    ))
                except OSError:
                    pass  # socket gone: the nrfa loop shuts the node down
                continue
            with items_lock:
                items_done += 1

    worker_threads = [
        threading.Thread(target=worker, name=f"nl-worker{i}", daemon=True)
        for i in range(workers)
    ]
    for t in worker_threads:
        t.start()

    t_run0 = time.perf_counter()
    try:
        while True:  # the nrfa client loop (b!i.S ; c?i.o ; d!i.o)
            conn.send(Frame(FrameType.WORK_REQUEST, {"node_id": node_id},
                            APP_WIRE_CHANNEL))
            obj = app_ch.get()
            if obj is UT:
                for _ in range(workers):
                    work_q.put(UT)
                break
            work_q.put(obj)  # blocks until a worker idles — then re-request
    except (ChannelClosed, OSError):
        # Host vanished (mid-recv or mid-request-send): there is nobody to
        # deliver to; shut down quietly.
        for _ in range(workers):
            work_q.put(UT)
    for t in worker_threads:
        t.join()
    run_ms = (time.perf_counter() - t_run0) * 1e3
    stop_beat.set()

    record = {
        "node_id": node_id,
        "load_ms": round(load_ms, 3),
        "run_ms": round(run_ms, 3),
        "items": items_done,
    }
    try:
        conn.send(Frame(FrameType.UT, record, LOAD_WIRE_CHANNEL))
    except OSError:
        pass
    conn.close()
    return record


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        description="ClusterBuilder Node-Loader (paper §4)"
    )
    parser.add_argument("--host", required=True,
                        help="Host-Node-Loader address")
    parser.add_argument("--port", type=int, required=True,
                        help="load network port (the paper's 2000)")
    parser.add_argument("--node-id", default=None)
    parser.add_argument("--connect-timeout", type=float, default=30.0)
    args = parser.parse_args(argv)
    try:
        record = run_node(
            args.host, args.port,
            node_id=args.node_id,
            connect_timeout=args.connect_timeout,
        )
    except (ConnectionError, socket.timeout, OSError) as exc:
        print(
            f"node-loader: cannot reach host-node-loader at "
            f"{args.host}:{args.port}: {exc}",
            flush=True,
        )
        return 1
    print(f"node-loader done: {record}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
