"""LocalLauncher: node-loaders as subprocesses of this machine.

The paper's §6.1 workflow — "operation and testing of a system can be
conducted on a single host node before using multiple nodes" — with true
process isolation: each Node-Loader is a fresh ``python -m
repro.cluster.node_loader`` OS process talking TCP on localhost, so there is
no GIL coupling and killing one is a *real* node death, not an injected one.

The launcher exports the host's ``sys.path`` to the children so code shipped
by reference (plain-pickle fallback, user modules) resolves; code shipped by
value (cloudpickle closures) needs only the libraries it imports.
"""

from __future__ import annotations

import collections
import os
import subprocess
import sys
import threading
from typing import Sequence

from repro.cluster.deploy.base import Launcher, NodeHandle


def jax_node_env(compile_cache_dir: str | None = None) -> dict[str, str]:
    """The env overlay every node-loader needs, whatever launches it.

    Node-loaders are bootstrap processes: keep their (transitive) jax happy
    on CPU-only machines.  With ``compile_cache_dir``, a cluster-wide XLA
    compilation cache: the host's warm-up compile lands on disk and every
    node-loader loads the binary instead of recompiling — the paper's
    single-source code-shipping idea applied to executables.  One recipe
    shared by every launcher (local subprocess env, ssh ``env`` exports),
    so a knob added here reaches remote nodes too.
    """
    env = {"JAX_PLATFORMS": "cpu"}
    if compile_cache_dir:
        env["JAX_COMPILATION_CACHE_DIR"] = compile_cache_dir
        env["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] = "0"
    return env


def _child_env(compile_cache_dir: str | None = None) -> dict[str, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
    for key, val in jax_node_env(compile_cache_dir).items():
        if key == "JAX_COMPILATION_CACHE_DIR":
            env[key] = val  # the shared cache is authoritative
        else:
            env.setdefault(key, val)  # respect the caller's environment
    return env


def node_loader_argv(host: str, port: int, node_id: str,
                     *, python: str = sys.executable,
                     preload: Sequence[str] = (),
                     connect_timeout: float | None = None) -> list[str]:
    """The §4 'identical executable' invocation every launcher fans out."""
    cmd = [python, "-m", "repro.cluster.node_loader",
           "--host", host, "--port", str(port), "--node-id", node_id]
    if preload:
        cmd += ["--preload", ",".join(preload)]
    if connect_timeout is not None:
        cmd += ["--connect-timeout", str(connect_timeout)]
    return cmd


def spawn_node_loader(host: str, port: int, node_id: str,
                      *, python: str = sys.executable,
                      preload: tuple[str, ...] = (),
                      compile_cache_dir: str | None = None
                      ) -> subprocess.Popen:
    """Start one Node-Loader subprocess (kept for direct callers).

    ``preload`` names modules the child imports concurrently with its
    registration (e.g. ``("jax.numpy",)``), so heavy environment boot
    overlaps the load-network handshake instead of serializing after it.
    """
    return subprocess.Popen(
        node_loader_argv(host, port, node_id, python=python, preload=preload),
        env=_child_env(compile_cache_dir),
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )


class PopenNodeHandle(NodeHandle):
    """A node-loader behind a local ``subprocess.Popen`` (direct child or an
    ssh client process).  Stdout+stderr are drained continuously so a chatty
    child never blocks on a full pipe; the tail is kept for diagnostics."""

    def __init__(self, node_id: str, proc: subprocess.Popen,
                 where: str = "local", log_lines: int = 200):
        self.node_id = node_id
        self.where = where
        self.proc = proc
        self._log: collections.deque[str] = collections.deque(maxlen=log_lines)
        self._drainers: list[threading.Thread] = []
        for stream in (proc.stdout, proc.stderr):
            if stream is None:
                continue
            t = threading.Thread(target=self._drain, args=(stream,),
                                 name=f"drain-{node_id}", daemon=True)
            t.start()
            self._drainers.append(t)

    def _drain(self, stream) -> None:
        for line in stream:
            self._log.append(line.rstrip("\n"))
        stream.close()

    def poll(self) -> int | None:
        return self.proc.poll()

    def wait(self, timeout: float | None = None) -> int | None:
        try:
            return self.proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            return None

    def kill(self) -> None:
        self.proc.kill()

    def logs(self) -> list[str]:
        return list(self._log)

    def join_drainers(self, timeout: float = 5.0) -> None:
        for t in self._drainers:  # EOF arrives once the child exits
            t.join(timeout=timeout)

    @property
    def returncode(self) -> int | None:
        return self.proc.returncode


class LocalLauncher(Launcher):
    """Forks node-loader subprocesses on this machine (the seed behaviour,
    extracted out of ``ProcessClusterApplication``)."""

    def __init__(self, *, python: str = sys.executable,
                 preload: Sequence[str] = (),
                 compile_cache_dir: str | None = None):
        self.python = python
        self.preload = tuple(preload)
        self.compile_cache_dir = compile_cache_dir
        self.connect_host = "127.0.0.1"
        self.port = 0

    def launch(self, node_id: str, *,
               avoid: Sequence[str] = ()) -> PopenNodeHandle:
        proc = spawn_node_loader(
            self.connect_host, self.port, node_id,
            python=self.python, preload=self.preload,
            compile_cache_dir=self.compile_cache_dir,
        )
        return PopenNodeHandle(node_id, proc, where="local")
