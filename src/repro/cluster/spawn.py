"""ProcessClusterApplication: cluster lifecycle + deployment policy.

The runnable returned by ``build_application(spec, backend="cluster")``.
*How* node-loaders come into existence is delegated to a pluggable
:class:`~repro.cluster.deploy.base.Launcher` (``repro.cluster.deploy``):
subprocesses on this machine (:class:`LocalLauncher`, the default — the
paper's §6.1 "test on one host first" mode with true process isolation),
ssh fan-out to idle workstations (:class:`SSHLauncher`, via ``launcher=``
or the ``hosts=`` shorthand), or threads for fast launcher-logic tests
(:class:`InProcessLauncher`).  This module no longer knows what a
``subprocess.Popen`` is.

What remains here is lifecycle and policy: bootstrap the HostLoader, fan
the launches out, relaunch silent nodes when the host's placement policy
asks (``min_nodes`` / ``max_respawns`` / late join — see
:class:`~repro.cluster.deploy.base.PlacementPolicy`), and guarantee that
*no path out of run()/start() leaks a child* — teardown runs even when
bootstrap itself raises midway through the fan-out.

``spawn_node_loader`` is re-exported for direct callers; it lives in
``repro.cluster.deploy.local`` now.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.cluster.deploy.base import Launcher, NodeHandle, PlacementPolicy
from repro.cluster.deploy.local import (  # noqa: F401  (compat re-exports)
    LocalLauncher,
    _child_env,
    spawn_node_loader,
)
from repro.cluster.host_loader import HostLoader
from repro.cluster.telemetry import Telemetry, TelemetryServer
from repro.core.timing import TimingCollector
from repro.runtime.failures import HeartbeatMonitor


@dataclass
class ProcessClusterApplication:
    """Runnable returned by ``build_application(spec, backend="cluster")``.

    Same contract as ``runtime.local.LocalClusterApplication`` — ``run()``
    blocks to completion and returns the finalised result — but the workers
    are real node-loaders started by a :class:`Launcher`.  ``slowdown``
    maps node ids to an artificial seconds-per-item delay (straggler
    injection for §6.1-style testing); ``kill_node`` turns a live node into
    a real mid-job node death.
    """

    spec: Any
    plan: Any
    timing: TimingCollector
    port: int = 0  # 0 = ephemeral; the paper's deployment would fix 2000
    # Defaults tolerate multi-second GC/compile stalls in work functions;
    # tests override with much tighter settings.
    heartbeat_interval: float = 0.5
    heartbeat_misses: int = 10
    job_timeout: float = 300.0
    register_timeout: float = 30.0
    shutdown_grace: float = 10.0
    slowdown: dict[str, float] = field(default_factory=dict)
    artifacts: dict[str, bytes] = field(default_factory=dict)
    # Data-plane knobs (see ARCHITECTURE.md "Data plane"): modules each
    # node pre-imports during boot; extra items beyond `workers` the node
    # keeps buffered (None = one per worker); and the node-side result
    # coalescing threshold/interval.
    preload: tuple[str, ...] = ()
    prefetch: int | None = None
    flush_items: int = 8
    flush_interval: float = 0.005
    # Directory for a shared XLA compilation cache (host warms it, nodes
    # load instead of recompiling).  None = no persistent cache.
    compile_cache_dir: str | None = None
    # -- deployment layer ---------------------------------------------------
    # Which machines run node-loaders and what happens when one never shows
    # up.  ``launcher=None`` defaults to LocalLauncher (subprocesses here);
    # ``hosts=["ws01", ...]`` is shorthand for an SSHLauncher over those
    # machines.  ``bind_host`` is the load-network bind address — keep the
    # loopback default for local runs, use "0.0.0.0" (plus an
    # SSHLauncher(connect_host=<reachable ip>)) to span machines.
    launcher: Launcher | None = None
    hosts: Sequence[str] | None = None
    bind_host: str = "127.0.0.1"
    min_nodes: int | None = None
    max_respawns: int = 0
    respawn_after: float | None = None
    allow_late_join: bool = True
    # Mid-run healing budget: relaunch nodes that die *during* the run
    # (0 = shrink to survivors) — see PlacementPolicy.max_heals.
    max_heals: int = 0
    # Optional fault injection: a repro.cluster.chaos.FaultPlan armed when
    # the launches fan out (one-shot runs test the full bootstrap+run
    # window, unlike the service which arms after pool-ready).
    chaos: Any = None
    chaos_controller: Any = None
    # -- observability ------------------------------------------------------
    # ``http_port``: None = no status endpoint, 0 = ephemeral (read
    # ``http_url`` after start).  ``trace_path`` appends the run's lifecycle
    # events as JSONL for offline replay.
    telemetry: Telemetry | None = None
    trace_path: str | None = None
    http_host: str = "127.0.0.1"
    http_port: int | None = None
    http_server: TelemetryServer | None = None

    host_loader: HostLoader | None = None
    handles: dict[str, NodeHandle] = field(default_factory=dict)
    result: Any = None
    error: BaseException | None = None  # set by run_async on failure
    _ran: bool = False

    def __post_init__(self) -> None:
        if hasattr(self.spec, "as_pipeline"):
            self.spec = self.spec.as_pipeline()

    # -- compat views (the seed exposed Popen internals) --------------------

    @property
    def processes(self) -> dict[str, NodeHandle]:
        """Per-node handles (named for the era when they were Popens)."""
        return self.handles

    @property
    def node_logs(self) -> dict[str, list[str]]:
        """Last lines of each node-loader's stdout+stderr (diagnostics)."""
        return {nid: h.logs() for nid, h in self.handles.items()}

    def node_ids(self) -> list[str]:
        """Flat node ids, stage order (stage assignment lives in the spec)."""
        return [nid for nid, _ in self.spec.node_assignments()]

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Bootstrap the load network and fan out the node-loaders.

        Any failure mid-fan-out (port bind, a launcher raising on the k-th
        node) tears down whatever was already started — bootstrap must
        never leak children.
        """
        try:
            self._start_inner()
        except BaseException:
            self._shutdown()
            raise

    def _start_inner(self) -> None:
        if self.launcher is not None and self.hosts is not None:
            raise TypeError("pass either launcher= or hosts=, not both")
        if self.launcher is None:
            if self.hosts is not None:
                from repro.cluster.deploy.ssh import SSHLauncher

                self.launcher = SSHLauncher(
                    self.hosts,
                    preload=tuple(self.preload),
                    compile_cache_dir=self.compile_cache_dir,
                )
            else:
                self.launcher = LocalLauncher(
                    preload=tuple(self.preload),
                    compile_cache_dir=self.compile_cache_dir,
                )
        node_ids = self.node_ids()
        if self.telemetry is None:
            self.telemetry = Telemetry(trace_path=self.trace_path)
        conn_wrapper = None
        if self.chaos is not None and self.chaos_controller is None:
            from repro.cluster.chaos import ChaosController

            self.chaos_controller = ChaosController(
                self.chaos,
                kill=self.kill_node,
                telemetry=self.telemetry,
                items_fn=lambda: (self.host_loader.stats.items_total
                                  if self.host_loader is not None else 0),
            )
            self.telemetry.set_sampler("chaos", self.chaos_controller.sample)
        if self.chaos_controller is not None:
            conn_wrapper = self.chaos_controller.wrap_connection
        self.host_loader = HostLoader(
            self.spec,
            self.timing,
            host=self.bind_host,
            port=self.port,
            heartbeat=HeartbeatMonitor(
                interval_s=self.heartbeat_interval,
                misses=self.heartbeat_misses,
            ),
            register_timeout=self.register_timeout,
            job_timeout=self.job_timeout,
            slowdown=self.slowdown,
            artifacts=self.artifacts,
            prefetch=self.prefetch,
            flush_items=self.flush_items,
            flush_interval=self.flush_interval,
            placement=PlacementPolicy(
                min_nodes=self.min_nodes,
                max_respawns=self.max_respawns,
                respawn_after=self.respawn_after,
                allow_late_join=self.allow_late_join,
                max_heals=self.max_heals,
            ),
            expected_nodes=node_ids,
            relaunch=self._relaunch,
            telemetry=self.telemetry,
            conn_wrapper=conn_wrapper,
        )
        if self.http_port is not None and self.http_server is None:
            self.http_server = TelemetryServer(
                self.telemetry, host=self.http_host, port=self.http_port,
            )
        self.host_loader.start()
        # The bind address goes through verbatim: each launcher knows how to
        # resolve an unroutable "0.0.0.0" (loopback for local launchers; an
        # SSHLauncher keeps its explicitly configured connect_host).
        self.launcher.prepare(self.bind_host, self.host_loader.port)
        for node_id in node_ids:
            self.handles[node_id] = self.launcher.launch(node_id)
        if self.chaos_controller is not None:
            self.chaos_controller.arm()

    def _relaunch(self, old_node_id: str, new_node_id: str) -> bool:
        """Placement-policy callback: a launch never registered — retire it
        and start a replacement, steering clear of the machine that already
        swallowed one launch."""
        old = self.handles.get(old_node_id)
        avoid = (old.where,) if old is not None else ()
        try:
            self.handles[new_node_id] = self.launcher.launch(
                new_node_id, avoid=avoid
            )
        except Exception:
            return False
        if old is not None:
            try:
                old.kill()  # best effort; it never joined the network
            except Exception:
                pass
        return True

    def run(self) -> Any:
        if self._ran:
            raise RuntimeError("application already ran; build a fresh one")
        self._ran = True
        try:
            if self.host_loader is None:
                self.start()
            self.result = self.host_loader.run()
        finally:
            self._shutdown()
        return self.result

    def run_async(self) -> threading.Thread:
        """Start and run in a background thread (lets callers kill nodes
        mid-job); join the returned thread, then read ``result``/``error``."""

        def target() -> None:
            try:
                self.run()
            except BaseException as exc:  # surfaced via .error, not stderr
                self.error = exc

        t = threading.Thread(target=target, name="cluster-app", daemon=True)
        t.start()
        return t

    def kill_node(self, node_id: str) -> None:
        """Hard-kill a node-loader: a real workstation loss, detected only
        by its heartbeats going silent."""
        self.handles[node_id].kill()

    # -- teardown -----------------------------------------------------------

    def _shutdown(self) -> None:
        # Chaos first: no new faults may fire into a cluster being torn down.
        if self.chaos_controller is not None:
            self.chaos_controller.disarm()
        # Close the host's sockets first: surviving node-loaders blocked on
        # the application channel see ChannelClosed and exit promptly
        # (milliseconds, exit 0) instead of burning the grace period.
        if self.host_loader is not None:
            self.host_loader.close()
        deadline = time.monotonic() + self.shutdown_grace
        for handle in self.handles.values():
            remaining = max(0.0, deadline - time.monotonic())
            if handle.wait(timeout=remaining) is None:
                handle.kill()
                handle.wait(timeout=self.shutdown_grace)
        for handle in self.handles.values():
            join = getattr(handle, "join_drainers", None)
            if join is not None:  # EOF arrives once the child exits
                join()
        if self.launcher is not None:
            self.launcher.close()
        if self.http_server is not None:
            self.http_server.close()
        if self.telemetry is not None:
            self.telemetry.close()

    @property
    def http_url(self) -> str | None:
        """Base URL of the status endpoint (None when not serving)."""
        return None if self.http_server is None else self.http_server.url

    def metrics_snapshot(self) -> dict[str, Any]:
        """The ``GET /metrics`` JSON as a dict (usable after shutdown too —
        the bus outlives the sockets)."""
        if self.telemetry is None:
            self.telemetry = Telemetry(trace_path=self.trace_path)
        return self.telemetry.snapshot()

    def orphaned(self) -> list[str]:
        """Node-loaders still running after shutdown (must be empty)."""
        return [nid for nid, h in self.handles.items() if h.poll() is None]
