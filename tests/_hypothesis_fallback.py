"""Tiny deterministic stand-in for `hypothesis` (used when it isn't installed).

The property tests in this suite only need ``@given``/``@settings`` and three
strategies (``integers``, ``sampled_from``, ``lists``).  This fallback runs
each property over a fixed-seed pseudo-random sample of the input space, so
the properties still execute (deterministically) in environments without the
real library.  When ``hypothesis`` is importable, ``conftest.py`` leaves it
alone and this module is unused.

Not a replacement for hypothesis: no shrinking, no example database, no
coverage-guided generation — just enough API to keep tier-1 collection and
the properties themselves running.
"""

from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda r: [elements.draw(r) for _ in range(r.randint(min_size, max_size))]
    )


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


# Fallback runs fewer examples than hypothesis would; the fixed seed keeps
# the sampled subset identical across runs.
_MAX_EXAMPLES_CAP = 25


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies_by_name):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", 100),
            )
            rnd = random.Random(0)
            for _ in range(min(limit, _MAX_EXAMPLES_CAP)):
                drawn = {k: s.draw(rnd) for k, s in strategies_by_name.items()}
                fn(*args, **kwargs, **drawn)

        # Hide the drawn parameters from pytest's signature inspection —
        # otherwise it would look for fixtures named after them.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items()
            if name not in strategies_by_name
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        wrapper.hypothesis_fallback = True
        return wrapper

    return deco


def install(sys_modules) -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "lists", "booleans", "floats"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
