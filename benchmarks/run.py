"""Benchmark harness — one function per paper table + the scale benches.

Prints ``name,us_per_call,derived`` CSV rows.  Paper anchors:

* Table 1 — single-processor worker scaling (Mandelbrot, 1..W workers);
* Table 2 — cluster scaling (nodes x 4 workers, demand-driven);
* Table 3 — multicore-vs-cluster comparison at equal worker cores;
* Table 4 (ours) — threads-vs-processes at equal worker count, with the
  wire counters of the pipelined data plane;
* section 8.2 — application load time, linear in node count;
* roofline — reads ``results/roofline`` (produced by launch.roofline).

The container is one CPU host, so "nodes" are thread groups exactly as the
paper's single-host confidence-building mode (section 6.1); XLA releases
the GIL during the Mandelbrot tile computation so workers overlap.
Absolute times differ from the paper's i7/i9 cluster; the *scaling
behaviour* (speedup, efficiency, demand-driven balance, load-time
linearity) is the reproduced object.

Instance sizes are env-tunable (CI smoke runs shrink them)::

    REPRO_BENCH_LINES / REPRO_BENCH_WIDTH / REPRO_BENCH_ITERS     tables 1-3
    REPRO_BENCH_T4_LINES / REPRO_BENCH_T4_ITERS                   table 4

Table 4's cluster run takes a pluggable launcher: set
``REPRO_BENCH_SSH_HOSTS=host1,host2`` to fan node-loaders out over ssh
(``SSHLauncher``) instead of forking localhost subprocesses — CI's
ssh-smoke job runs exactly this against a loopback sshd.  For hosts that
are *not* this machine, also set ``REPRO_BENCH_BIND_HOST=0.0.0.0`` and
``REPRO_BENCH_CONNECT_HOST=<ip the workstations can dial>``; the
loopback defaults only reach node-loaders running locally.

Table 4 defaults to a larger instance (full paper escape threshold of
1000): the cluster backend pays a real multi-second boot per node
(interpreter + jax import), and on a toy instance that fixed cost — not
the data plane — is all the ratio would measure.
"""

from __future__ import annotations

import datetime
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.builder import ClusterBuilder
from repro.core.dsl import ClusterSpec, Pipeline
from repro.core.processes import EmitDetails, ResultDetails
from repro.kernels.mandelbrot.ops import mandelbrot
from repro.kernels.mandelbrot.ref import line_coords

# Scaled-down Mandelbrot instance (paper: 3200 lines x 5600 points, esc 1000).
LINES = int(os.environ.get("REPRO_BENCH_LINES", "120"))
WIDTH = int(os.environ.get("REPRO_BENCH_WIDTH", "1400"))
MAX_ITERS = int(os.environ.get("REPRO_BENCH_ITERS", "300"))
LINES_PER_ITEM = 4  # one work object = a band of lines (paper: 1 line)

# Table 4 (threads vs processes) runs closer to the paper's instance.
T4_LINES = int(os.environ.get("REPRO_BENCH_T4_LINES", "480"))
T4_MAX_ITERS = int(os.environ.get("REPRO_BENCH_T4_ITERS", "1000"))

# Two-stage pipeline bench (Mandelbrot bands -> per-band reduce).
P2_LINES = int(os.environ.get("REPRO_BENCH_P2_LINES", "96"))
P2_MAX_ITERS = int(os.environ.get("REPRO_BENCH_P2_ITERS", "300"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
COMPILE_CACHE = os.path.join(RESULTS_DIR, "xla_cache")

# Comma-separated workstations for the cluster rows: non-empty -> the same
# bench fans node-loaders out over ssh (the deployment layer's SSHLauncher)
# instead of forking localhost subprocesses.
SSH_HOSTS = [h.strip()
             for h in os.environ.get("REPRO_BENCH_SSH_HOSTS", "").split(",")
             if h.strip()]
# Spanning real machines needs routable addresses on both sides; the
# defaults cover the localhost / loopback-sshd cases.
BIND_HOST = os.environ.get("REPRO_BENCH_BIND_HOST", "127.0.0.1")
CONNECT_HOST = os.environ.get("REPRO_BENCH_CONNECT_HOST") or None


def _bench_launcher():
    """The launcher table4's cluster run deploys with (None = local)."""
    if not SSH_HOSTS:
        return None
    from repro.cluster.deploy import SSHLauncher

    return SSHLauncher(
        SSH_HOSTS,
        connect_host=CONNECT_HOST,
        python=sys.executable,
        preload=("repro.kernels.mandelbrot.ops",),
        compile_cache_dir=os.path.abspath(COMPILE_CACHE),
        connect_timeout=120.0,
    )


def _mandelbrot_spec(
    nclusters: int,
    workers: int,
    *,
    lines: int = LINES,
    width: int = WIDTH,
    max_iters: int = MAX_ITERS,
) -> ClusterSpec:
    lines_per_item = LINES_PER_ITEM

    def init(n_items):
        return (0, n_items)

    def create(state):
        i, n = state
        if i >= n:
            return None, state
        return i, (i + 1, n)

    def work(item: int):
        import jax.numpy as jnp  # the node imports its own (preloaded) jax

        from repro.kernels.mandelbrot.ops import mandelbrot
        from repro.kernels.mandelbrot.ref import line_coords

        y0 = item * lines_per_item
        xs, ys = [], []
        for dy in range(lines_per_item):
            x, y = line_coords(width, y0 + dy)
            xs.append(x)
            ys.append(y)
        x0 = jnp.stack(xs)
        y0g = jnp.stack(ys)
        iters, colour = mandelbrot(x0, y0g, max_iters=max_iters)
        return (int(jnp.sum(iters)), int(jnp.sum(colour)), colour.size)

    def collect(acc, item):
        t, w, p = item
        return (acc[0] + t, acc[1] + w, acc[2] + p)

    return ClusterSpec.simple(
        host="127.0.0.1",
        nclusters=nclusters,
        workers_per_node=workers,
        emit_details=EmitDetails(
            name="Mdata", init=init, init_data=(lines // lines_per_item,),
            create=create,
        ),
        work_function=work,
        result_details=ResultDetails(
            name="Mcollect", init=lambda: (0, 0, 0), collect=collect,
        ),
    )


def _run_spec(nclusters: int, workers: int, backend: str = "threads",
              **spec_kw):
    builder = ClusterBuilder()
    kw = {}
    if backend == "cluster":
        kw = {
            "job_timeout": 600.0,
            # Heavy deps import during node boot, overlapping registration;
            # code distribution (load) then hits a warm module cache.
            "preload": ("repro.kernels.mandelbrot.ops",),
            # Nodes load the host-warmed executable instead of recompiling.
            "compile_cache_dir": COMPILE_CACHE,
            # Deployment is pluggable: REPRO_BENCH_SSH_HOSTS swaps the
            # localhost fork for ssh fan-out, same bench otherwise.
            "launcher": _bench_launcher(),
            "bind_host": BIND_HOST,
            "register_timeout": 120.0,
        }
    app = builder.build_application(
        _mandelbrot_spec(nclusters, workers, **spec_kw), backend=backend, **kw
    )
    t0 = time.perf_counter()
    result = app.run()
    dt = time.perf_counter() - t0
    return dt, result, builder.timing, app


def _warm(max_iters: int = MAX_ITERS) -> None:
    # compile the kernel once so Table rows measure compute, not tracing
    x, y = line_coords(WIDTH, 0)
    x0 = jnp.stack([x] * LINES_PER_ITEM)
    y0 = jnp.stack([y] * LINES_PER_ITEM)
    jax.block_until_ready(mandelbrot(x0, y0, max_iters=max_iters))


def _enable_compile_cache() -> None:
    """Host-side persistent XLA cache shared with node-loader children."""
    os.makedirs(COMPILE_CACHE, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", COMPILE_CACHE)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)


def table1_worker_scaling() -> list[str]:
    """Paper Table 1: 1 node, varying worker count."""
    rows = []
    base = None
    for w in (1, 2, 4, 8):
        dt, result, _, _app = _run_spec(1, w)
        base = base or dt
        speedup = base / dt
        eff = speedup / w
        rows.append(
            f"table1_workers_{w},{dt * 1e6:.0f},"
            f"speedup={speedup:.2f};efficiency={100 * eff:.1f}%"
            f";points={result[2]}"
        )
    return rows


def table2_cluster_scaling() -> list[str]:
    """Paper Table 2: nodes x 4 workers, demand-driven distribution."""
    rows = []
    base = None
    for nodes in (1, 2, 3):
        dt, _result, timing, _app = _run_spec(nodes, 4)
        base = base or dt
        speedup = base / dt
        eff = speedup / nodes
        items = {t.node_id: t.items for t in timing.nodes
                 if t.node_id.startswith("node")}
        rows.append(
            f"table2_nodes_{nodes},{dt * 1e6:.0f},"
            f"speedup={speedup:.2f};efficiency={100 * eff:.1f}%"
            f";items={'/'.join(str(items[k]) for k in sorted(items))}"
        )
    return rows


def table4_threads_vs_processes() -> list[str]:
    """Threads-vs-processes column for Table 1: the same Mandelbrot spec run
    by the threaded runtime (§6.1 confidence mode) and by the real
    multi-process transport (repro.cluster: subprocess node-loaders + TCP,
    credit-pipelined batched data plane).

    Process nodes pay a real boot phase (interpreter start, jax import —
    overlapped with registration and accounted as boot, not load, per the
    §8.2 split) but escape the host GIL entirely.  The full comparison plus
    the wire counters goes to results/bench_cluster.json, and every run
    appends one line to results/bench_trajectory.json so perf regressions
    across PRs stay visible.
    """
    _enable_compile_cache()
    _warm(T4_MAX_ITERS)
    size_kw = dict(lines=T4_LINES, max_iters=T4_MAX_ITERS)
    comparison: dict[str, dict] = {}
    rows = []
    expected = None
    for backend in ("threads", "cluster"):
        dt, result, timing, app = _run_spec(2, 2, backend=backend, **size_kw)
        expected = expected or result
        items = {t.node_id: t.items for t in timing.nodes
                 if t.node_id.startswith("node")}
        comparison[backend] = {
            "seconds": round(dt, 4),
            "points": result[2],
            "results_match": result == expected,
            "boot_ms": round(timing.total_boot_ms(), 3),
            "load_ms": round(timing.total_load_ms(), 3),
            "run_ms": round(timing.total_run_ms(), 3),
            "items_per_node": items,
        }
        if backend == "cluster":
            comparison[backend]["wire"] = {
                k: int(v) for k, v in sorted(timing.wire.items())
            }
            comparison[backend]["launcher"] = (
                f"ssh:{','.join(SSH_HOSTS)}" if SSH_HOSTS else "local"
            )
            # The run's final telemetry snapshot (same JSON GET /metrics
            # serves): per-job gauges, per-node wire/cache counters, events.
            comparison[backend]["metrics"] = app.metrics_snapshot()
        rows.append(
            f"table4_{backend}_nodes2_workers2,{dt * 1e6:.0f},"
            f"points={result[2]}"
            f";items={'/'.join(str(items[k]) for k in sorted(items))}"
            f";load_ms={timing.total_load_ms():.1f}"
            f";boot_ms={timing.total_boot_ms():.1f}"
        )
    comparison["process_over_thread_ratio"] = round(
        comparison["cluster"]["seconds"] / comparison["threads"]["seconds"], 3
    )
    comparison["instance"] = {
        "lines": T4_LINES, "width": WIDTH, "max_iters": T4_MAX_ITERS,
        "lines_per_item": LINES_PER_ITEM,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "bench_cluster.json")
    with open(out_path, "w") as fh:
        json.dump({"mandelbrot_threads_vs_processes": comparison}, fh, indent=2)
    _append_trajectory(comparison)
    rows.append(
        f"table4_json,0,written={os.path.relpath(out_path, os.path.dirname(__file__))}"
    )
    rows.append(
        f"table4_ratio,0,process_over_thread="
        f"{comparison['process_over_thread_ratio']}"
    )
    return rows


def _append_trajectory(comparison: dict) -> None:
    """Bench hygiene: one appended record per table4 run, so the ratio and
    wire traffic are comparable across PRs."""
    path = os.path.join(RESULTS_DIR, "bench_trajectory.json")
    history: list = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                history = json.load(fh)
        except (OSError, ValueError):
            history = []
    history.append({
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "instance": comparison.get("instance", {}),
        "threads_seconds": comparison["threads"]["seconds"],
        "cluster_seconds": comparison["cluster"]["seconds"],
        "process_over_thread_ratio": comparison["process_over_thread_ratio"],
        "results_match": comparison["cluster"]["results_match"],
        "cluster_boot_ms": comparison["cluster"]["boot_ms"],
        "cluster_load_ms": comparison["cluster"]["load_ms"],
        "wire": comparison["cluster"].get("wire", {}),
    })
    with open(path, "w") as fh:
        json.dump(history, fh, indent=2)


def warm_resubmit() -> list[str]:
    """The warm-pool service against the one-shot bill it amortises.

    Boots one ClusterService pool (table4 geometry: 2 nodes x 2 workers,
    subprocess node-loaders), then submits the table4 Mandelbrot instance
    three times back-to-back — the first submission pays the entire boot
    (interpreter + jax import per node) and ships the stage code; the
    second and third run *warm*: ``cluster_boot_ms == 0``, zero functions
    shipped (digest-cache rebind), and wall time comparable to the threads
    backend on the same instance.  A final pair of *concurrent* jobs
    interleaves on the same pool and must both collect exact results.

    Everything lands in results/bench_service.json (CI's service-smoke
    gates on it) and appends one record to results/bench_trajectory.json.
    """
    _enable_compile_cache()
    _warm(T4_MAX_ITERS)
    from repro.cluster.service import ClusterService

    size_kw = dict(lines=T4_LINES, max_iters=T4_MAX_ITERS)
    # The threads baseline the warm submissions are judged against.
    dt_threads, expected, _, _app = _run_spec(2, 2, backend="threads", **size_kw)
    # One spec object resubmitted as-is: identical function objects pickle
    # to identical bytes, which is what makes the digest cache hit.
    spec = _mandelbrot_spec(2, 2, **size_kw)

    rows = []
    record: dict = {"threads_seconds": round(dt_threads, 4),
                    "submissions": [], "concurrent": []}
    launcher = _bench_launcher()
    if launcher is None:
        from repro.cluster.deploy import LocalLauncher

        # Same node-side economics as table4's cluster run: jax imports
        # during boot, the host-warmed XLA cache spares the recompile.
        launcher = LocalLauncher(
            preload=("repro.kernels.mandelbrot.ops",),
            compile_cache_dir=os.path.abspath(COMPILE_CACHE),
        )
    # REPRO_BENCH_HTTP_PORT exposes the live status endpoint for the run
    # (CI's service-smoke curls /metrics and / mid-bench through it).
    http_port = os.environ.get("REPRO_BENCH_HTTP_PORT")
    svc = ClusterService(
        nodes=2, workers=2,
        launcher=launcher,
        bind_host=BIND_HOST,
        register_timeout=120.0,
        http_port=int(http_port) if http_port else None,
    )
    try:
        with svc:
            for i in range(3):
                t0 = time.perf_counter()
                handle = svc.submit(spec, timeout=600.0)
                result = handle.result()
                dt = time.perf_counter() - t0
                stats = handle.stats()
                sub = {
                    "seconds": round(dt, 4),
                    "cluster_boot_ms": round(stats["cluster_boot_ms"], 3),
                    "submit_to_first_result_ms": round(
                        stats["submit_to_first_result_ms"] or 0.0, 3),
                    "code_shipped": stats["code_shipped"],
                    "code_cached": stats["code_cached"],
                    "results_match": result == expected,
                    "vs_threads_ratio": round(dt / dt_threads, 3),
                }
                record["submissions"].append(sub)
                rows.append(
                    f"warm_resubmit_submit{i + 1},{dt * 1e6:.0f},"
                    f"cluster_boot_ms={sub['cluster_boot_ms']}"
                    f";first_result_ms={sub['submit_to_first_result_ms']}"
                    f";code_shipped={sub['code_shipped']}"
                    f";results_match={sub['results_match']}"
                )
            t0 = time.perf_counter()
            handles = [svc.submit(spec, timeout=600.0) for _ in range(2)]
            results = [h.result() for h in handles]
            dt = time.perf_counter() - t0
            for h, r in zip(handles, results):
                record["concurrent"].append({
                    "results_match": r == expected,
                    "submit_to_first_result_ms": round(
                        h.submit_to_first_result_ms or 0.0, 3),
                })
            rows.append(
                f"warm_resubmit_concurrent2,{dt * 1e6:.0f},"
                f"results_match="
                f"{all(c['results_match'] for c in record['concurrent'])}"
            )
            # Final /metrics snapshot while the pool is still up: per-job
            # gauges, per-node wire + warm-cache counters, event cursor.
            record["metrics"] = svc.metrics_snapshot()
            # REPRO_BENCH_HOLD_S keeps the warm pool (and its endpoint) up
            # after the runs so an external prober has a window to read
            # jobs_completed >= 1 — the runs themselves finish in well
            # under a second once warm.
            hold = float(os.environ.get("REPRO_BENCH_HOLD_S", "0") or 0)
            if hold > 0:
                time.sleep(hold)
    finally:
        record["orphaned"] = svc.orphaned()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "bench_service.json")
    with open(out_path, "w") as fh:
        json.dump({"warm_resubmit": record}, fh, indent=2)
    _append_service_trajectory(record)
    rows.append(
        f"warm_resubmit_json,0,"
        f"written={os.path.relpath(out_path, os.path.dirname(__file__))}"
    )
    return rows


def _append_service_trajectory(record: dict) -> None:
    """One appended record per warm_resubmit run: the boot amortisation and
    warm-submit latency stay comparable across PRs."""
    path = os.path.join(RESULTS_DIR, "bench_trajectory.json")
    history: list = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                history = json.load(fh)
        except (OSError, ValueError):
            history = []
    history.append({
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "bench": "warm_resubmit",
        "instance": {"lines": T4_LINES, "width": WIDTH,
                     "max_iters": T4_MAX_ITERS,
                     "lines_per_item": LINES_PER_ITEM},
        "threads_seconds": record["threads_seconds"],
        "submissions": [
            {"cluster_boot_ms": s["cluster_boot_ms"],
             "submit_to_first_result_ms": s["submit_to_first_result_ms"],
             "seconds": s["seconds"],
             "results_match": s["results_match"]}
            for s in record["submissions"]
        ],
        "concurrent_results_match": all(
            c["results_match"] for c in record["concurrent"]
        ),
    })
    with open(path, "w") as fh:
        json.dump(history, fh, indent=2)


# Gateway fairness bench sizes (CI smoke shrinks them via env).
GW_SLEEP_MS = float(os.environ.get("REPRO_BENCH_GW_SLEEP_MS", "10"))
GW_WIDE_JOBS = int(os.environ.get("REPRO_BENCH_GW_WIDE_JOBS", "2"))
GW_WIDE_ITEMS = int(os.environ.get("REPRO_BENCH_GW_WIDE_ITEMS", "60"))
GW_NARROW_JOBS = int(os.environ.get("REPRO_BENCH_GW_NARROW_JOBS", "8"))
GW_AS_ITEMS = int(os.environ.get("REPRO_BENCH_GW_AS_ITEMS", "20"))


def _gw_sleep_work(x):
    """Fixed-cost work item: the gateway bench measures *scheduling*
    latency, so compute time must be a constant, not a kernel."""
    time.sleep(GW_SLEEP_MS / 1e3)
    return x * 2


def _gw_spec(n_items):
    from repro.core.processes import EmitDetails, ResultDetails

    def init(limit):
        return (0, limit)

    def create(state):
        return (None, state) if state[0] >= state[1] \
            else (state[0], (state[0] + 1, state[1]))

    return ClusterSpec.simple(
        host="127.0.0.1", nclusters=1, workers_per_node=2,
        emit_details=EmitDetails(name="range", init=init,
                                 init_data=(n_items,), create=create),
        work_function=_gw_sleep_work,
        result_details=ResultDetails(name="list", init=lambda: [],
                                     collect=lambda a, x: a + [x],
                                     finalise=sorted),
    )


def _p50(values):
    ordered = sorted(values)
    return ordered[len(ordered) // 2] if ordered else 0.0


def gateway_fairness() -> list[str]:
    """The job gateway's three pillars, measured.

    On one warm pool (1 node x 2 workers, in-process node-loaders — the
    gateway is host-side machinery, so node realism buys nothing here):

    * **solo** — the narrow tenant alone: N one-item tickets through a
      fair gateway; their p50 enqueue-to-done latency is the baseline;
    * **fifo** — the PR 6 behaviour: a wide tenant's big high-priority
      jobs enqueued first, the narrow tickets behind them, ``mode="fifo"``
      (raw priority, no credit caps) — the starvation figure;
    * **fair** — the same mix under weighted-fair admission with the wide
      tenant capped at ``max_inflight=1``: the acceptance gate is the
      narrow tenant's p50 at most 3x its solo p50;
    * **durability** — enqueue, kill the gateway before admission,
      restart over the same database, reattach: the result must match and
      report ``cluster_boot_ms == 0`` (the pool stayed warm throughout);
    * **autoscale** — a fresh 1-node pool, three tenants' bursts, one
      ticket deliberately dropped and reattached by id: the queue-driven
      control loop must grow the pool (``scale_up_events >= 1``).

    Everything lands in results/bench_gateway.json (CI's gateway-smoke
    gates on it) plus one bench_trajectory.json record.
    """
    from repro.cluster.deploy.inprocess import InProcessLauncher
    from repro.cluster.gateway import (
        AutoscalePolicy,
        JobGateway,
        TenantPolicy,
    )
    from repro.cluster.service import ClusterService

    os.makedirs(RESULTS_DIR, exist_ok=True)
    db_dir = os.path.join(RESULTS_DIR, "gateway_dbs")
    os.makedirs(db_dir, exist_ok=True)

    def db(name):
        path = os.path.join(db_dir, f"{name}.db")
        if os.path.exists(path):
            os.remove(path)
        return path

    def ticket_latencies_ms(gw, tickets):
        out = []
        for t in tickets:
            row = gw.store.get(t)
            out.append((row.finished_at - row.submitted_at) * 1e3)
        return out

    tenants = {"wide": TenantPolicy(weight=1.0, max_inflight=1),
               "narrow": TenantPolicy(weight=1.0)}
    record: dict = {
        "instance": {
            "sleep_ms": GW_SLEEP_MS, "wide_jobs": GW_WIDE_JOBS,
            "wide_items": GW_WIDE_ITEMS, "narrow_jobs": GW_NARROW_JOBS,
            "autoscale_items": GW_AS_ITEMS,
        },
    }
    rows = []
    narrow_expected = [2 * i for i in range(1)]

    with ClusterService(nodes=1, workers=2,
                        launcher=InProcessLauncher()) as svc:
        # -- solo: the narrow tenant with the pool to itself -------------
        with JobGateway(svc, db("solo"), tenants=tenants) as gw:
            tickets = [gw.enqueue(_gw_spec(1), tenant="narrow")
                       for _ in range(GW_NARROW_JOBS)]
            for t in tickets:
                assert gw.attach(t).result(timeout=300) == narrow_expected
            solo = ticket_latencies_ms(gw, tickets)
        record["solo"] = {"p50_ms": round(_p50(solo), 3),
                          "latencies_ms": [round(v, 3) for v in solo]}

        # -- fifo baseline vs fair, same tenant mix ----------------------
        for mode in ("fifo", "fair"):
            with JobGateway(svc, db(mode), tenants=tenants,
                            mode=mode) as gw:
                t0 = time.perf_counter()
                wide = [gw.enqueue(_gw_spec(GW_WIDE_ITEMS), tenant="wide",
                                   priority=5)
                        for _ in range(GW_WIDE_JOBS)]
                narrow = [gw.enqueue(_gw_spec(1), tenant="narrow",
                                     priority=0)
                          for _ in range(GW_NARROW_JOBS)]
                ok = all(
                    gw.attach(t).result(timeout=600) == narrow_expected
                    for t in narrow)
                ok &= all(
                    gw.attach(t).result(timeout=600)
                    == [2 * i for i in range(GW_WIDE_ITEMS)]
                    for t in wide)
                dt = time.perf_counter() - t0
                lat = ticket_latencies_ms(gw, narrow)
            record[mode] = {
                "narrow_p50_ms": round(_p50(lat), 3),
                "narrow_max_ms": round(max(lat), 3),
                "narrow_over_solo_p50": round(
                    _p50(lat) / max(record["solo"]["p50_ms"], 1e-9), 3),
                "elapsed_seconds": round(dt, 4),
                "results_match": ok,
            }
            rows.append(
                f"gateway_{mode}_narrow_p50,"
                f"{record[mode]['narrow_p50_ms'] * 1e3:.0f},"
                f"over_solo={record[mode]['narrow_over_solo_p50']}"
                f";results_match={ok}"
            )

        # -- durability: enqueue, crash, restart, reattach ---------------
        dura_db = db("durability")
        gw1 = JobGateway(svc, dura_db,
                         default_policy=TenantPolicy(max_active_jobs=0))
        ticket = gw1.enqueue(_gw_spec(GW_AS_ITEMS), tenant="narrow")
        gw1.kill()  # the simulated crash: the row survives, queued
        t0 = time.perf_counter()
        with JobGateway(svc, dura_db) as gw2:
            handle = gw2.attach(ticket)
            result = handle.result(timeout=300)
            stats = handle.stats()
        record["durability"] = {
            "results_match": result == [2 * i for i in range(GW_AS_ITEMS)],
            "cluster_boot_ms": stats.get("cluster_boot_ms"),
            "reattach_to_result_seconds": round(
                time.perf_counter() - t0, 4),
        }
        rows.append(
            f"gateway_durability,"
            f"{record['durability']['reattach_to_result_seconds'] * 1e6:.0f},"
            f"results_match={record['durability']['results_match']}"
            f";cluster_boot_ms={record['durability']['cluster_boot_ms']}"
        )

    # -- autoscale: three tenants' burst on a fresh 1-node pool ----------
    policy = AutoscalePolicy(min_nodes=1, max_nodes=2, scale_up_wait_s=0.15,
                             backlog_per_node=2.0, cooldown_s=0.3,
                             idle_shrink_s=5.0, interval_s=0.05)
    with ClusterService(nodes=1, workers=2,
                        launcher=InProcessLauncher()) as svc:
        with JobGateway(svc, db("autoscale"), autoscale=policy,
                        max_active_jobs=2) as gw:
            tickets = {}
            for tenant in ("alice", "bob", "carol"):
                tickets[tenant] = [
                    gw.enqueue(_gw_spec(GW_AS_ITEMS), tenant=tenant)
                    for _ in range(2)
                ]
            # One client "disconnects": bob's first handle is dropped and
            # the ticket reattached by id only.
            reattached = gw.attach(tickets["bob"][0])
            ok = all(
                gw.attach(t).result(timeout=600)
                == [2 * i for i in range(GW_AS_ITEMS)]
                for ts in tickets.values() for t in ts)
            ok &= (reattached.result(timeout=60)
                   == [2 * i for i in range(GW_AS_ITEMS)])
            counters = svc.telemetry.snapshot()["cluster"]
        record["autoscale"] = {
            "tenants": 3,
            "results_match": ok,
            "scale_up_events": int(counters.get("scale_up_events", 0)),
            "scale_down_events": int(counters.get("scale_down_events", 0)),
        }
        rows.append(
            f"gateway_autoscale,0,"
            f"results_match={ok}"
            f";scale_up_events={record['autoscale']['scale_up_events']}"
        )

    out_path = os.path.join(RESULTS_DIR, "bench_gateway.json")
    with open(out_path, "w") as fh:
        json.dump({"gateway_fairness": record}, fh, indent=2)
    _append_gateway_trajectory(record)
    rows.append(
        f"gateway_json,0,"
        f"written={os.path.relpath(out_path, os.path.dirname(__file__))}"
    )
    return rows


def _append_gateway_trajectory(record: dict) -> None:
    """One appended record per gateway_fairness run: the fairness ratio,
    durability round-trip and autoscale figures stay comparable across
    PRs."""
    path = os.path.join(RESULTS_DIR, "bench_trajectory.json")
    history: list = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                history = json.load(fh)
        except (OSError, ValueError):
            history = []
    history.append({
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "bench": "gateway_fairness",
        "instance": record["instance"],
        "solo_p50_ms": record["solo"]["p50_ms"],
        "fair_over_solo_p50": record["fair"]["narrow_over_solo_p50"],
        "fifo_over_solo_p50": record["fifo"]["narrow_over_solo_p50"],
        "durability_results_match": record["durability"]["results_match"],
        "durability_cluster_boot_ms": record["durability"]["cluster_boot_ms"],
        "autoscale_results_match": record["autoscale"]["results_match"],
        "scale_up_events": record["autoscale"]["scale_up_events"],
    })
    with open(path, "w") as fh:
        json.dump(history, fh, indent=2)


def chaos_smoke() -> list[str]:
    """Self-healing under injected faults: the chaos harness against a
    real subprocess pool.

    Boots a 4-node ClusterService with a fixed FaultPlan — one mid-run
    ``kill_node`` (node1, progress-triggered) plus one ``straggler``
    window — a heal budget of 1, and runs the tiny table4 Mandelbrot
    instance submitted with ``retries=1``.  The pool must detect the
    death, launch a replacement through the placement path, and still
    produce the exact threads-backend result; the attempt history and
    the chaos/heal counters land in results/bench_chaos.json for CI's
    chaos-smoke gates (results_match, respawns >= 1, attempts present).
    """
    _enable_compile_cache()
    _warm(T4_MAX_ITERS)
    from repro.cluster.chaos import Fault, FaultPlan, chaos_events
    from repro.cluster.service import ClusterService

    size_kw = dict(lines=T4_LINES, max_iters=T4_MAX_ITERS)
    _, expected, _, _app = _run_spec(2, 2, backend="threads", **size_kw)
    spec = _mandelbrot_spec(4, 1, **size_kw)

    launcher = _bench_launcher()
    if launcher is None:
        from repro.cluster.deploy import LocalLauncher

        launcher = LocalLauncher(
            preload=("repro.kernels.mandelbrot.ops",),
            compile_cache_dir=os.path.abspath(COMPILE_CACHE),
        )
    plan = FaultPlan([
        Fault("kill_node", node="node1", after_items=1),
        Fault("straggler", node="node0", at_s=0.5, duration_s=2.0,
              delay_s=0.05),
    ])
    svc = ClusterService(
        nodes=4, workers=1,
        launcher=launcher,
        bind_host=BIND_HOST,
        register_timeout=120.0,
        heartbeat_interval=0.25, heartbeat_misses=6,
        max_heals=1,
        chaos=plan,
    )
    record: dict = {}
    t0 = time.perf_counter()
    try:
        with svc:
            handle = svc.submit(spec, timeout=600.0, retries=1)
            result = handle.result(timeout=600.0)
            # The kill fires on progress but detection rides the heartbeat
            # deadline — on a fast instance the job can finish first, so
            # wait for the heal before snapshotting the counters.
            deadline = time.monotonic() + 60.0
            while (svc.host_loader.stats.heals < 1
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            stats = handle.stats()
            record = {
                "seconds": round(time.perf_counter() - t0, 4),
                "results_match": result == expected,
                "respawns": stats["respawns"],
                "heals": stats["heals"],
                "deaths_detected": svc.host_loader.stats.deaths_detected,
                "redispatched": svc.host_loader.stats.redispatched,
                "attempts": stats["attempts"],
                "fired": svc.chaos_controller.fired,
                "chaos_heal_events": [
                    {k: e.get(k) for k in ("kind", "node", "fault")}
                    for e in chaos_events(svc.telemetry.events_since(0))
                ],
                "metrics": svc.metrics_snapshot(),
            }
    finally:
        record["orphaned"] = svc.orphaned()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "bench_chaos.json")
    with open(out_path, "w") as fh:
        json.dump({"chaos_smoke": record}, fh, indent=2)
    return [
        f"chaos_smoke,{record['seconds'] * 1e6:.0f},"
        f"results_match={record['results_match']}"
        f";respawns={record['respawns']}"
        f";deaths_detected={record['deaths_detected']}"
        f";faults_injected={len(record['fired'])}",
        f"chaos_smoke_json,0,"
        f"written={os.path.relpath(out_path, os.path.dirname(__file__))}",
    ]


def _two_stage_pipeline_spec(lines: int = P2_LINES, width: int = WIDTH,
                             max_iters: int = P2_MAX_ITERS, *,
                             route: str | None = None,
                             block: tuple[str, str] | None = None):
    """Mandelbrot rendered per band (stage 1, the compute-heavy hop) whose
    per-line records are then reduced per band (stage 2, a cheap hop on its
    own node) — the multi-stage shape the PipelineSpec API adds.

    ``route="peer"`` marks the render->reduce hop for direct node-to-node
    shipping; ``block=(name, digest)`` makes the reduce stage fetch that
    broadcast block once per worker and digest-check it, so a broken
    chunk-stripe fetch fails the job instead of passing silently."""
    lines_per_item = LINES_PER_ITEM

    def init(n_items):
        return (0, n_items)

    def create(state):
        i, n = state
        if i >= n:
            return None, state
        return i, (i + 1, n)

    def render(item: int):
        import jax.numpy as jnp  # the node imports its own (preloaded) jax

        from repro.kernels.mandelbrot.ops import mandelbrot
        from repro.kernels.mandelbrot.ref import line_coords

        y0 = item * lines_per_item
        xs, ys = [], []
        for dy in range(lines_per_item):
            x, y = line_coords(width, y0 + dy)
            xs.append(x)
            ys.append(y)
        iters, colour = mandelbrot(jnp.stack(xs), jnp.stack(ys),
                                   max_iters=max_iters)
        # one record per line: (total_iters, white, points)
        return [
            (int(jnp.sum(iters[i])), int(jnp.sum(colour[i])), width)
            for i in range(lines_per_item)
        ]

    checked: list = []  # per-worker once-flag (each process gets its own)

    def reduce_band(records):
        t = w = p = 0
        for (ti, wi, pi) in records:
            t, w, p = t + ti, w + wi, p + pi
        if block is not None and not checked:
            from repro.cluster.peer import block_digest, get_block

            name, digest = block
            blob = get_block(name, timeout=60.0)
            if blob is None or block_digest(blob) != digest:
                raise RuntimeError(
                    f"broadcast block {name!r} missing or corrupt")
            checked.append(True)
        return (t, w, p)

    def collect(acc, item):
        t, w, p = item
        return (acc[0] + t, acc[1] + w, acc[2] + p)

    return (Pipeline(host="127.0.0.1")
            .emit(EmitDetails(name="Mdata", init=init,
                              init_data=(lines // lines_per_item,),
                              create=create))
            .stage(render, nodes=2, workers=2, name="render")
            .stage(reduce_band, nodes=1, workers=1, name="reduce",
                   route=route)
            .collect(ResultDetails(name="Mcollect", init=lambda: (0, 0, 0),
                                   collect=collect))
            .build())


def pipeline_two_stage() -> list[str]:
    """The two-stage pipeline on both backends: same spec, matching results.

    Row format mirrors table4; the derived column records the per-stage
    item routing (render nodes share the emit stream, the reduce node sees
    every forwarded band) and whether the backends agree.
    """
    _enable_compile_cache()
    _warm(P2_MAX_ITERS)
    rows = []
    expected = None
    match = True
    for backend in ("threads", "cluster"):
        builder = ClusterBuilder()
        kw = {}
        if backend == "cluster":
            kw = {
                "job_timeout": 600.0,
                "preload": ("repro.kernels.mandelbrot.ops",),
                "compile_cache_dir": COMPILE_CACHE,
                "register_timeout": 120.0,
            }
        app = builder.build_application(
            _two_stage_pipeline_spec(), backend=backend, **kw
        )
        t0 = time.perf_counter()
        result = app.run()
        dt = time.perf_counter() - t0
        expected = expected or result
        match = match and (result == expected)
        items = {t.node_id: t.items for t in builder.timing.nodes
                 if t.node_id.startswith("node")}
        rows.append(
            f"pipeline2_{backend}_render2x2_reduce1x1,{dt * 1e6:.0f},"
            f"points={result[2]}"
            f";items={'/'.join(str(items[k]) for k in sorted(items))}"
            f";results_match={result == expected}"
        )
    rows.append(f"pipeline2_match,0,results_match={match}")
    return rows


def peer_pipeline() -> list[str]:
    """Peer data plane vs host relay on the two-stage pipeline.

    One 3-node ClusterService pool runs the P2 Mandelbrot bands->reduce
    instance twice with the same geometry: first with the render->reduce
    hop host-relayed (the v1 data plane), then with ``route="peer"`` so
    render nodes ship band records straight to a reduce node and the host
    carries only per-item acks.  Before the peer run a ~2 MiB broadcast
    block is published and digest-checked inside the reduce stage, which
    exercises the chunk-stripe fetch path (each node host-fetches its
    stripe and trades the rest peer-to-peer).

    Everything lands in results/bench_peer.json; CI's peer-smoke job
    gates on results_match for both runs, ``host_relay_bytes == 0`` on
    the peer run, and at least one chunk fetched from a peer.
    """
    _enable_compile_cache()
    _warm(P2_MAX_ITERS)
    from repro.cluster.service import ClusterService

    builder = ClusterBuilder()
    t0 = time.perf_counter()
    expected = builder.build_application(
        _two_stage_pipeline_spec(), backend="threads").run()
    dt_threads = time.perf_counter() - t0

    launcher = _bench_launcher()
    if launcher is None:
        from repro.cluster.deploy import LocalLauncher

        launcher = LocalLauncher(
            preload=("repro.kernels.mandelbrot.ops",),
            compile_cache_dir=os.path.abspath(COMPILE_CACHE),
        )
    rows: list[str] = []
    record: dict = {"threads_seconds": round(dt_threads, 4)}
    svc = ClusterService(
        nodes=3, workers=2,
        launcher=launcher,
        bind_host=BIND_HOST,
        register_timeout=120.0,
    )
    try:
        with svc:
            # Deterministic ~2 MiB payload = three 1 MiB chunks across
            # three nodes: the stripe hands every node one host-fetch and
            # forces the other two chunks to come from peers.
            blob = bytes(range(256)) * (2 * 1024 * 1024 // 256 + 1)
            digest = svc.publish_block("peer_bench_weights", blob)
            for mode in ("host", "peer"):
                spec = _two_stage_pipeline_spec(
                    route="peer" if mode == "peer" else None,
                    block=("peer_bench_weights", digest)
                    if mode == "peer" else None,
                )
                t0 = time.perf_counter()
                handle = svc.submit(spec, timeout=600.0)
                result = handle.result(timeout=600.0)
                dt = time.perf_counter() - t0
                stats = handle.stats()
                record[mode] = {
                    "seconds": round(dt, 4),
                    "results_match": result == expected,
                    "host_relay_bytes": stats["host_relay_bytes"],
                    "peer_forwarded": stats["peer_forwarded"],
                    "duplicates_dropped": stats["duplicates_dropped"],
                }
                rows.append(
                    f"peer_pipeline_{mode},{dt * 1e6:.0f},"
                    f"results_match={result == expected}"
                    f";host_relay_bytes={stats['host_relay_bytes']}"
                    f";peer_forwarded={stats['peer_forwarded']}"
                )
            snap = svc.metrics_snapshot()
            reports = [n.get("report") or {}
                       for n in (snap.get("nodes") or {}).values()]
            for k in ("blocks_fetched_from_peers", "blocks_fetched_from_host",
                      "peer_bytes_sent", "peer_bytes_recv"):
                record[k] = sum(r.get(k, 0) for r in reports)
            record["metrics"] = snap
    finally:
        record["orphaned"] = svc.orphaned()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "bench_peer.json")
    with open(out_path, "w") as fh:
        json.dump({"peer_pipeline": record}, fh, indent=2)
    _append_peer_trajectory(record)
    rows.append(
        f"peer_pipeline_blocks,0,"
        f"from_peers={record['blocks_fetched_from_peers']}"
        f";from_host={record['blocks_fetched_from_host']}"
    )
    rows.append(
        f"peer_pipeline_json,0,"
        f"written={os.path.relpath(out_path, os.path.dirname(__file__))}"
    )
    return rows


def _append_peer_trajectory(record: dict) -> None:
    """One appended record per peer_pipeline run: relayed-vs-peer bytes
    stay comparable across PRs."""
    path = os.path.join(RESULTS_DIR, "bench_trajectory.json")
    history: list = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                history = json.load(fh)
        except (OSError, ValueError):
            history = []
    history.append({
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "bench": "peer_pipeline",
        "instance": {"lines": P2_LINES, "width": WIDTH,
                     "max_iters": P2_MAX_ITERS,
                     "lines_per_item": LINES_PER_ITEM},
        "threads_seconds": record["threads_seconds"],
        "host_relay_bytes": {m: record[m]["host_relay_bytes"]
                             for m in ("host", "peer")},
        "peer_forwarded": record["peer"]["peer_forwarded"],
        "peer_bytes_sent": record.get("peer_bytes_sent", 0),
        "blocks_fetched_from_peers": record.get("blocks_fetched_from_peers", 0),
        "results_match": all(record[m]["results_match"]
                             for m in ("host", "peer")),
    })
    with open(path, "w") as fh:
        json.dump(history, fh, indent=2)


def table3_multicore_vs_cluster() -> list[str]:
    """Paper Table 3: same worker-core count, one node vs many nodes."""
    rows = []
    for cores in (4, 8):
        dt_multi, _r1, _, _app = _run_spec(1, cores)  # "multicore": 1 node
        dt_cluster, _r2, _, _app2 = _run_spec(cores // 4, 4)  # 4-core nodes
        diff = (dt_cluster - dt_multi) / dt_cluster * 100
        rows.append(
            f"table3_cores_{cores},{dt_cluster * 1e6:.0f},"
            f"multicore_us={dt_multi * 1e6:.0f};diff={diff:.1f}%"
        )
    return rows


def load_time_linearity() -> list[str]:
    """Paper section 8.2: load time linear in node count, small vs runtime."""
    rows = []
    for nodes in (1, 2, 4, 8):
        builder = ClusterBuilder()
        app = builder.build_application(_mandelbrot_spec(nodes, 1))
        app.run()
        load_ms = builder.timing.total_load_ms()
        frac = builder.timing.load_fraction()
        rows.append(
            f"load_time_nodes_{nodes},{load_ms * 1e3:.0f},"
            f"load_fraction={100 * frac:.2f}%"
        )
    return rows


def verification_cost() -> list[str]:
    """Formal verification wall time (FDR-analogue, paper section 7)."""
    from repro.core.verify import verify_network

    rows = []
    for (n, w, m) in [(2, 1, 5), (2, 2, 4)]:
        t0 = time.perf_counter()
        rep = verify_network(n, w, m)
        dt = time.perf_counter() - t0
        rows.append(
            f"verify_N{n}_W{w}_M{m},{dt * 1e6:.0f},"
            f"states={rep.num_states};ok={rep.ok}"
        )
    return rows


def kernel_microbench() -> list[str]:
    """Per-kernel interpret-mode sanity timings vs jnp oracle."""
    from repro.kernels.rmsnorm.ops import rms_norm
    from repro.kernels.rmsnorm.ref import rms_norm_reference

    x = jax.random.normal(jax.random.PRNGKey(0), (512, 1024), jnp.float32)
    s = jnp.zeros((1024,))
    rows = []
    for name, fn in (
        ("rmsnorm_pallas_interp", lambda: rms_norm(x, s)),
        ("rmsnorm_jnp_ref", lambda: rms_norm_reference(x, s)),
    ):
        fn()  # warm
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        rows.append(f"{name},{(time.perf_counter() - t0) / 5 * 1e6:.0f},-")
    return rows


def roofline_summary() -> list[str]:
    """Summarise results/roofline (if the sweep has been run)."""
    out_dir = os.path.join(os.path.dirname(__file__), "..", "results",
                           "roofline")
    rows = []
    if not os.path.isdir(out_dir):
        return ["roofline,0,run `python -m repro.launch.roofline --all` first"]
    for name in sorted(os.listdir(out_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(out_dir, name)) as fh:
            r = json.load(fh)
        if not r.get("ok"):
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,FAILED")
            continue
        bound = max(r["terms_seconds"].values())
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},{bound * 1e6:.0f},"
            f"dominant={r['dominant']};useful={r['useful_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.3f}"
        )
    return rows


def main() -> None:
    sections = [
        table1_worker_scaling,
        table2_cluster_scaling,
        table3_multicore_vs_cluster,
        table4_threads_vs_processes,
        warm_resubmit,
        gateway_fairness,
        chaos_smoke,
        pipeline_two_stage,
        peer_pipeline,
        load_time_linearity,
        verification_cost,
        kernel_microbench,
        roofline_summary,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    selected = [fn for fn in sections if not only or only in fn.__name__]
    # The generic warm-up compiles the MAX_ITERS kernel, which only the
    # Mandelbrot tables at default size use — table4 warms its own
    # (T4_MAX_ITERS) variant, so e.g. CI's table4-only smoke skips this.
    needs_warm = {table1_worker_scaling, table2_cluster_scaling,
                  table3_multicore_vs_cluster, load_time_linearity}
    if needs_warm & set(selected):
        _warm()
    print("name,us_per_call,derived")
    for fn in selected:
        for row in fn():
            print(row, flush=True)


if __name__ == "__main__":
    main()
