"""Flash attention (forward) as a Pallas TPU kernel.

Tiling (FlashAttention re-thought for VMEM/MXU rather than SRAM/warps):

* grid = (B*H, Sq / BLOCK_Q); each program owns one query block;
* K/V live in VMEM as whole-sequence blocks (per (b,h) slice) — on v5e,
  Skv<=4096 bf16 keys+values = 2 x 1MiB, well under the ~16MiB VMEM budget;
  the inner ``fori_loop`` walks KV in BLOCK_K chunks with ``pl.load``;
* online softmax: running (max, denom, acc) in f32 registers, rescaled per
  chunk — no [Sq, Skv] tensor ever exists;
* causal: the KV loop stops at the diagonal block (trip count is a
  traced-static function of the query-block index), the diagonal chunk is
  masked lane-wise; optional sliding window lower-bounds the loop start.

MXU alignment: BLOCK_Q x BLOCK_K = 128 x 128 tiles; D (head_dim) 64-256.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, window: int,
                  block_k: int, sm_scale: float):
    # q_ref: [BLOCK_Q, D]; k_ref/v_ref: [Skv, D]; o_ref: [BLOCK_Q, D]
    qi = pl.program_id(1)
    block_q, D = q_ref.shape
    skv = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale

    q_start = qi * block_q
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)[:, None]  # [bq, 1]

    # KV range touched by this query block.
    hi = skv if not causal else jnp.minimum(skv, q_start + block_q)
    num_k = pl.cdiv(hi, block_k) if causal else skv // block_k
    lo_block = 0
    if window > 0:
        lo = jnp.maximum(0, q_start - window)
        lo_block = lo // block_k

    def body(kb, state):
        m_prev, l_prev, acc = state
        k_start = kb * block_k
        kv_idx = pl.dslice(k_start, block_k)
        kk = pl.load(k_ref, (kv_idx, slice(None))).astype(jnp.float32)
        vv = pl.load(v_ref, (kv_idx, slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)[None, :]
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        mask &= k_pos < skv  # guard ragged tail
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo_block, num_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, H, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if Sq % block_q or Skv % block_k:
        raise ValueError(
            f"Sq={Sq}/Skv={Skv} must tile by ({block_q},{block_k}); "
            "use ops.flash_attention for padding"
        )
    sm_scale = 1.0 / math.sqrt(D)
    grid = (B * H, Sq // block_q)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_k=block_k,
        sm_scale=sm_scale,
    )
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Skv, D)
    vf = v.reshape(B * H, Skv, D)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, Skv, D), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, Skv, D), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda bh, qi: (bh, qi, 0)),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
