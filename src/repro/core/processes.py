"""Declarative process records mirroring the paper's (GPP-library) processes.

A ClusterBuilder specification instantiates these records exactly as Listing 2
of the paper does in Groovy::

    emit      = Emit(e_details=...)                 # {2:12}
    onrl      = OneNodeRequestedList()              # {2:13}
    nrfa      = NodeRequestingFanAny(destinations=cores)   # {2:16}
    group     = AnyGroupAny(workers=cores, function=Mdata.calculate)  # {2:17}
    afoc      = AnyFanOne(sources=cores)            # {2:20}
    afo       = AnyFanOne(sources=clusters)         # {2:28}
    collector = Collect(r_details=...)              # {2:29}

These records are *purely declarative* — they carry no channels.  The
``ClusterBuilder`` wires them (paper requirement 4: "define and build
application network interconnections with no user intervention") and the
runtime executes them; ``core.protocol``/``core.verify`` model-check the
resulting network.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


class ProcessRecord:
    """Marker base class for the declarative process records."""


@dataclass
class EmitDetails:
    """Mirror of the paper's ``DataDetails`` {2:7-11}.

    ``init`` is called once with ``init_data`` and returns the initial
    generator state; ``create`` is called repeatedly with the current state
    and must return ``(work_item | None, new_state)`` — ``None`` signals
    *normalTermination* (the generator is exhausted), after which the builder
    injects the Universal Terminator into the network.
    """

    name: str
    create: Callable[[Any], tuple[Any, Any]]
    init: Callable[..., Any] | None = None
    init_data: Sequence[Any] = ()

    def initial_state(self) -> Any:
        if self.init is None:
            return None
        return self.init(*self.init_data)


@dataclass
class ResultDetails:
    """Mirror of the paper's ``ResultDetails`` {2:23-27}.

    ``init`` returns the accumulator, ``collect(acc, item) -> acc`` folds one
    processed object in, ``finalise(acc)`` produces the final result (the
    paper prints counts; we return the value as well).
    """

    name: str
    collect: Callable[[Any, Any], Any]
    init: Callable[[], Any] = lambda: None
    finalise: Callable[[Any], Any] = lambda acc: acc


@dataclass
class Emit(ProcessRecord):
    """Produces work objects into the network (paper's ``Emit``)."""

    e_details: EmitDetails


@dataclass
class OneNodeRequestedList(ProcessRecord):
    """The ``onrl`` *server* process of the client-server pair.

    Reads one object from Emit, then waits for a *request* signal from any
    node's ``nrfa`` client and answers it with the object.  Responding to a
    client request in finite time, with no client-server loops, guarantees
    deadlock/livelock freedom (Welch et al. 1993) — model-checked in
    ``core.verify``.
    """


@dataclass
class NodeRequestingFanAny(ProcessRecord):
    """The ``nrfa`` *client* process resident on every node.

    Acts as a one-place buffer: it may only issue a new request to the server
    after it has delivered its current object to an idle worker.  This is the
    invariant that keeps the server unblocked (paper §5) and is asserted by
    the model checker.
    """

    destinations: int = 1  # number of workers it fans out to


@dataclass
class AnyGroupAny(ProcessRecord):
    """A group of identical worker processes (paper's ``group`` {2:17-19}).

    ``function`` is the user's sequential data-object method (e.g.
    ``Mdata.calculate``); workers read any, compute, and write any.
    """

    workers: int
    function: Callable[[Any], Any]


@dataclass
class AnyFanOne(ProcessRecord):
    """Merges ``sources`` input streams into one output stream.

    Used twice in the canonical network: per-node (``afoc``, merging that
    node's workers) and at the host (``afo``, merging the node streams into
    the collector).
    """

    sources: int


@dataclass
class Collect(ProcessRecord):
    """Folds processed objects into the final result (paper's ``Collect``)."""

    r_details: ResultDetails


@dataclass
class NodeNetwork:
    """The process group replicated on every cluster node (Figure 2)."""

    nrfa: NodeRequestingFanAny
    group: AnyGroupAny
    afoc: AnyFanOne

    def __post_init__(self) -> None:
        if self.nrfa.destinations != self.group.workers:
            raise ValueError(
                "nrfa.destinations must equal group.workers "
                f"({self.nrfa.destinations} != {self.group.workers})"
            )
        if self.afoc.sources != self.group.workers:
            raise ValueError(
                "afoc.sources must equal group.workers "
                f"({self.afoc.sources} != {self.group.workers})"
            )


@dataclass
class HostNetwork:
    """The process group resident on the host node (emit + collect phases)."""

    emit: Emit
    onrl: OneNodeRequestedList
    afo: AnyFanOne
    collector: Collect


@dataclass
class StageNetwork:
    """The record group of one pipeline stage.

    The paper's network (Figure 2) is the one-stage special case; a stage
    generalises it into a reusable hop: a host-side server (``onrl``) feeding
    ``nclusters`` replicas of the node fragment (``node_net``), merged back
    at the host by ``afo`` — whose output stream is either the next stage's
    server input or the collector.  Every hop is therefore exactly the
    client-server pattern whose deadlock/livelock freedom ``core.verify``
    proves; ``PipelineSpec`` chains the hops.
    """

    name: str
    nclusters: int
    node_net: NodeNetwork
    onrl: OneNodeRequestedList = field(default_factory=OneNodeRequestedList)
    afo: AnyFanOne | None = None
    # Per-stage data-plane knobs (None = inherit the cluster-wide values):
    # extra items beyond ``workers`` a node of this stage keeps buffered,
    # and the node-side result-flush interval in milliseconds.
    prefetch: int | None = None
    flush_ms: float | None = None
    # How this stage *receives* its input hop: None/"host" relays results
    # through the host (the paper's topology); "peer" ships them node-to-
    # node with the host keeping only the control plane.  ``key_fn``
    # (peer-only) turns the hop into a keyed shuffle: items land on the
    # target chosen by a stable hash of ``key_fn(value)``.
    route: str | None = None
    key_fn: Callable[[Any], Any] | None = None

    def __post_init__(self) -> None:
        if self.nclusters < 1:
            raise ValueError(
                f"stage {self.name!r}: nclusters must be >= 1, "
                f"got {self.nclusters}"
            )
        if self.afo is None:
            self.afo = AnyFanOne(sources=self.nclusters)
        elif self.afo.sources != self.nclusters:
            raise ValueError(
                f"stage {self.name!r}: afo.sources must equal nclusters "
                f"({self.afo.sources} != {self.nclusters}); the merge reads "
                "one stream per node"
            )

    @property
    def workers_per_node(self) -> int:
        return self.node_net.group.workers

    @property
    def function(self) -> Callable[[Any], Any]:
        return self.node_net.group.function
