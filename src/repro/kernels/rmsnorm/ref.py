"""Pure-jnp oracle for the fused RMS-norm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_reference(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    """x: [N, D]; scale: [D] (zero-centred: output *= (1 + scale))."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
