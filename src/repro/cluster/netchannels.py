"""Socket-backed channel ends with the threaded runtime's blocking API.

``runtime.local`` wires the Figure-2 network with ``queue.Queue(maxsize=1)``
one-place buffers; this module gives the *same* blocking ``put``/``get``
surface to channel ends whose other end lives in a different OS process.
Because the API and the buffering discipline are identical, the CSP model
checked by ``core.verify`` (one-place nrfa buffer, server answers every
request in finite time, UT flood on shutdown) describes the socket network
too — only the transport changed.

A :class:`ChannelMux` owns one :class:`~repro.cluster.wire.FrameConnection`
and a reader thread that routes incoming frames to per-channel inboxes; a
:class:`NetChannelEnd` is one (wire channel, frame type) view of the mux.

Fault injection: the mux only needs ``send``/``recv``/``close``/``peer``
from its connection, so a :class:`~repro.cluster.chaos.FaultyConnection`
(the chaos layer's drop/delay/duplicate/corrupt wrapper) slots in wherever
a bare ``FrameConnection`` does.  Either way a dead transport surfaces as
:class:`ChannelClosed` on *both* operations — a blocked ``get`` and a
``put`` into a severed socket raise the same typed error, so runtime code
has one failure vocabulary for the read and write sides.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable

from repro.cluster.wire import (
    APP_WIRE_CHANNEL,
    UT,
    Frame,
    FrameConnection,
    FrameType,
)

__all__ = ["ChannelClosed", "ChannelMux", "NetChannelEnd"]


class ChannelClosed(ConnectionError):
    """The underlying socket died while a channel end was blocked on it."""


_CLOSED = object()


class NetChannelEnd:
    """One directional channel end over a mux (paper: ip:port/channel)."""

    def __init__(self, mux: "ChannelMux", wire_channel: int, ftype: FrameType,
                 inbox: queue.Queue):
        self._mux = mux
        self._wire_channel = wire_channel
        self._ftype = ftype
        self._inbox = inbox

    # The queue.Queue surface used by runtime.local -------------------------

    def put(self, obj: Any) -> None:
        """Write ``obj`` to the remote end (UT is sent as a typed frame).

        A dead socket raises :class:`ChannelClosed`, mirroring ``get`` —
        the writer learns its peer is gone as a typed channel error, not a
        raw OSError that depends on which syscall happened to fail.
        """
        frame = (Frame(FrameType.UT, None, self._wire_channel) if obj is UT
                 else Frame(self._ftype, obj, self._wire_channel))
        try:
            self._mux.send(frame)
        except ChannelClosed:
            raise
        except (ConnectionError, OSError) as exc:
            raise ChannelClosed(
                f"peer {self._mux.conn.peer} closed while sending"
            ) from exc

    def get(self, timeout: float | None = None) -> Any:
        obj = self._inbox.get(timeout=timeout)
        if obj is _CLOSED:
            self._inbox.put(_CLOSED)  # keep later readers failing too
            raise ChannelClosed(f"peer {self._mux.conn.peer} closed")
        return obj


class ChannelMux:
    """Routes frames on one connection to per-wire-channel one-place inboxes.

    ``open`` declares a readable channel *before* the reader can deliver to
    it — the paper's "input ends are created before output ends" bootstrap
    rule (§4), enforced here per connection.
    """

    def __init__(self, conn: FrameConnection,
                 on_unrouted: Callable[[Frame], None] | None = None):
        self.conn = conn
        self._inboxes: dict[int, queue.Queue] = {}
        self._lock = threading.Lock()
        self._on_unrouted = on_unrouted
        self._reader: threading.Thread | None = None

    def open(self, wire_channel: int = APP_WIRE_CHANNEL,
             ftype: FrameType = FrameType.WORK, maxsize: int = 1,
             ) -> NetChannelEnd:
        with self._lock:
            if wire_channel not in self._inboxes:
                self._inboxes[wire_channel] = queue.Queue(maxsize=maxsize)
            inbox = self._inboxes[wire_channel]
        return NetChannelEnd(self, wire_channel, ftype, inbox)

    def send(self, frame: Frame) -> None:
        self.conn.send(frame)

    def start(self) -> None:
        self._reader = threading.Thread(
            target=self._read_loop, name="channel-mux-reader", daemon=True
        )
        self._reader.start()

    def _read_loop(self) -> None:
        try:
            while True:
                frame = self.conn.recv()
                self._route(frame)
        except (ConnectionError, OSError, ValueError):
            with self._lock:
                inboxes = list(self._inboxes.values())
            for inbox in inboxes:
                inbox.put(_CLOSED)

    def _route(self, frame: Frame) -> None:
        with self._lock:
            inbox = self._inboxes.get(frame.channel)
        if inbox is None:
            if self._on_unrouted is not None:
                self._on_unrouted(frame)
            return
        if frame.ftype is FrameType.UT:
            inbox.put(UT)
        else:
            inbox.put(frame.payload)

    def close(self) -> None:
        self.conn.close()
