"""Gradient compression for cross-pod reduction (distributed-optimization).

Two compressors, both with error feedback (the residual of the lossy cast is
carried into the next step, preserving convergence — 1-bit Adam lineage):

* ``bf16``  — cast fp32 grads to bfloat16 on the wire (2x);
* ``int8``  — per-tensor-row affine int8 quantisation (4x).

Used by the explicit-DP train-step variant (``runtime.steps`` with
``compress_grads != none``): gradients are compressed before the data-axis
``psum`` (inside ``shard_map``) and decompressed after, so the bytes crossing
the slow pod links shrink by the stated factor.  The roofline collective
term scales accordingly (hillclimb option for collective-bound cells).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = x.reshape(x.shape[0] if x.ndim > 1 else 1, -1).astype(jnp.float32)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def compress(grads: Any, errors: Any, mode: str) -> tuple[Any, Any, Any]:
    """Returns (wire_tree, decompress_meta, new_errors).

    ``wire_tree`` is what travels through the collective; adding the carried
    error before compression and storing the new residual after implements
    error feedback.
    """
    if mode == "none":
        return grads, None, errors

    if mode == "bf16":
        def leaf(g, e):
            corrected = g.astype(jnp.float32) + e
            wire = corrected.astype(jnp.bfloat16)
            return wire, corrected - wire.astype(jnp.float32)

        pairs = jax.tree.map(leaf, grads, errors)
        wire = jax.tree.map(lambda pr: pr[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return wire, None, new_err

    if mode == "int8":
        def leaf(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = _quant_int8(corrected)
            deq = _dequant_int8(q, scale, corrected.shape)
            return (q, scale), corrected - deq

        pairs = jax.tree.map(leaf, grads, errors)
        wire = jax.tree.map(lambda pr: pr[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        shapes = jax.tree.map(lambda g: g.shape, grads)
        return wire, shapes, new_err

    raise ValueError(f"unknown compression mode {mode!r}")


def decompress(wire: Any, meta: Any, mode: str) -> Any:
    if mode == "none" or mode == "bf16":
        return jax.tree.map(lambda w: w.astype(jnp.float32), wire) \
            if mode == "bf16" else wire
    if mode == "int8":
        def leaf(pair, shape):
            q, scale = pair
            return _dequant_int8(q, scale, shape)

        return jax.tree.map(
            leaf, wire, meta, is_leaf=lambda x: isinstance(x, tuple)
        )
    raise ValueError(f"unknown compression mode {mode!r}")


def wire_bytes(tree: Any, mode: str) -> int:
    """Bytes on the wire for one gradient exchange (reporting helper)."""
    import math

    def nbytes(x):
        return math.prod(x.shape) * x.dtype.itemsize

    if mode == "int8":
        total = 0
        for leaf in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, tuple)):
            if isinstance(leaf, tuple):
                total += sum(nbytes(x) for x in leaf)
            else:
                total += nbytes(leaf)
        return total
    return sum(nbytes(x) for x in jax.tree.leaves(tree))
