"""repro.cluster — the real (multi-process, TCP) deployment subsystem.

The paper's central deliverable is *deployment*: a Host-Node-Loader (HNL)
bootstraps a load network on port 2000 / channel 1, ships code to Node-Loaders
(NL) running on idle workstations, wires the application network, and only
then runs the emit/cluster/collect farm (§4, Figure 1).  ``runtime.local``
executes the same network as threads in one process; this package crosses the
process boundary: the *same* :class:`~repro.core.dsl.ClusterSpec` runs over
real OS processes connected by sockets, with zero changes to user code —
``ClusterBuilder.build_application(spec, backend="cluster")``.

Modules (one per architectural role):

* :mod:`repro.cluster.wire` — length-prefixed msgpack/pickle/ndarray wire
  format with a typed frame header (REGISTER/LOAD/WORK_REQUEST/WORK_BATCH/
  RESULT_BATCH/HEARTBEAT/UT plus the legacy WORK/RESULT single forms);
* :mod:`repro.cluster.netchannels` — socket-backed channel ends with the same
  blocking queue API as the threaded runtime, so the protocol model-checked
  by ``core.verify`` still describes the network;
* :mod:`repro.cluster.host_loader` — the Host-Node-Loader (registration,
  code broadcast, the credit-pipelined onrl server loop, collect, failure
  re-dispatch);
* :mod:`repro.cluster.node_loader` — the Node-Loader a worker machine runs
  (register, boot-preload, load, windowed request→compute→batched deliver,
  UT shutdown);
* :mod:`repro.cluster.membership` — registry + heartbeat tracking feeding the
  ``runtime.failures`` detection thresholds, with a launch lifecycle
  (launching/registered/loaded/done/dead/replaced) for the placement policy;
* :mod:`repro.cluster.deploy` — the pluggable deployment layer: the
  :class:`~repro.cluster.deploy.base.Launcher` contract plus LocalLauncher
  (subprocesses, §6.1 "test on one host first"), SSHLauncher (the identical
  node-loader command fanned out over ssh, with rsync/tar code sync) and
  InProcessLauncher (threads, for launcher-logic tests);
* :mod:`repro.cluster.spawn` — ProcessClusterApplication: cluster lifecycle
  + placement policy over whichever launcher the deployment chose;
* :mod:`repro.cluster.service` — ClusterService: a persistent warm node pool
  multiplexing many jobs over one bootstrap (digest-keyed warm code cache,
  FIFO-with-priority scheduling);
* :mod:`repro.cluster.gateway` — the job gateway in front of the service:
  a durable SQLite-backed submit queue (tickets survive client disconnects
  and gateway restarts), a weighted-fair multi-tenant admission scheduler
  (deficit round robin + per-tenant in-flight caps), and a queue-driven
  autoscaler growing/shrinking the pool through the launcher's late-join
  and graceful-retirement paths;
* :mod:`repro.cluster.telemetry` — live observability: the event bus +
  metrics registry every host-side component publishes into, the
  ``GET /metrics`` / dashboard HTTP endpoint, and the JSONL trace writer;
* :mod:`repro.cluster.chaos` — fault injection against the real transport:
  a declarative :class:`~repro.cluster.chaos.FaultPlan` (kill/drop/delay/
  duplicate/corrupt/stall-heartbeat/partition/straggler) armed by a
  :class:`~repro.cluster.chaos.ChaosController`, exercising the heal +
  retry machinery continuously (``ClusterService(chaos=...)``).

This package must stay importable without jax: the node-loader bootstrap path
(wire/netchannels/membership/node_loader) imports no accelerator code; user
work functions pull in whatever they need when the shipped code is loaded.
"""

from repro.cluster.chaos import (  # noqa: F401
    ChaosController,
    Fault,
    FaultPlan,
)
from repro.cluster.wire import UT, Frame, FrameType  # noqa: F401
