"""Mixture-of-Experts FFN with capacity-based dispatch (EP over the model axis).

Routing: top-k (llama4: k=1 + shared expert; olmoe: k=8).  Dispatch is the
TPU-native scatter/gather pattern:

  1. router logits -> top-k (expert id, prob) per token;
  2. position-in-expert via a cumulative-sum over the one-hot choice
     (GShard); tokens beyond ``capacity = cf * T * k / E`` are dropped to
     the residual path;
  3. ``scatter`` token activations into a dense [E, C, D] buffer — experts
     are sharded over the *model* mesh axis, activations are replicated on
     it, so the scatter is local to each shard (no all-to-all on the XLA
     path; an all-to-all variant is a hillclimb option);
  4. batched expert SwiGLU via einsum over the stacked [E, D, F] weights;
  5. gather back, scale by router prob, sum over k slots.

Aux losses: Switch load-balance loss + router z-loss, returned to the caller
(weighted into the training objective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, fan_in_normal


def moe_param_specs(layers: int, d: int, f_expert: int, n_experts: int,
                    n_shared: int, d_shared_ff: int) -> dict:
    specs = {
        "router": ParamSpec(
            (layers, d, n_experts), ("layers", "d_model_fsdp", "experts"),
            stddev=fan_in_normal((d, n_experts)),
        ),
        "w_gate": ParamSpec(
            (layers, n_experts, d, f_expert),
            ("layers", "experts", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, f_expert)),
        ),
        "w_up": ParamSpec(
            (layers, n_experts, d, f_expert),
            ("layers", "experts", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, f_expert)),
        ),
        "w_down": ParamSpec(
            (layers, n_experts, f_expert, d),
            ("layers", "experts", "d_ff", "d_model_fsdp"),
            stddev=fan_in_normal((f_expert, d)),
        ),
    }
    if n_shared > 0:
        specs["shared_w_gate"] = ParamSpec(
            (layers, d, d_shared_ff), ("layers", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, d_shared_ff)),
        )
        specs["shared_w_up"] = ParamSpec(
            (layers, d, d_shared_ff), ("layers", "d_model_fsdp", "d_ff"),
            stddev=fan_in_normal((d, d_shared_ff)),
        )
        specs["shared_w_down"] = ParamSpec(
            (layers, d_shared_ff, d), ("layers", "d_ff", "d_model_fsdp"),
            stddev=fan_in_normal((d_shared_ff, d)),
        )
    return specs


def position_in_expert_onehot(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """GShard-literal positions: cumsum over a [T*k, E] one-hot.

    O(T*k*E) memory — the dominant HBM term for large-E MoE (olmoe:
    134 GB/device at train_4k).  Kept as the paper-era baseline."""
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.sum(pos_in_e * onehot, axis=-1)


def position_in_expert_sort(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Sort-based positions: O(T*k) memory, identical assignment.

    Stable argsort groups slots by expert while preserving token order, so
    position-in-expert = rank-within-sorted-run — exactly the one-hot
    cumsum's token-order positions (verified by property test)."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_sorted = jnp.arange(n) - starts[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def moe_ffn(
    x: jax.Array,
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    compute_dtype=jnp.bfloat16,
    dispatch: str = "onehot",  # "onehot" (GShard baseline) | "sort" (O(S*k))
) -> tuple[jax.Array, dict]:
    """x: [B, S, D] -> (out [B, S, D], aux metrics/losses).

    **Grouped dispatch** (GShard "groups" = the data-sharded batch rows):
    routing positions, the [E, C, D] scatter and the gather-back are all
    computed *per batch row* (vmap over B), so under data parallelism every
    shard dispatches only its local tokens — no global sort/cumsum, no
    cross-shard token movement on the XLA path.  The dispatch buffer is
    [B, E, C, D] with B on the data axis and E on the model axis (EP).

    ``params`` holds per-layer slices: router [D, E], w_gate/w_up [E, D, F],
    w_down [E, F, D] (+ optional shared_* dense weights).
    """
    B, S, D = x.shape
    E = num_experts
    capacity = max(int(capacity_factor * S * top_k / E), 1)

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32),
        params["router"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # [B, S, E]
    top_p, top_e = jax.lax.top_k(probs, top_k)  # [B, S, k]
    # Normalise the selected probabilities (Mixtral/OLMoE convention).
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(B, S * top_k)
    pos_fn = (position_in_expert_sort if dispatch == "sort"
              else position_in_expert_onehot)
    pos = jax.vmap(lambda fe: pos_fn(fe, E))(flat_e)  # [B, S*k]
    keep = pos < capacity
    drop_fraction = 1.0 - jnp.mean(keep.astype(jnp.float32))
    safe_pos = jnp.where(keep, pos, capacity)
    token_idx = jnp.repeat(jnp.arange(S), top_k)

    def disp(xg, fe, sp):
        buf = jnp.zeros((E, capacity + 1, D), compute_dtype)
        buf = buf.at[fe, sp].set(xg[token_idx].astype(compute_dtype))
        return buf[:, :capacity]

    buf = jax.vmap(disp)(x, flat_e, safe_pos)  # [B, E, C, D]

    # Batched expert SwiGLU (E sharded over the model axis: EP).
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(compute_dtype))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h,
                         params["w_down"].astype(compute_dtype))

    # Gather back per group and combine over the k slots.
    def undisp(ob, fe, sp):
        return ob[fe, jnp.minimum(sp, capacity - 1)]  # [S*k, D]

    gathered = jax.vmap(undisp)(out_buf, flat_e, safe_pos)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weighted = gathered.astype(jnp.float32) * top_p.reshape(B, S * top_k, 1)
    out = jnp.sum(weighted.reshape(B, S, top_k, D), axis=2)

    if "shared_w_gate" in params:
        sg = jnp.einsum("bsd,df->bsf", x.astype(compute_dtype),
                        params["shared_w_gate"].astype(compute_dtype))
        su = jnp.einsum("bsd,df->bsf", x.astype(compute_dtype),
                        params["shared_w_up"].astype(compute_dtype))
        sh = jax.nn.silu(sg) * su
        out = out + jnp.einsum(
            "bsf,fd->bsd", sh, params["shared_w_down"].astype(compute_dtype)
        ).astype(jnp.float32)

    # -- aux losses ----------------------------------------------------------
    # Switch load-balance: E * sum_e f_e * P_e (f = fraction of tokens
    # dispatched to e, P = mean router prob for e).
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(dispatch_frac * mean_prob)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(router_logits, axis=-1) ** 2)

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_drop_fraction": drop_fraction,
    }
    return out.astype(x.dtype), aux
