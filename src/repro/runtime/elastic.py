"""Elastic re-meshing: resume the same job on a different node count.

Paper requirement 4 ("the application can be built and deployed ... using
different workstations, not restricted to a specific set") maps to: rebuild
the mesh from the surviving devices, re-derive every sharding through the
same rules, restore the checkpoint against the new shardings, continue.
``Nclusters`` is a *parameter* of the deployment, exactly as in the DSL.

SPMD cannot change topology mid-step, so elasticity is a step-boundary
operation: detect -> checkpoint (or use the last async one) -> rebuild ->
restore -> resume.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.channels import ShardingRules, rules_for_shape_kind
from repro.launch.mesh import axis_types_kwargs


@dataclass
class ElasticController:
    """Owns the device pool and builds (mesh, rules) for a node count."""

    model_axis: int = 1
    devices_per_node: int = 1
    shape_kind: str = "train"

    def available_nodes(self, excluded: set[int] | None = None) -> list[int]:
        n_dev = len(jax.devices())
        nodes = n_dev // (self.devices_per_node * self.model_axis)
        return [n for n in range(nodes) if n not in (excluded or set())]

    def build(self, nodes: list[int]) -> tuple[Mesh, ShardingRules]:
        if not nodes:
            raise RuntimeError("no surviving nodes to build a mesh from")
        per_node = self.devices_per_node * self.model_axis
        devs = np.asarray(jax.devices())
        chosen = np.concatenate(
            [devs[n * per_node : (n + 1) * per_node] for n in nodes]
        )
        data = len(nodes) * self.devices_per_node
        mesh_devs = chosen.reshape(data, self.model_axis)
        mesh = Mesh(mesh_devs, ("data", "model"), **axis_types_kwargs(2))
        rules = rules_for_shape_kind(mesh, self.shape_kind)
        return mesh, rules

    def largest_batch_divisor_nodes(self, global_batch: int,
                                    excluded: set[int]) -> list[int]:
        """Pick the largest surviving node subset whose data-parallel degree
        divides the global batch (keeps the step semantics identical)."""
        nodes = self.available_nodes(excluded)
        while nodes:
            data = len(nodes) * self.devices_per_node
            if global_batch % data == 0:
                return nodes
            nodes = nodes[:-1]
        raise RuntimeError("no node subset divides the global batch")
