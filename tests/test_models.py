"""Model-layer unit & property tests: attention equivalences, mLSTM
chunkwise-vs-sequential, RG-LRU chaining, MoE routing invariants, chunked CE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import attention as am
from repro.models import moe as moe_mod
from repro.models import xlstm as xm
from repro.models.layers import chunked_cross_entropy
from repro.models.recurrent import causal_conv1d, rglru_scan, rglru_step


def keys(n, seed=0):
    return [jax.random.fold_in(jax.random.PRNGKey(seed), i) for i in range(n)]


# -- attention -----------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("q_chunk", [16, 64])
def test_blockwise_equals_reference(window, q_chunk):
    ks = keys(3)
    q = jax.random.normal(ks[0], (2, 128, 4, 16))
    k = jax.random.normal(ks[1], (2, 128, 2, 16))
    v = jax.random.normal(ks[2], (2, 128, 2, 16))
    ref = am.attention_reference(q, k, v, causal=True, window=window)
    blk = am.attention_blockwise(q, k, v, causal=True, window=window,
                                 q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref), atol=2e-6)
    unr = am.attention_blockwise(q, k, v, causal=True, window=window,
                                 q_chunk=q_chunk, unroll=True)
    np.testing.assert_allclose(np.asarray(unr), np.asarray(ref), atol=2e-6)


def test_decode_attention_per_slot_lengths():
    """Continuous batching: per-batch cache_len masks independently."""
    ks = keys(3)
    B, S, H, D = 3, 32, 2, 8
    q = jax.random.normal(ks[0], (B, 1, H, D))
    ck = jax.random.normal(ks[1], (B, S, H, D))
    cv = jax.random.normal(ks[2], (B, S, H, D))
    lens = jnp.asarray([4, 17, 32])
    out = am.decode_attention(q, ck, cv, lens)
    for b, n in enumerate([4, 17, 32]):
        ref = am.decode_attention(q[b : b + 1], ck[b : b + 1, :],
                                  cv[b : b + 1, :], jnp.int32(n))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   atol=1e-6)


def test_rope_preserves_norm_and_relative_position():
    ks = keys(2)
    x = jax.random.normal(ks[0], (1, 64, 2, 32))
    r = am.apply_rope(x, jnp.arange(64), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    q = jax.random.normal(ks[1], (1, 1, 1, 32))
    k = jax.random.normal(ks[0], (1, 1, 1, 32))
    def dot(i, j):
        qr = am.apply_rope(q, jnp.asarray([i]), 10000.0)
        kr = am.apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot(5, 3) - dot(12, 10)) < 1e-4


# -- mLSTM / sLSTM ---------------------------------------------------------------


@given(chunk=st.sampled_from([8, 16, 32]), seed=st.integers(0, 10))
@settings(max_examples=12, deadline=None)
def test_mlstm_chunkwise_property(chunk, seed):
    ks = keys(5, seed)
    B, S, H, D = 1, 64, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    i_raw = jax.random.normal(ks[3], (B, S, H))
    f_raw = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h_ref, st_ref = xm.mlstm_sequential(q, k, v, i_raw, f_raw)
    h_ck, st_ck = xm.mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               atol=5e-4, rtol=1e-3)
    for a, b in zip(st_ref, st_ck):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-3)


def test_mlstm_decode_continuation():
    ks = keys(5)
    B, S, H, D = 2, 32, 2, 8
    q, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    i_raw = jax.random.normal(ks[3], (B, S, H))
    f_raw = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h_full, _ = xm.mlstm_sequential(q, k, v, i_raw, f_raw)
    _, st = xm.mlstm_sequential(q[:, :-1], k[:, :-1], v[:, :-1],
                                i_raw[:, :-1], f_raw[:, :-1])
    h_step, _ = xm.mlstm_step(q[:, -1], k[:, -1], v[:, -1],
                              i_raw[:, -1], f_raw[:, -1], st)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(h_full[:, -1]),
                               atol=1e-5)


def test_slstm_bounded_and_stateful():
    ks = keys(8)
    B, S, H, D = 1, 48, 2, 8
    gates = {g: jax.random.normal(ks[i], (B, S, H, D))
             for i, g in enumerate(["z", "f", "i", "o"])}
    r = {g: jax.random.normal(ks[4 + i], (H, D, D)) * 0.2
         for i, g in enumerate(["z", "f", "i", "o"])}
    h, state = xm.slstm_scan(gates, r)
    assert jnp.isfinite(h).all()
    assert jnp.abs(h).max() < 10.0  # normalised memory keeps h bounded
    # chaining halves == full
    h1, s1 = xm.slstm_scan({g: v[:, :24] for g, v in gates.items()}, r)
    h2, s2 = xm.slstm_scan({g: v[:, 24:] for g, v in gates.items()}, r, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h), atol=1e-5)


# -- RG-LRU ----------------------------------------------------------------------


def test_rglru_scan_matches_step():
    ks = keys(2)
    params = {
        "lambda": jnp.ones((64,)) * 0.5,
        "w_a": jax.random.normal(ks[0], (64,)) * 0.1,
        "b_a": jnp.zeros((64,)),
        "w_x": jax.random.normal(ks[1], (64,)) * 0.1,
        "b_x": jnp.zeros((64,)),
    }
    x = jax.random.normal(ks[0], (2, 16, 64))
    y_scan, h_last = rglru_scan(params, x)
    h = jnp.zeros((2, 64))
    for t in range(16):
        y_t, h = rglru_step(params, x[:, t], h)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_scan[:, t]),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last), atol=1e-5)


def test_causal_conv1d_is_causal():
    ks = keys(2)
    w = jax.random.normal(ks[0], (4, 8))
    x = jax.random.normal(ks[1], (1, 16, 8))
    y, _ = causal_conv1d(w, x)
    x2 = x.at[:, 10:].set(0.0)
    y2, _ = causal_conv1d(w, x2)
    np.testing.assert_allclose(np.asarray(y[:, :10]), np.asarray(y2[:, :10]),
                               atol=1e-6)


# -- MoE ------------------------------------------------------------------------


@given(top_k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_moe_routing_invariants(top_k, seed):
    ks = keys(3, seed)
    B, S, D, E, F = 2, 16, 32, 8, 64
    x = jax.random.normal(ks[0], (B, S, D))
    params = {
        "router": jax.random.normal(ks[1], (D, E)) * 0.1,
        "w_gate": jax.random.normal(ks[2], (E, D, F)) * 0.05,
        "w_up": jax.random.normal(ks[0], (E, D, F)) * 0.05,
        "w_down": jax.random.normal(ks[1], (E, F, D)) * 0.05,
    }
    out, aux = moe_mod.moe_ffn(x, params, num_experts=E, top_k=top_k,
                               capacity_factor=8.0,
                               compute_dtype=jnp.float32)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    # with generous capacity nothing is dropped
    assert float(aux["moe_drop_fraction"]) == 0.0
    # load-balance loss >= 1 (equality at perfect uniformity)
    assert float(aux["moe_lb_loss"]) >= 0.99


def test_moe_capacity_drops_are_reported():
    ks = keys(2)
    B, S, D, E, F = 2, 32, 16, 4, 32
    x = jax.random.normal(ks[0], (B, S, D))
    # heavily skewed router -> one expert overloaded at cf=0.25
    router = jnp.zeros((D, E)).at[:, 0].set(5.0)
    params = {
        "router": router,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
        "w_up": jax.random.normal(ks[0], (E, D, F)) * 0.05,
        "w_down": jax.random.normal(ks[1], (E, F, D)) * 0.05,
    }
    _out, aux = moe_mod.moe_ffn(x, params, num_experts=E, top_k=1,
                                capacity_factor=0.25,
                                compute_dtype=jnp.float32)
    assert float(aux["moe_drop_fraction"]) > 0.5


# -- chunked CE -------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_ce_matches_full(chunk):
    ks = keys(3)
    B, S, D, V, Vp = 2, 64, 16, 50, 56
    x = jax.random.normal(ks[0], (B, S, D))
    head = jax.random.normal(ks[1], (D, Vp)) * 0.1
    targets = jax.random.randint(ks[2], (B, S), 0, V)
    ce = chunked_cross_entropy(x, head, targets, vocab_size=V,
                               seq_chunk=chunk, compute_dtype=jnp.float32)
    # full reference over the true vocab only
    logits = (x @ head)[..., :V]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    ref = jnp.mean(lse - tgt)
    np.testing.assert_allclose(float(ce), float(ref), rtol=1e-5)
    un = chunked_cross_entropy(x, head, targets, vocab_size=V,
                               seq_chunk=chunk, compute_dtype=jnp.float32,
                               unroll=True)
    np.testing.assert_allclose(float(un), float(ref), rtol=1e-5)


@given(seed=st.integers(0, 100), e=st.sampled_from([2, 8, 64]))
@settings(max_examples=30, deadline=None)
def test_moe_sort_dispatch_equals_onehot(seed, e):
    """The O(T) stable-argsort position computation must assign exactly the
    GShard one-hot cumsum positions (the §Perf cell-1 optimization is
    semantics-preserving)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 400))
    fe = jnp.asarray(rng.integers(0, e, n), jnp.int32)
    a = moe_mod.position_in_expert_onehot(fe, e)
    b = moe_mod.position_in_expert_sort(fe, e)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_grouped_dispatch_is_batch_local():
    """Grouped dispatch: permuting batch rows permutes outputs (no
    cross-row interaction) — the property that keeps dispatch local to
    each data shard."""
    ks = keys(5)
    B, S, D, E, F = 4, 16, 16, 4, 32
    x = jax.random.normal(ks[0], (B, S, D))
    params = {
        "router": jax.random.normal(ks[1], (D, E)) * 0.1,
        "w_gate": jax.random.normal(ks[2], (E, D, F)) * 0.05,
        "w_up": jax.random.normal(ks[3], (E, D, F)) * 0.05,
        "w_down": jax.random.normal(ks[4], (E, F, D)) * 0.05,
    }
    kw = dict(num_experts=E, top_k=2, capacity_factor=8.0,
              compute_dtype=jnp.float32)
    out, _ = moe_mod.moe_ffn(x, params, **kw)
    perm = jnp.asarray([2, 0, 3, 1])
    out_p, _ = moe_mod.moe_ffn(x[perm], params, **kw)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out[perm]),
                               atol=1e-5)
