"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517; unverified).
24L d_model=1024 4H d_ff=0 (blocks carry their own projections)
vocab=50304.  Ratio 3 mLSTM : 1 sLSTM per period.  Recurrent state is O(1)
in sequence length, so xlstm runs long_500k."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    supports_long_context=True,
)
